"""Shared benchmark pipeline: a properly-trained reduced DeepSeek-V2-Lite
backbone + train/test trace sets, cached under artifacts/ so every paper
figure/table reads the same experiment."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts")

BACKBONE_STEPS = 900
N_TRAIN_TRACES = 64
N_TEST_TRACES = 16
TRACE_LEN = 72          # prompt 16 + 56 generated
PROMPT_LEN = 16


def backbone_and_traces(fresh: bool = False, log=print):
    """Returns (cfg, model, params, train_traces, test_traces)."""
    from repro.configs import get_reduced
    from repro.core.tracing import collect_traces, load_traces, save_traces
    from repro.data import make_topic_corpus, sample_prompts
    from repro.launch.train import train
    from repro.models import build_model
    from repro.training import checkpoint as ckpt

    os.makedirs(ART, exist_ok=True)
    cfg = get_reduced("deepseek-v2-lite")
    model = build_model(cfg)
    ck = os.path.join(ART, "backbone.npz")
    tr_path = os.path.join(ART, "traces_train.npz")
    te_path = os.path.join(ART, "traces_test.npz")

    if not fresh and all(os.path.exists(p) for p in (ck, tr_path, te_path)):
        params = ckpt.load(ck, jax.eval_shape(model.init,
                                              jax.random.PRNGKey(0)))
        params = jax.tree.map(jnp.asarray, params)
        return (cfg, model, params, load_traces(tr_path),
                load_traces(te_path))

    t0 = time.time()
    log(f"[common] training backbone ({BACKBONE_STEPS} steps)...")
    params, losses = train("deepseek-v2-lite", reduced=True,
                           steps=BACKBONE_STEPS, batch_size=16, seq_len=64,
                           lr=3e-3, log=log)
    ckpt.save(ck, params)
    log(f"[common] backbone done ({time.time() - t0:.0f}s, "
        f"final loss {losses[-1]:.3f})")

    corpus = make_topic_corpus(cfg.vocab_size, n_topics=8, seed=0)
    log(f"[common] collecting {N_TRAIN_TRACES}+{N_TEST_TRACES} traces...")
    # train traces: topic corpus (stands in for Puffin)
    train_prompts = sample_prompts(corpus, N_TRAIN_TRACES, PROMPT_LEN,
                                   seed=10)
    train_traces = collect_traces(model, params, train_prompts,
                                  max_new=TRACE_LEN - PROMPT_LEN,
                                  cache_len=TRACE_LEN, seed=0)
    # test traces: DIFFERENT seed + slight topic shift (stands in for
    # WebGLM-QA generalization)
    corpus_test = make_topic_corpus(cfg.vocab_size, n_topics=8, seed=7)
    test_prompts = sample_prompts(corpus_test, N_TEST_TRACES, PROMPT_LEN,
                                  seed=99)
    test_traces = collect_traces(model, params, test_prompts,
                                 max_new=TRACE_LEN - PROMPT_LEN,
                                 cache_len=TRACE_LEN, seed=1)
    save_traces(tr_path, train_traces)
    save_traces(te_path, test_traces)
    log(f"[common] traces done ({time.time() - t0:.0f}s total)")
    return cfg, model, params, train_traces, test_traces


def predictor_cfg(cfg, n_moe):
    from repro.configs.base import PredictorConfig
    return PredictorConfig(
        token_emb_dim=cfg.d_model, num_model_layers=n_moe,
        num_experts=cfg.moe.num_experts, layer_emb_dim=32, d_model=96,
        num_layers=4, num_heads=8, d_ff=192, max_seq=TRACE_LEN,
        top_k=cfg.moe.top_k, dropout=0.1)


def trained_predictor(fresh: bool = False, log=print):
    """Returns (pcfg, predictor_params, history, traces bundle)."""
    import pickle

    from repro.core.predictor_train import train_predictor
    from repro.core.tracing import moe_layer_ids
    from repro.training import checkpoint as ckpt
    from repro.core.predictor import predictor_init

    bundle = backbone_and_traces(fresh, log)
    cfg, model, params, train_traces, test_traces = bundle
    n_moe = len(moe_layer_ids(cfg))
    pcfg = predictor_cfg(cfg, n_moe)

    pk = os.path.join(ART, "predictor.npz")
    hk = os.path.join(ART, "predictor_hist.pkl")
    if not fresh and os.path.exists(pk) and os.path.exists(hk):
        template = jax.eval_shape(
            lambda: predictor_init(jax.random.PRNGKey(0), pcfg))
        pp = jax.tree.map(jnp.asarray, ckpt.load(pk, template))
        with open(hk, "rb") as f:
            hist = pickle.load(f)
        return pcfg, pp, hist, bundle

    log("[common] training predictor (paper §3.2.3 protocol)...")
    pp, hist = train_predictor(train_traces, test_traces, pcfg, epochs=16,
                               batch_size=4, base_lr=3e-3, patience=5,
                               log=log)
    ckpt.save(pk, pp)
    with open(hk, "wb") as f:
        pickle.dump(hist, f)
    return pcfg, pp, hist, bundle
