"""Beyond-paper: the integrated offload serving engine (real decode, real
slot buffer) under each prefetch policy — hit rates + modeled stall."""
from __future__ import annotations

import numpy as np


def run(log=print):
    from benchmarks.common import trained_predictor
    from repro.core.policies import (MoEInfinityPolicy, NextLayerAllPolicy,
                                     NoPrefetchPolicy, OnlineMoEBeyondPolicy)
    from repro.core.tracing import moe_layer_ids
    from repro.data import make_topic_corpus, sample_prompts
    from repro.serving.engine import OffloadEngine

    pcfg, pp, hist, bundle = trained_predictor(log=log)
    cfg, model, params, train_traces, _ = bundle
    n_moe = len(moe_layer_ids(cfg))
    e = cfg.moe.num_experts
    capacity = max(1, int(0.2 * n_moe * e))

    corpus = make_topic_corpus(cfg.vocab_size, n_topics=8, seed=3)
    prompt = sample_prompts(corpus, 1, 12, seed=5)[0]

    policies = {
        "none": NoPrefetchPolicy(),
        "next-layer-all": NextLayerAllPolicy(e),
        "moe-infinity": MoEInfinityPolicy(train_traces, n_moe, e, width=6),
        "moe-beyond-online": OnlineMoEBeyondPolicy(pp, pcfg, width=6),
    }
    out = {}
    log("  policy,cache_hit,fetch_MiB,stall_ms_total (engine, capacity 20%)")
    for name, pol in policies.items():
        eng = OffloadEngine(model, params, pol, capacity)
        eng.generate(prompt, max_new=36, cache_len=64)
        s = eng.stats
        log(f"  {name},{s.hit_rate:.3f},{s.fetch_bytes / 2**20:.1f},"
            f"{s.sim_stall_s * 1e3:.1f}")
        out[f"engine_{name}_hit"] = s.hit_rate
    return out
