"""Beyond-paper: the integrated offload serving engine (real decode, real
slot buffer) under each prefetch policy — hit rates + modeled stall — plus
batched-vs-sequential decode throughput for the continuous-batching engine.

CI smoke mode (no cached artifacts, tiny backbone, JSON artifact):
  PYTHONPATH=src python -m benchmarks.engine_bench --tiny \
      --out artifacts/engine_bench.json

Mixed-length workload mode (--mixed): ragged prompts at batch >= 4 through
the paged + chunked-prefill engine vs the token-by-token prompt path —
reports per-request admission-to-first-token latency and the KV memory
high-water (actual blocks allocated vs the contiguous batch x cache_len
model):
  PYTHONPATH=src python -m benchmarks.engine_bench --tiny --mixed \
      --out artifacts/engine_bench_mixed.json
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _throughput(model, params, cfg, prompts, max_new: int, cache_len: int,
                batch: int, log=print):
    """tokens/s: one batched engine at ``batch`` vs the same requests run
    sequentially through one batch-1 engine. Both are warmed first so jit
    compilation stays out of the timed region."""
    from repro.core.tracing import moe_layer_ids
    from repro.serving.engine import OffloadEngine
    from repro.serving.scheduler import BatchedOffloadEngine

    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts

    seq = OffloadEngine(model, params, None, n_total)
    seq.generate(prompts[0], max_new=2, cache_len=cache_len)      # warm
    tok0 = seq.stats.tokens
    t0 = time.perf_counter()
    for p in prompts:
        seq.generate(p, max_new=max_new, cache_len=cache_len)
    seq_s = time.perf_counter() - t0
    seq_tokens = seq.stats.tokens - tok0

    bat = BatchedOffloadEngine(model, params, None, n_total,
                               max_batch=batch)
    bat.generate(prompts, max_new=2, cache_len=cache_len)         # warm
    tok0 = bat.stats.tokens
    t0 = time.perf_counter()
    bat.generate(prompts, max_new=max_new, cache_len=cache_len)
    bat_s = time.perf_counter() - t0
    bat_tokens = bat.stats.tokens - tok0

    seq_tps = seq_tokens / max(seq_s, 1e-9)
    bat_tps = bat_tokens / max(bat_s, 1e-9)
    log(f"  throughput: sequential {seq_tps:.1f} tok/s, "
        f"batch={batch} {bat_tps:.1f} tok/s "
        f"({bat_tps / max(seq_tps, 1e-9):.2f}x, "
        f"mean batch {bat.stats.mean_batch:.2f})")
    return {"seq_tok_s": seq_tps, "batched_tok_s": bat_tps,
            "speedup": bat_tps / max(seq_tps, 1e-9),
            "mean_batch": bat.stats.mean_batch}


def _mixed_workload(cfg, corpus, n_requests: int, seed: int):
    """Ragged prompt lengths spanning sub-block to multi-block: the shape
    continuous batching actually sees."""
    from repro.data import sample_prompts
    lengths = [4, 28, 8, 36, 6, 20, 32, 12][:n_requests]
    rng_seed = seed
    prompts = []
    for i, ln in enumerate(lengths):
        prompts.append(sample_prompts(corpus, 1, ln, seed=rng_seed + i)[0])
    return prompts


def _mixed_latency(model, params, cfg, prompts, max_new: int, cache_len: int,
                   batch: int, log=print):
    """Admission-to-first-token latency + KV memory high-water: paged engine
    with chunked prefill vs the same engine on the token-by-token prompt
    path (paged=False), same requests, batch >= 4."""
    from repro.core.tracing import moe_layer_ids
    from repro.serving.scheduler import BatchedOffloadEngine

    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts

    def ttft_stats(eng, rid_from):
        # only the timed run's requests: the warm run's first tokens pay
        # jit compilation and would swamp the scheduling signal
        tt = sorted(v for r, v in eng.ttft().items() if r >= rid_from)
        if not tt:
            return {"mean": 0.0, "p50": 0.0, "max": 0.0}
        return {"mean": float(sum(tt) / len(tt)),
                "p50": float(tt[len(tt) // 2]),
                "max": float(tt[-1])}

    tok = BatchedOffloadEngine(model, params, None, n_total,
                               max_batch=batch, paged=False)
    tok.generate(prompts, max_new=2, cache_len=cache_len)            # warm
    rid0 = tok._next_rid
    t0 = time.perf_counter()
    outs_tok = tok.generate(prompts, max_new=max_new, cache_len=cache_len)
    tok_s = time.perf_counter() - t0
    tok_tt = ttft_stats(tok, rid0)

    pag = BatchedOffloadEngine(model, params, None, n_total,
                               max_batch=batch, block_size=8,
                               prefill_chunk=16)
    pag.generate(prompts, max_new=2, cache_len=cache_len)            # warm
    rid0 = pag._next_rid
    chunks0, ptok0 = pag.stats.prefill_chunks, pag.stats.prefill_tokens
    t0 = time.perf_counter()
    outs_pag = pag.generate(prompts, max_new=max_new, cache_len=cache_len)
    pag_s = time.perf_counter() - t0
    pag_tt = ttft_stats(pag, rid0)

    assert outs_pag == outs_tok, "paged/token prompt paths diverged"

    # memory model: actual paged high-water vs contiguous batch x cache_len
    per_tok = pag.kv_block_bytes / pag.block_size
    rows_bytes = int(batch * cache_len * per_tok)
    paged_bytes = pag.kv_high_water_bytes
    log(f"  mixed-length batch={batch}: TTFT mean "
        f"{tok_tt['mean'] * 1e3:.1f}ms token-path vs "
        f"{pag_tt['mean'] * 1e3:.1f}ms paged+chunked "
        f"({tok_tt['mean'] / max(pag_tt['mean'], 1e-9):.2f}x); KV high-water "
        f"{paged_bytes / 2**10:.0f}KiB paged vs {rows_bytes / 2**10:.0f}KiB "
        f"batch*cache_len rows "
        f"({paged_bytes / max(rows_bytes, 1):.2f}x)")
    return {
        "ttft_token_mean_s": tok_tt["mean"],
        "ttft_token_p50_s": tok_tt["p50"],
        "ttft_token_max_s": tok_tt["max"],
        "ttft_paged_mean_s": pag_tt["mean"],
        "ttft_paged_p50_s": pag_tt["p50"],
        "ttft_paged_max_s": pag_tt["max"],
        "ttft_speedup": tok_tt["mean"] / max(pag_tt["mean"], 1e-9),
        "wall_token_s": tok_s,
        "wall_paged_s": pag_s,
        "kv_high_water_bytes": paged_bytes,
        "kv_rows_model_bytes": rows_bytes,
        "kv_high_water_frac": paged_bytes / max(rows_bytes, 1),
        "kv_blocks_high_water": pag.pool.stats.high_water,
        "prefill_chunks": pag.stats.prefill_chunks - chunks0,
        "prefill_tokens": pag.stats.prefill_tokens - ptok0,
        "streams_identical": True,
    }


def run(log=print):
    from benchmarks.common import trained_predictor
    from repro.core.policies import (MoEInfinityPolicy, NextLayerAllPolicy,
                                     NoPrefetchPolicy, OnlineMoEBeyondPolicy)
    from repro.core.tracing import moe_layer_ids
    from repro.data import make_topic_corpus, sample_prompts
    from repro.serving.engine import OffloadEngine

    pcfg, pp, hist, bundle = trained_predictor(log=log)
    cfg, model, params, train_traces, _ = bundle
    n_moe = len(moe_layer_ids(cfg))
    e = cfg.moe.num_experts
    capacity = max(1, int(0.2 * n_moe * e))

    corpus = make_topic_corpus(cfg.vocab_size, n_topics=8, seed=3)
    prompt = sample_prompts(corpus, 1, 12, seed=5)[0]

    policies = {
        "none": NoPrefetchPolicy(),
        "next-layer-all": NextLayerAllPolicy(e),
        "moe-infinity": MoEInfinityPolicy(train_traces, n_moe, e, width=6),
        "moe-beyond-online": OnlineMoEBeyondPolicy(pp, pcfg, width=6),
    }
    out = {}
    log("  policy,cache_hit,fetch_MiB,stall_ms,blocking_ms "
        "(engine, capacity 20%, layer_compute 50us)")
    for name, pol in policies.items():
        eng = OffloadEngine(model, params, pol, capacity,
                            layer_compute_s=50e-6)
        eng.generate(prompt, max_new=36, cache_len=64)
        s = eng.stats
        log(f"  {name},{s.hit_rate:.3f},{s.fetch_bytes / 2**20:.1f},"
            f"{s.sim_stall_s * 1e3:.1f},{s.blocking_stall_s * 1e3:.1f}")
        out[f"engine_{name}_hit"] = s.hit_rate
        out[f"engine_{name}_stall_ms"] = s.sim_stall_s * 1e3

    prompts = sample_prompts(corpus, 4, 12, seed=6)
    tp = _throughput(model, params, cfg, prompts, max_new=24, cache_len=64,
                     batch=4, log=log)
    out.update({f"batched_{k}": v for k, v in tp.items()})
    return out


def run_tiny(out_path=None, mixed=False, log=print):
    """CI smoke: briefly-trained reduced backbone, no cached artifacts;
    writes the JSON artifact the workflow uploads. ``mixed`` switches to the
    ragged-length admission-latency / memory-high-water workload."""
    from repro.configs import get_reduced
    from repro.core.policies import NextLayerAllPolicy, NoPrefetchPolicy
    from repro.core.tracing import moe_layer_ids
    from repro.data import make_topic_corpus, sample_prompts
    from repro.launch.train import train
    from repro.models import build_model
    from repro.serving.engine import OffloadEngine

    t0 = time.time()
    arch = "deepseek-v2-lite"
    params, _ = train(arch, reduced=True, steps=30, batch_size=8,
                      seq_len=64, lr=3e-3, log=log)
    cfg = get_reduced(arch)
    model = build_model(cfg)
    corpus = make_topic_corpus(cfg.vocab_size, n_topics=4, seed=0)
    n_moe = len(moe_layer_ids(cfg))
    e = cfg.moe.num_experts

    if mixed:
        prompts = _mixed_workload(cfg, corpus, n_requests=8, seed=11)
        results = _mixed_latency(model, params, cfg, prompts, max_new=8,
                                 cache_len=48, batch=4, log=log)
        results["wall_s"] = time.time() - t0
        log(f"  tiny mixed bench: {json.dumps(results, indent=2)}")
        if out_path:
            os.makedirs(os.path.dirname(os.path.abspath(out_path)),
                        exist_ok=True)
            with open(out_path, "w") as f:
                json.dump(results, f, indent=2)
            log(f"  wrote {out_path}")
        return results

    prompts = sample_prompts(corpus, 4, 8, seed=1)
    results = _throughput(model, params, cfg, prompts, max_new=12,
                          cache_len=32, batch=4, log=log)

    cap = max(model.cfg.moe.top_k * 4 + 1, (n_moe * e) // 4)
    eng = OffloadEngine(model, params, NoPrefetchPolicy(), cap,
                        layer_compute_s=50e-6)
    eng.generate(prompts[0], max_new=12, cache_len=32)
    s = eng.stats
    # prefetch-ahead engine: transfers hide behind modeled compute
    pre = OffloadEngine(model, params, NextLayerAllPolicy(e), cap,
                        layer_compute_s=50e-6)
    pre.generate(prompts[0], max_new=12, cache_len=32)
    results.update({
        "hit_rate_small_cache": s.hit_rate,
        "stall_ms": s.sim_stall_s * 1e3,
        "blocking_stall_ms": s.blocking_stall_s * 1e3,
        "prefetch_hit_rate": pre.stats.hit_rate,
        "prefetch_stall_ms": pre.stats.sim_stall_s * 1e3,
        "prefetch_blocking_stall_ms": pre.stats.blocking_stall_s * 1e3,
        "prefetch_overlapped_ms": pre.stats.overlapped_s * 1e3,
        "wall_s": time.time() - t0,
    })
    log(f"  tiny bench: {json.dumps(results, indent=2)}")
    if out_path:
        os.makedirs(os.path.dirname(os.path.abspath(out_path)),
                    exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
        log(f"  wrote {out_path}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: tiny backbone, no cached artifacts")
    ap.add_argument("--mixed", action="store_true",
                    help="mixed-length workload: admission-to-first-token "
                         "latency + KV memory high-water, paged vs token "
                         "prompt path")
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args()
    if args.tiny or args.mixed:
        run_tiny(args.out, mixed=args.mixed)
    else:
        results = run()
        if args.out:
            os.makedirs(os.path.dirname(os.path.abspath(args.out)),
                        exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=2)


if __name__ == "__main__":
    main()
