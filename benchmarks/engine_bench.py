"""Beyond-paper: the integrated offload serving engine (real decode, real
slot buffer) under each prefetch policy — hit rates + modeled stall — plus
batched-vs-sequential decode throughput for the continuous-batching engine.

CI smoke mode (no cached artifacts, tiny backbone, JSON artifact):
  PYTHONPATH=src python -m benchmarks.engine_bench --tiny \
      --out artifacts/engine_bench.json

Mixed-length workload mode (--mixed): ragged prompts at batch >= 4 through
the paged + chunked-prefill engine vs the token-by-token prompt path —
reports per-request admission-to-first-token latency and the KV memory
high-water (actual blocks allocated vs the contiguous batch x cache_len
model):
  PYTHONPATH=src python -m benchmarks.engine_bench --tiny --mixed \
      --out artifacts/engine_bench_mixed.json

Long-context mode (--longctx): sweeps simulated cache length and times one
batched decode step through the paged pools on the flash-decode kernel
route vs the gather-and-materialise route, reporting per-step latency,
modeled KV bytes read, and the (N, W*block_size, ...) bytes only the gather
route materialises:
  PYTHONPATH=src python -m benchmarks.engine_bench --tiny --longctx \
      --out artifacts/engine_bench_longctx.json

Prefix-sharing mode (--prefix): N requests sharing a >=64-token system
prompt through the paged engine with the prefix cache on vs off — reports
the prefix hit rate, skipped-prefill tokens, per-request TTFT, and the KV
block high-water (streams must stay token-identical; TTFT and high-water
must drop):
  PYTHONPATH=src python -m benchmarks.engine_bench --tiny --prefix \
      --out artifacts/engine_bench_prefix.json

Tiered-store mode (--tiers): the expert set sharded across simulated hosts
with disk spill (serving/expertstore.py) — sweeps shard count x tier-0
capacity reporting per-tier hit rates, the stall-by-tier breakdown, and
tok/s, then pins horizon-aware prefetch against fixed-horizon at equal
tier-0 capacity (streams must stay token-identical to the single-host
engine; horizon-aware must shrink un-overlapped stall). ``--dispatch all``
adds the fetch/ship/auto compute-dispatch comparison (ship the token group
to the expert's shard vs pull its weights) in a cold-expert regime:
  PYTHONPATH=src python -m benchmarks.engine_bench --tiny --tiers \
      --dispatch all --out artifacts/engine_bench_tiers.json

SLO mode (--slo): an open-loop Poisson load sweep (serving/workload.py) of
an interactive class (urgent, tight TTFT SLO) mixed with long batch
requests, served with SLO-aware preemptive scheduling on vs off — reports
p50/p95/p99 TTFT, per-token latency, preemption counts, and goodput under
SLO per arrival rate, and asserts that preemption beats the no-preemption
baseline on p99 TTFT AND goodput at >=1 overload point with every stream
token-identical to an uncontended reference run:
  PYTHONPATH=src python -m benchmarks.engine_bench --tiny --slo \
      --out artifacts/engine_bench_slo.json

Telemetry-trace mode (--trace): the tiered paged engine with the runtime
telemetry layer (serving/telemetry.py) on — per-request span timelines,
copy-channel transfer tracks, and the predictor-quality scoreboard —
pinned token-identical and deterministic-stats-identical against a
telemetry-off twin, written as Chrome trace_event JSON that opens in
ui.perfetto.dev (tools/check_trace.py validates it in CI):
  PYTHONPATH=src python -m benchmarks.engine_bench --tiny --trace \
      --out artifacts/engine_bench_trace.json
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


class _SanitizerSession:
    """--sanitize support: one :class:`~repro.analysis.RetraceGuard` around
    the whole run (per-program XLA compile counts in the artifact) plus a
    :class:`~repro.analysis.LeakSanitizer` auto-installed on every batched
    engine the run constructs, so the pool refcount ledger and the expert
    store's residency ledger are re-proved at every request retire."""

    def __init__(self):
        from repro.analysis import RetraceGuard
        self.guard = RetraceGuard()
        self.sanitizers = []
        self._orig_init = None

    def __enter__(self):
        from repro.analysis import sanitize_engine
        from repro.serving.scheduler import BatchedOffloadEngine
        self.guard.__enter__()
        orig = BatchedOffloadEngine.__init__
        sanitizers = self.sanitizers

        def init_with_sanitizer(eng, *a, **kw):
            orig(eng, *a, **kw)
            san = sanitize_engine(eng)
            if san is not None:
                sanitizers.append(san)

        self._orig_init = orig
        BatchedOffloadEngine.__init__ = init_with_sanitizer
        return self

    def __exit__(self, *exc):
        from repro.serving.scheduler import BatchedOffloadEngine
        if self._orig_init is not None:
            BatchedOffloadEngine.__init__ = self._orig_init
            self._orig_init = None
        for san in self.sanitizers:
            san.uninstall()
        self.guard.__exit__(*exc)

    def report(self) -> dict:
        """The ``"sanitizer"`` artifact section."""
        counts = self.guard.counts()
        return {
            "compiles_by_program": counts,
            "distinct_programs": len(counts),
            "total_compiles": sum(counts.values()),
            "engines_sanitized": len(self.sanitizers),
            "leak_checks": sum(s.checks for s in self.sanitizers),
        }


def _throughput(model, params, cfg, prompts, max_new: int, cache_len: int,
                batch: int, log=print):
    """tokens/s: one batched engine at ``batch`` vs the same requests run
    sequentially through one batch-1 engine. Both are warmed first so jit
    compilation stays out of the timed region."""
    from repro.core.tracing import moe_layer_ids
    from repro.serving.engine import OffloadEngine
    from repro.serving.scheduler import BatchedOffloadEngine

    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts

    seq = OffloadEngine(model, params, None, n_total)
    seq.generate(prompts[0], max_new=2, cache_len=cache_len)      # warm
    tok0 = seq.stats.tokens
    t0 = time.perf_counter()
    for p in prompts:
        seq.generate(p, max_new=max_new, cache_len=cache_len)
    seq_s = time.perf_counter() - t0
    seq_tokens = seq.stats.tokens - tok0

    bat = BatchedOffloadEngine(model, params, None, n_total,
                               max_batch=batch)
    bat.generate(prompts, max_new=2, cache_len=cache_len)         # warm
    tok0 = bat.stats.tokens
    t0 = time.perf_counter()
    bat.generate(prompts, max_new=max_new, cache_len=cache_len)
    bat_s = time.perf_counter() - t0
    bat_tokens = bat.stats.tokens - tok0

    seq_tps = seq_tokens / max(seq_s, 1e-9)
    bat_tps = bat_tokens / max(bat_s, 1e-9)
    log(f"  throughput: sequential {seq_tps:.1f} tok/s, "
        f"batch={batch} {bat_tps:.1f} tok/s "
        f"({bat_tps / max(seq_tps, 1e-9):.2f}x, "
        f"mean batch {bat.stats.mean_batch:.2f})")
    return {"seq_tok_s": seq_tps, "batched_tok_s": bat_tps,
            "speedup": bat_tps / max(seq_tps, 1e-9),
            "mean_batch": bat.stats.mean_batch}


def _mixed_workload(cfg, corpus, n_requests: int, seed: int):
    """Ragged prompt lengths spanning sub-block to multi-block: the shape
    continuous batching actually sees."""
    from repro.data import sample_prompts
    lengths = [4, 28, 8, 36, 6, 20, 32, 12][:n_requests]
    rng_seed = seed
    prompts = []
    for i, ln in enumerate(lengths):
        prompts.append(sample_prompts(corpus, 1, ln, seed=rng_seed + i)[0])
    return prompts


def _mixed_latency(model, params, cfg, prompts, max_new: int, cache_len: int,
                   batch: int, log=print):
    """Admission-to-first-token latency + KV memory high-water: paged engine
    with chunked prefill vs the same engine on the token-by-token prompt
    path (paged=False), same requests, batch >= 4."""
    from repro.core.tracing import moe_layer_ids
    from repro.serving.scheduler import BatchedOffloadEngine

    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts

    def ttft_stats(eng, rid_from):
        # only the timed run's requests: the warm run's first tokens pay
        # jit compilation and would swamp the scheduling signal
        tt = sorted(v for r, v in eng.ttft().items() if r >= rid_from)
        if not tt:
            return {"mean": 0.0, "p50": 0.0, "max": 0.0}
        return {"mean": float(sum(tt) / len(tt)),
                "p50": float(tt[len(tt) // 2]),
                "max": float(tt[-1])}

    tok = BatchedOffloadEngine(model, params, None, n_total,
                               max_batch=batch, paged=False)
    tok.generate(prompts, max_new=2, cache_len=cache_len)            # warm
    rid0 = tok._next_rid
    t0 = time.perf_counter()
    outs_tok = tok.generate(prompts, max_new=max_new, cache_len=cache_len)
    tok_s = time.perf_counter() - t0
    tok_tt = ttft_stats(tok, rid0)

    pag = BatchedOffloadEngine(model, params, None, n_total,
                               max_batch=batch, block_size=8,
                               prefill_chunk=16)
    pag.generate(prompts, max_new=2, cache_len=cache_len)            # warm
    rid0 = pag._next_rid
    chunks0, ptok0 = pag.stats.prefill_chunks, pag.stats.prefill_tokens
    t0 = time.perf_counter()
    outs_pag = pag.generate(prompts, max_new=max_new, cache_len=cache_len)
    pag_s = time.perf_counter() - t0
    pag_tt = ttft_stats(pag, rid0)

    assert outs_pag == outs_tok, "paged/token prompt paths diverged"

    # memory model: actual paged high-water vs contiguous batch x cache_len
    per_tok = pag.kv_block_bytes / pag.block_size
    rows_bytes = int(batch * cache_len * per_tok)
    paged_bytes = pag.kv_high_water_bytes
    log(f"  mixed-length batch={batch}: TTFT mean "
        f"{tok_tt['mean'] * 1e3:.1f}ms token-path vs "
        f"{pag_tt['mean'] * 1e3:.1f}ms paged+chunked "
        f"({tok_tt['mean'] / max(pag_tt['mean'], 1e-9):.2f}x); KV high-water "
        f"{paged_bytes / 2**10:.0f}KiB paged vs {rows_bytes / 2**10:.0f}KiB "
        f"batch*cache_len rows "
        f"({paged_bytes / max(rows_bytes, 1):.2f}x)")
    # prompt tokens each engine streamed token-by-token through decode:
    # ~every prompt body on the token path, none on the chunk-prefill path
    # (ring/recurrent stacks would show up here even with paged=True)
    log(f"  fallback prefill tokens: {tok.stats.fallback_prefill_tokens} "
        f"token-path vs {pag.stats.fallback_prefill_tokens} paged+chunked")
    return {
        "ttft_token_mean_s": tok_tt["mean"],
        "ttft_token_p50_s": tok_tt["p50"],
        "ttft_token_max_s": tok_tt["max"],
        "ttft_paged_mean_s": pag_tt["mean"],
        "ttft_paged_p50_s": pag_tt["p50"],
        "ttft_paged_max_s": pag_tt["max"],
        "ttft_speedup": tok_tt["mean"] / max(pag_tt["mean"], 1e-9),
        "wall_token_s": tok_s,
        "wall_paged_s": pag_s,
        "kv_high_water_bytes": paged_bytes,
        "kv_rows_model_bytes": rows_bytes,
        "kv_high_water_frac": paged_bytes / max(rows_bytes, 1),
        "kv_blocks_high_water": pag.pool.stats.high_water,
        "prefill_chunks": pag.stats.prefill_chunks - chunks0,
        "prefill_tokens": pag.stats.prefill_tokens - ptok0,
        "fallback_prefill_tokens_token_path": tok.stats.fallback_prefill_tokens,
        "fallback_prefill_tokens_paged": pag.stats.fallback_prefill_tokens,
        "streams_identical": True,
    }


def _prefix_workload(cfg, corpus, n_requests: int, sys_len: int,
                     tail_len: int, seed: int):
    """N prompts sharing one ``sys_len``-token system prompt with unique
    ``tail_len``-token user tails — the shape prefix sharing targets."""
    from repro.data import sample_prompts
    system = sample_prompts(corpus, 1, sys_len, seed=seed)[0]
    tails = [sample_prompts(corpus, 1, tail_len, seed=seed + 1 + i)[0]
             for i in range(n_requests)]
    return [list(system) + list(t) for t in tails]


def _prefix_sharing(model, params, cfg, prompts, shared_len: int,
                    max_new: int, cache_len: int, batch: int,
                    block_size: int, log=print):
    """Prefix cache on vs off on a shared-system-prompt workload: streams
    must stay token-identical while TTFT and the KV block high-water drop
    and the hit counters show real skipped prefill."""
    from repro.core.tracing import moe_layer_ids
    from repro.serving.scheduler import BatchedOffloadEngine

    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts

    def ttft_mean(eng, rid_from):
        tt = [v for r, v in eng.ttft().items() if r >= rid_from]
        return float(sum(tt) / len(tt)) if tt else 0.0

    engines = {}
    for name, share in (("off", False), ("on", True)):
        eng = BatchedOffloadEngine(model, params, None, n_total,
                                   max_batch=batch, block_size=block_size,
                                   prefill_chunk=16, prefix_cache=share)
        # warm jit outside the timed region (the warm run's prefix index is
        # per-run state and is rebuilt from scratch by the timed run)
        eng.generate(prompts[:2], max_new=2, cache_len=cache_len)
        engines[name] = eng

    out = {}
    streams = {}
    for name, eng in engines.items():
        rid0 = eng._next_rid
        ptok0 = eng.stats.prefill_tokens
        t0 = time.perf_counter()
        streams[name] = eng.generate(prompts, max_new=max_new,
                                     cache_len=cache_len)
        out[f"wall_{name}_s"] = time.perf_counter() - t0
        out[f"ttft_{name}_mean_s"] = ttft_mean(eng, rid0)
        out[f"kv_blocks_high_water_{name}"] = eng.pool.stats.high_water
        out[f"prefill_tokens_{name}"] = eng.stats.prefill_tokens - ptok0

    assert streams["on"] == streams["off"], \
        "prefix sharing changed a token stream"
    eng = engines["on"]
    st = eng.prefix.stats
    eng.pool.check_leaks(expected_in_use=eng.prefix.cached_blocks)
    out.update({
        "streams_identical": True,
        "n_requests": len(prompts),
        "shared_prefix_tokens": shared_len,
        "prefix_hit_rate": st.hit_rate,
        "prefix_hits": st.hits,
        "prefix_extensions": st.extensions,
        "skipped_prefill_tokens": st.hit_tokens,
        "prefix_cached_blocks": eng.prefix.cached_blocks,
        "cow_copies": eng.pool.stats.cow_copies,
        "ttft_speedup": (out["ttft_off_mean_s"]
                         / max(out["ttft_on_mean_s"], 1e-9)),
        "kv_high_water_frac": (out["kv_blocks_high_water_on"]
                               / max(out["kv_blocks_high_water_off"], 1)),
    })
    log(f"  prefix sharing batch={batch}: hit rate "
        f"{st.hit_rate:.2f} ({st.hits} hits + {st.extensions} boundary "
        f"extensions), {st.hit_tokens} prompt tokens skipped, "
        f"{out['cow_copies']} COW copies")
    log(f"  TTFT mean {out['ttft_off_mean_s'] * 1e3:.1f}ms off -> "
        f"{out['ttft_on_mean_s'] * 1e3:.1f}ms on "
        f"({out['ttft_speedup']:.2f}x); KV high-water "
        f"{out['kv_blocks_high_water_off']} -> "
        f"{out['kv_blocks_high_water_on']} blocks "
        f"({out['kv_high_water_frac']:.2f}x)")
    return out


def _tier_sweep(model, params, cfg, prompts, max_new: int, cache_len: int,
                batch: int, replacement: str = "both",
                cold_dtype: str = "both", dispatch: str = "fetch",
                log=print):
    """Tiered expert store under load: shard count x tier-0 capacity sweep
    (per-tier hit rates, stall-by-tier, tok/s), then horizon-aware vs
    fixed-horizon prefetch at equal tier-0 capacity, then learned-vs-LRU
    replacement and int8-vs-full cold tiers side by side.

    The tier hardware model is scaled to the architecture's own roofline
    (layer_compute_s="roofline" drives the OverlapTracker clock): a tier-2
    fetch costs ~1.2 layers of compute, a tier-3 fetch ~2.5 — so a
    single-layer lookahead cannot hide the slow tiers but a tier-scaled
    horizon can. Every full-precision configuration's streams must be
    token-identical to the single-host engine's; the lossy int8 run
    reports (not asserts) whether its streams matched.

    ``replacement`` in {"lru", "learned", "both"} picks the eviction
    policies swept; ``cold_dtype`` in {"none", "int8", "both"} picks the
    cold-tier storage comparison; ``dispatch`` in {"fetch", "ship",
    "auto", "all"} additionally compares compute-dispatch modes in a
    cold-expert regime (no tier-1 promotion cache, slow interconnect,
    equal tier-0 capacity): ships vs fetches, wire bytes down each path,
    and un-overlapped stall — asserting auto strictly beats fetch-only
    on stall with token-identical streams."""
    from repro.core.policies import NextLayerAllPolicy
    from repro.core.tracing import moe_layer_ids
    from repro.launch.dryrun import decode_layer_roofline
    from repro.serving.expertstore import TierConfig
    from repro.serving.scheduler import BatchedOffloadEngine

    n_moe = len(moe_layer_ids(cfg))
    e = cfg.moe.num_experts
    n_total = n_moe * e
    pol = NextLayerAllPolicy(e)

    # single-host reference: same requests, same policy, one DRAM store
    ref = BatchedOffloadEngine(model, params, pol, n_total, max_batch=batch)
    ref_out = ref.generate(prompts, max_new=max_new, cache_len=cache_len)
    expert_bytes = ref.core.store.bytes_per_expert

    per_layer = decode_layer_roofline(cfg, batch=batch)
    mean_layer = sum(a + f for a, f in per_layer) / len(per_layer)

    def tier_cfg(shards, horizons=(1, 1, 2, 3), cache_experts=None,
                 cold=None):
        # scale the tier hardware model so one MoE layer's *batch* of
        # peer/disk fetches costs ~1.5/~2.2 layers of this arch's roofline
        # compute: a single-layer lookahead cannot hide the slow tiers,
        # a tier-scaled one can
        dram = max(1, n_total // (shards * 4))
        disk_per_layer = max(1, (n_total - shards * dram) // n_moe)
        peer_per_layer = max(1, (shards - 1) * dram // n_moe)
        dur_disk = 2.2 * mean_layer / disk_per_layer
        dur_peer = 1.5 * mean_layer / peer_per_layer
        return TierConfig(
            num_shards=shards,
            shard_dram_experts=dram,
            cache_experts=(max(2, n_total // 6) if cache_experts is None
                           else cache_experts),
            peer_latency_s=0.3 * dur_peer,
            peer_bw=expert_bytes / (0.7 * dur_peer),
            disk_latency_s=0.3 * dur_disk,
            disk_bw=expert_bytes / (0.7 * dur_disk),
            horizons=horizons,
            cold_dtype=cold)

    # local DRAM is an order faster than the interconnect: a full layer's
    # worth of tier-1 refetches costs ~0.4 layers of compute, so a
    # single-layer lookahead hides them (tier-1 duration is modeled by the
    # SlotBuffer's host_bw, not TierConfig)
    host_bw = expert_bytes * e / (0.4 * mean_layer)

    def run_engine(tc, cap, eviction="lru", assert_parity=True):
        eng = BatchedOffloadEngine(model, params, pol, cap,
                                   eviction=eviction, host_bw=host_bw,
                                   max_batch=batch,
                                   layer_compute_s="roofline", tiers=tc)
        t0 = time.perf_counter()
        out = eng.generate(prompts, max_new=max_new, cache_len=cache_len)
        wall = time.perf_counter() - t0
        if assert_parity:
            assert out == ref_out, "tiered store changed a token stream"
        s = eng.stats
        st = eng.core.store.stats
        accesses = max(s.hits + s.misses, 1)
        slow = (s.fetches_by_tier.get(2, 0) + s.fetches_by_tier.get(3, 0))
        fast = s.hits + s.fetches_by_tier.get(1, 0)
        row = {
            "replacement": eviction,
            "tok_s": s.tokens / max(wall, 1e-9),
            "tier0_hit_rate": s.hit_rate,
            # share of expert materialisations served without touching a
            # slow tier: tier-0 slot hits plus tier-1 (local DRAM) fetches
            # over everything including tier-2/3 fetches
            "tier01_hit_rate": fast / max(fast + slow, 1),
            "tier_fetch_rates": {t: n / accesses
                                 for t, n in s.fetches_by_tier.items()},
            "fetches_by_tier": dict(s.fetches_by_tier),
            "fetch_bytes_by_tier": dict(s.fetch_bytes_by_tier),
            "stall_by_tier_ms": {t: v * 1e3
                                 for t, v in s.stall_by_tier.items()},
            "sim_stall_ms": s.sim_stall_s * 1e3,
            "overlapped_ms": s.overlapped_s * 1e3,
            "deep_prefetch_hits": s.deep_prefetch_hits,
            "horizon_clamps": s.horizon_clamps,
            "evictions_learned": s.evictions_learned,
            "evictions_lru": s.evictions_lru,
            "store_evictions_learned": st.cache_evictions_learned,
            "store_evictions_lru": st.cache_evictions_lru,
            "quantized_fetches": st.quantized_fetches,
            "spilled_experts": st.spilled_experts,
            "streams_match_ref": out == ref_out,
        }
        eng.core.store.close()
        return row

    reps = ("lru", "learned") if replacement == "both" else (replacement,)
    min_cap = batch * cfg.moe.top_k
    caps = sorted({max(min_cap, n_total // 3), n_total})
    sweep = []
    log(f"  tiers sweep ({n_total} experts, {e}/layer x {n_moe} layers): "
        "shards,cap,policy,tok/s,tier0_hit,tier01_hit,fetch_t1/t2/t3,"
        "stall_ms(t1/t2/t3)")
    for shards in (1, 4):
        for cap in caps:
            for rep in reps:
                row = {"num_shards": shards, "tier0_capacity": cap}
                row.update(run_engine(tier_cfg(shards), cap, eviction=rep))
                sweep.append(row)
                f = row["fetches_by_tier"]
                st = row["stall_by_tier_ms"]
                log(f"  {shards},{cap},{rep},{row['tok_s']:.1f},"
                    f"{row['tier0_hit_rate']:.3f},"
                    f"{row['tier01_hit_rate']:.3f},"
                    f"{f.get(1, 0)}/{f.get(2, 0)}/{f.get(3, 0)},"
                    f"{st.get(1, 0.0):.2f}/{st.get(2, 0.0):.2f}/"
                    f"{st.get(3, 0.0):.2f}")

    # horizon-aware vs fixed-horizon at equal tier-0 capacity. Compared at
    # the capacity that holds the lookahead window's working set: deeper
    # prefetch trades slot residency time for overlap, so at the bare
    # admission minimum it thrashes instead (visible in the sweep rows) —
    # tier-0 capacity and prefetch horizon are coupled knobs.
    cap = caps[-1]
    fixed = run_engine(tier_cfg(4, horizons=(1, 1, 1, 1)), cap)
    aware = run_engine(tier_cfg(4, horizons=(1, 1, 2, 3)), cap)
    reduction = 1.0 - (aware["sim_stall_ms"]
                       / max(fixed["sim_stall_ms"], 1e-12))
    log(f"  horizon-aware vs fixed (4 shards, cap {cap}): stall "
        f"{fixed['sim_stall_ms']:.2f} -> {aware['sim_stall_ms']:.2f} ms "
        f"({reduction:.1%} less), deep prefetch hits "
        f"{aware['deep_prefetch_hits']}")

    results = {
        "sweep": sweep,
        "streams_identical": True,
        "num_experts_total": n_total,
        "expert_bytes": expert_bytes,
        "mean_layer_roofline_s": mean_layer,
        "horizon_fixed": fixed,
        "horizon_aware": aware,
        "horizon_stall_reduction": reduction,
        "batch": batch,
        "replacement_axis": list(reps),
        "cold_dtype_axis": cold_dtype,
    }

    # learned vs LRU replacement at equal capacity, with a tier-1 cache
    # sized where retention matters (half the expert set): the scorer
    # keeps the copies predicted soonest-reused where LRU cycles them out
    if len(reps) == 2:
        cmp_cap = max(min_cap, n_total // 3)
        cmp_tc = lambda: tier_cfg(4, cache_experts=n_total // 2)  # noqa: E731
        cmp = {rep: run_engine(cmp_tc(), cmp_cap, eviction=rep)
               for rep in reps}
        hit_gain = (cmp["learned"]["tier01_hit_rate"]
                    - cmp["lru"]["tier01_hit_rate"])
        stall_red = 1.0 - (cmp["learned"]["sim_stall_ms"]
                           / max(cmp["lru"]["sim_stall_ms"], 1e-12))
        log(f"  learned vs lru (4 shards, cap {cmp_cap}, cache "
            f"{n_total // 2}): tier0+1 hit "
            f"{cmp['lru']['tier01_hit_rate']:.3f} -> "
            f"{cmp['learned']['tier01_hit_rate']:.3f} (+{hit_gain:.3f}), "
            f"stall {cmp['lru']['sim_stall_ms']:.2f} -> "
            f"{cmp['learned']['sim_stall_ms']:.2f} ms "
            f"({stall_red:.1%} less)")
        results["replacement_comparison"] = {
            "tier0_capacity": cmp_cap,
            "cache_experts": n_total // 2,
            "lru": cmp["lru"],
            "learned": cmp["learned"],
            "tier01_hit_gain": hit_gain,
            "stall_reduction": stall_red,
        }

    # int8 cold tiers vs full precision: same config, same requests —
    # tier-2/3 fetch bytes shrink by the quantization ratio. Lossy, so
    # stream parity is reported, not asserted.
    if cold_dtype in ("int8", "both"):
        full = run_engine(tier_cfg(4), cap)
        cold = run_engine(tier_cfg(4, cold=("int8")), cap,
                          assert_parity=False)
        b_full = sum(full["fetch_bytes_by_tier"].get(t, 0) for t in (2, 3))
        b_cold = sum(cold["fetch_bytes_by_tier"].get(t, 0) for t in (2, 3))
        ratio = b_full / max(b_cold, 1)
        log(f"  int8 cold tiers (4 shards, cap {cap}): tier-2/3 fetch "
            f"bytes {b_full / 2**20:.2f} -> {b_cold / 2**20:.2f} MiB "
            f"({ratio:.2f}x smaller), quantized fetches "
            f"{cold['quantized_fetches']}, streams match: "
            f"{cold['streams_match_ref']}")
        results["cold_comparison"] = {
            "tier0_capacity": cap,
            "full": full,
            "int8": cold,
            "cold_fetch_bytes_t23": b_cold,
            "full_fetch_bytes_t23": b_full,
            "cold_fetch_bytes_ratio_t23": ratio,
            "cold_streams_match": cold["streams_match_ref"],
        }

    # fetch vs ship vs auto compute dispatch in a cold-expert regime: no
    # tier-1 promotion cache, tier-0 sized to the bare demand window, and
    # an interconnect where one peer weight pull costs ~1.2 layers of
    # compute — every peer expert is a fresh per-(expert, token-count)
    # decision between pulling its weights and shipping its token group.
    if dispatch != "fetch":
        modes = (("fetch", "ship", "auto") if dispatch == "all"
                 else ("fetch", dispatch))
        dcap = min_cap
        dur_peer = 1.2 * mean_layer
        disp = {}
        log(f"  dispatch comparison (4 shards, cap {dcap}, cold peers): "
            "mode,tok_s,ships,fetches,ship_wire_KiB,fetch_wire_MiB,"
            "stall_ms")
        for mode in modes:
            tc = TierConfig(num_shards=4, cache_experts=0,
                            peer_latency_s=0.3 * dur_peer,
                            peer_bw=expert_bytes / (0.7 * dur_peer),
                            dispatch=mode)
            eng = BatchedOffloadEngine(model, params, pol, dcap,
                                       host_bw=host_bw, max_batch=batch,
                                       layer_compute_s="roofline",
                                       tiers=tc)
            t0 = time.perf_counter()
            out = eng.generate(prompts, max_new=max_new,
                               cache_len=cache_len)
            wall = time.perf_counter() - t0
            assert out == ref_out, \
                f"dispatch={mode} changed a token stream"
            s = eng.stats
            row = dict(eng.dispatch_summary())
            row.update({
                "tok_s": s.tokens / max(wall, 1e-9),
                "sim_stall_ms": s.sim_stall_s * 1e3,
                "stall_by_tier_ms": {t: v * 1e3
                                     for t, v in s.stall_by_tier.items()},
                "streams_match_ref": True,
            })
            disp[mode] = row
            eng.core.store.close()
            log(f"  {mode},{row['tok_s']:.1f},{row['ships']},"
                f"{row['fetches']},{row['ship_wire_bytes'] / 2**10:.1f},"
                f"{row['fetch_wire_bytes'] / 2**20:.2f},"
                f"{row['sim_stall_ms']:.2f}")
        for mode in modes[1:]:
            assert disp[mode]["ships"] > 0, f"{mode} mode never shipped"
        if "auto" in disp:
            # the acceptance: at equal tier-0 capacity, pricing fetch vs
            # ship per (expert, token-count) strictly cuts un-overlapped
            # stall vs always pulling weights
            assert (disp["auto"]["sim_stall_ms"]
                    < disp["fetch"]["sim_stall_ms"]), \
                "auto dispatch did not reduce stall vs fetch-only"
            red = 1.0 - (disp["auto"]["sim_stall_ms"]
                         / max(disp["fetch"]["sim_stall_ms"], 1e-12))
            log(f"  auto vs fetch-only: stall "
                f"{disp['fetch']['sim_stall_ms']:.2f} -> "
                f"{disp['auto']['sim_stall_ms']:.2f} ms ({red:.1%} less)")
            results["dispatch_stall_reduction"] = red
        results["dispatch_comparison"] = {
            "tier0_capacity": dcap,
            "modes": list(modes),
            "streams_identical": True,
            **disp,
        }
    return results


def _slo_sweep(model, params, cfg, n_requests: int, load_factors,
               log=print):
    """Open-loop Poisson load sweep with SLO-aware preemption on vs off.

    Two priority classes share the engine: "interactive" (urgent: short
    prompts, tight TTFT SLO measured in decode-program times so the budget
    tracks the machine) and "batch" (long prompts + long decode, no SLO).
    At each arrival rate the SAME workload is replayed through a FIFO
    engine and a preemptive engine; both must produce streams
    token-identical to an uncontended closed-loop reference. At >=1
    overload point the preemptive engine must beat FIFO on the urgent
    class's p99 TTFT AND on goodput-under-SLO — the acceptance this mode
    pins in CI."""
    from repro.core.metrics import latency_stats
    from repro.core.tracing import moe_layer_ids
    from repro.serving.config import ServeConfig
    from repro.serving.scheduler import BatchedOffloadEngine
    from repro.serving.workload import (SLO, PriorityClass, poisson_workload,
                                        scale_rate)

    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    cache_len, batch, bs = 96, 2, 8

    def build(preempt):
        sc = ServeConfig(max_batch=batch, block_size=bs, prefill_chunk=8,
                         prefix_cache=True, preemption=preempt)
        return BatchedOffloadEngine(model, params, None, n_total, serve=sc)

    engines = {"fifo": build(False), "preempt": build(True)}
    for eng in engines.values():
        # warm every bucket the sweep will hit — batch-class long prefill,
        # interactive-class short prefill, 1- and 2-lane decode, and the
        # 1/2/4-wide prefill tails a preemption resume can produce — so
        # compile time never lands in a measured run
        eng.generate([list(range(1, 33)), [3, 5, 7, 9, 2, 4]], max_new=24,
                     cache_len=cache_len)
        eng.generate([[7, 2], [9, 4, 1]], max_new=2, cache_len=cache_len)
        eng.generate([[8, 3, 6, 5, 2]], max_new=2, cache_len=cache_len)

    # program time on the warmed engine sets the SLO budgets and the
    # capacity estimate, so the sweep adapts to the machine
    eng = engines["fifo"]
    p0 = eng.stats.steps + eng.stats.prefill_chunks
    t0 = time.perf_counter()
    eng.generate([list(range(1, 33))], max_new=24, cache_len=cache_len)
    progs = eng.stats.steps + eng.stats.prefill_chunks - p0
    prog_s = (time.perf_counter() - t0) / max(progs, 1)

    inter = PriorityClass("interactive", priority=0, weight=0.35,
                          prompt_len=6, max_new=4,
                          slo=SLO(ttft_s=10 * prog_s))
    batch_cls = PriorityClass("batch", priority=2, weight=0.65,
                              prompt_len=32, max_new=64, slo=None)
    # programs per request: ceil(prompt/chunk) prefill + max_new+1 decode
    progs_per_req = 0.35 * (1 + 5) + 0.65 * (4 + 65)
    capacity_rps = batch / (progs_per_req * prog_s)
    base = poisson_workload(n_requests, capacity_rps, (inter, batch_cls),
                            vocab_size=cfg.vocab_size, seed=7)
    n_inter = sum(1 for w in base if w.priority == 0)
    assert 0 < n_inter < len(base), "degenerate class mix: change the seed"

    # uncontended closed-loop reference streams (parity target)
    ref_eng = BatchedOffloadEngine(model, params, None, n_total,
                                   max_batch=4)
    ref_eng.generate([[3, 5, 7]], max_new=2, cache_len=cache_len)  # warm
    rids = [ref_eng.submit(w.prompt, w.max_new, w.temperature, w.seed)
            for w in base]
    ref_res = ref_eng.run(cache_len)
    ref_streams = [ref_res[r] for r in rids]

    log(f"  slo sweep: {len(base)} requests ({n_inter} interactive), "
        f"prog {prog_s * 1e3:.1f}ms, capacity ~{capacity_rps:.2f} rps, "
        f"interactive TTFT SLO {10 * prog_s * 1e3:.0f}ms")
    log("  load_x,mode,interactive p50/p95/p99 TTFT ms,goodput rps,"
        "slo_attain,preempts")
    sweep = []
    for factor in load_factors:
        wl = scale_rate(base, factor)
        row = {"load_x": factor, "rate_rps": capacity_rps * factor}
        for name, eng in engines.items():
            pre0 = eng.stats.preemptions
            res = eng.run_workload(wl, cache_len)
            streams = [res[r] for r in sorted(res)]
            assert streams == ref_streams, (
                f"{name} streams diverged at load {factor}x")
            lat = eng.stats.latency
            pre = eng.stats.preemptions - pre0
            d = lat.as_dict()
            d["preemptions"] = pre
            # per-class views: feed record subsets back through the
            # same summariser
            recs = eng.records().values()
            inter_lat = latency_stats(
                (r for r in recs if r.priority == 0), lat.elapsed_s)
            d["interactive"] = inter_lat.as_dict()
            row[name] = d
            log(f"  {factor:.1f},{name},"
                f"{inter_lat.ttft_p50_s * 1e3:.0f}/"
                f"{inter_lat.ttft_p95_s * 1e3:.0f}/"
                f"{inter_lat.ttft_p99_s * 1e3:.0f},"
                f"{lat.goodput_rps:.2f},{lat.slo_attainment:.2f},{pre}")
        row["streams_identical"] = True
        # the comparison axis is the SLO-bearing class: preemption spends
        # best-effort batch TTFT to protect urgent TTFT, so overall p99
        # measures the wrong thing
        row["preempt_beats_fifo"] = bool(
            row["preempt"]["interactive"]["ttft_p99_s"]
            < row["fifo"]["interactive"]["ttft_p99_s"]
            and row["preempt"]["goodput_rps"] >= row["fifo"]["goodput_rps"])
        sweep.append(row)

    # the acceptance: at >=1 overload point, preemption wins on BOTH the
    # urgent class's p99 TTFT and goodput-under-SLO, and really preempted
    wins = [r for r in sweep
            if r["load_x"] > 1.0 and r["preempt_beats_fifo"]
            and r["preempt"]["preemptions"] > 0]
    assert wins, "preemption never beat FIFO at an overload point"
    log(f"  preemption wins at load {[r['load_x'] for r in wins]}x "
        "(lower p99 TTFT, no worse goodput, preemptions > 0)")
    return {
        "sweep": sweep,
        "streams_identical": True,
        "prog_s": prog_s,
        "capacity_rps_est": capacity_rps,
        "slo_ttft_s": 10 * prog_s,
        "n_requests": len(base),
        "n_interactive": n_inter,
        "win_load_x": [r["load_x"] for r in wins],
        "batch": batch,
    }


def _run_slo(n_requests, load_factors, out_path=None, log=print):
    """Build the untrained reduced backbone (scheduling + stream parity
    only — prediction quality is the policy benches' job), run the SLO
    load sweep, write the artifact."""
    import jax

    from repro.configs import get_reduced
    from repro.models import build_model

    t0 = time.time()
    cfg = get_reduced("deepseek-v2-lite")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    results = _slo_sweep(model, params, cfg, n_requests=n_requests,
                         load_factors=load_factors, log=log)
    results["wall_s"] = time.time() - t0
    if out_path:
        os.makedirs(os.path.dirname(os.path.abspath(out_path)),
                    exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
        log(f"  wrote {out_path}")
    return results


def _longctx_sweep(model, params, cfg, lengths, batch: int, block_size: int,
                   iters: int, log=print):
    """Per-step decode latency vs cache length: paged flash-decode kernel
    route vs the gather-and-materialise route, same paged pools, same
    tables. ``step_s_*`` is the whole decode step (all layers + the MoE
    host loop — includes an O(cache) pool-copy both routes pay off-TPU,
    where XLA can't donate the cache buffers); ``attn_s_*`` times one paged
    attention layer's jitted program, the read path this comparison is
    about. Bytes are modeled from the cache shapes: both routes read every
    live page; only the gather route also materialises (and re-reads) the
    contiguous (N, W*block_size, ...) per-lane copy. (Off-TPU the kernel
    route is the lax.scan twin, whose live tile is capped at
    ``JNP_TILE_BLOCKS`` blocks — equal to the full copy while the table
    fits one tile, constant past it; ``materialized_bytes_kernel = 0``
    models the Pallas kernel the TPU route compiles.)"""
    import jax.numpy as jnp

    from repro.core.tracing import moe_layer_ids
    from repro.models import transformer as T
    from repro.serving.engine import DecodeCore
    from repro.serving.kvpool import blocks_for

    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    cores = {
        "kernel": DecodeCore(model, params, n_total, max_batch=batch,
                             kernel="auto"),
        "gather": DecodeCore(model, params, n_total, max_batch=batch,
                             kernel=None),
    }
    li = next(i for i, k in enumerate(cfg.layer_kinds())
              if k in T.PAGED_KINDS)
    kind = cfg.layer_kinds()[li]
    rng = np.random.default_rng(0)
    rows = []
    log(f"  longctx batch={batch} block_size={block_size}: cache_len,"
        "step_ms_kernel,step_ms_gather,attn_ms_kernel,attn_ms_gather,"
        "read_MiB,gather_materialized_MiB (kernel route materialises 0)")
    for cache_len in lengths:
        w = blocks_for(cache_len, block_size)
        num_blocks = batch * w + 1
        tables = np.stack([1 + i * w + np.arange(w) for i in range(batch)]
                          ).astype(np.int32)
        pos = [cache_len - 1] * batch
        toks = [1] * batch
        lanes = list(range(batch))
        row = {"cache_len": cache_len}
        route_caches = {}
        for name, core in cores.items():
            route_caches[name] = core.alloc_paged_caches(num_blocks,
                                                         block_size)
            block_bytes = core.paged_block_bytes(route_caches[name])
            core.step(route_caches[name], lanes, pos, toks, None, lanes,
                      tables=tables)                              # warm/jit
        # interleave routes so machine drift hits both equally
        acc = {name: 0.0 for name in cores}
        for _ in range(iters):
            for name, core in cores.items():
                t0 = time.perf_counter()
                core.step(route_caches[name], lanes, pos, toks, None, lanes,
                          tables=tables)
                acc[name] += time.perf_counter() - t0
        for name in cores:
            row[f"step_s_{name}"] = acc[name] / iters

        # isolate the read path: one paged layer's jitted attention program
        x = jnp.asarray(rng.normal(size=(batch, 1, cfg.d_model)),
                        jnp.dtype(cfg.dtype))
        tab_j = jnp.asarray(tables)
        pos_j = jnp.full((batch,), cache_len - 1, jnp.int32)
        attn_iters = 4 * iters
        for name, core in cores.items():
            lp = core.layers[li]
            cache = route_caches[name][li]
            core._paged_attn(lp, x, cache, tab_j, pos_j, kind=kind,
                             kernel=core.kernel)[0].block_until_ready()
        acc = {name: 0.0 for name in cores}
        for _ in range(attn_iters):
            for name, core in cores.items():
                lp = core.layers[li]
                cache = route_caches[name][li]
                t0 = time.perf_counter()
                core._paged_attn(lp, x, cache, tab_j, pos_j, kind=kind,
                                 kernel=core.kernel)[0].block_until_ready()
                acc[name] += time.perf_counter() - t0
        for name in cores:
            row[f"attn_s_{name}"] = acc[name] / attn_iters

        kv_read = batch * w * block_bytes
        row["kv_read_bytes"] = kv_read
        row["materialized_bytes_gather"] = kv_read
        row["materialized_bytes_kernel"] = 0
        rows.append(row)
        log(f"  {cache_len},{row['step_s_kernel'] * 1e3:.1f},"
            f"{row['step_s_gather'] * 1e3:.1f},"
            f"{row['attn_s_kernel'] * 1e3:.2f},"
            f"{row['attn_s_gather'] * 1e3:.2f},"
            f"{kv_read / 2**20:.1f},{kv_read / 2**20:.1f}")
    # growth of per-step attention-read time with cache length
    dl = max(rows[-1]["cache_len"] - rows[0]["cache_len"], 1)
    slopes = {name: (rows[-1][f"attn_s_{name}"] - rows[0][f"attn_s_{name}"])
              / dl for name in cores}
    log(f"  attn-read growth: kernel {slopes['kernel'] * 1e6:.3f}us/pos, "
        f"gather {slopes['gather'] * 1e6:.3f}us/pos "
        f"({slopes['gather'] / max(slopes['kernel'], 1e-12):.2f}x)")
    return {"rows": rows, "slope_s_per_pos_kernel": slopes["kernel"],
            "slope_s_per_pos_gather": slopes["gather"],
            "kernel_routes": {n: c.kernel for n, c in cores.items()},
            "batch": batch, "block_size": block_size}


def _run_tiers(out_path=None, replacement="both", cold_dtype="both",
               dispatch="fetch", log=print):
    """Build the untrained reduced backbone (stream parity + modeled stall
    only — prediction quality is the policy benches' job), run the tier
    sweep, write the artifact."""
    import jax

    from repro.configs import get_reduced
    from repro.data import make_topic_corpus, sample_prompts
    from repro.models import build_model

    t0 = time.time()
    cfg = get_reduced("deepseek-v2-lite")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    corpus = make_topic_corpus(cfg.vocab_size, n_topics=4, seed=0)
    prompts = sample_prompts(corpus, 6, 8, seed=2)
    results = _tier_sweep(model, params, cfg, prompts, max_new=6,
                          cache_len=32, batch=4, replacement=replacement,
                          cold_dtype=cold_dtype, dispatch=dispatch,
                          log=log)
    results["wall_s"] = time.time() - t0
    if out_path:
        os.makedirs(os.path.dirname(os.path.abspath(out_path)),
                    exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
        log(f"  wrote {out_path}")
    return results


def _run_trace(out_path=None, log=print):
    """Telemetry-trace mode: the tiered paged engine with the runtime
    telemetry layer on (``src/repro/serving/telemetry.py``), pinned
    against a telemetry-off twin, writing a Chrome-trace artifact.

    Three runs of the same shared-prefix workload through the paged
    engine with a 4-shard tiered expert store (so at least two copy
    channels carry traffic): a single-host token-stream reference, a
    telemetry-off tiered run, and a telemetry-on tiered run. Asserts the
    zero-overhead contract — telemetry on/off produce token-identical
    streams and identical deterministic engine stats (everything except
    the wall-clock ``latency`` summary) — then writes the on-run's
    ``Telemetry.to_chrome_trace()`` JSON with the predictor
    ``scoreboard`` section riding in the same file (Perfetto ignores
    unknown top-level keys). ``tools/check_trace.py`` validates the
    artifact in CI."""
    import jax

    from repro.configs import get_reduced
    from repro.core.policies import NextLayerAllPolicy
    from repro.core.tracing import moe_layer_ids
    from repro.launch.dryrun import decode_layer_roofline
    from repro.data import make_topic_corpus
    from repro.models import build_model
    from repro.serving.config import ServeConfig
    from repro.serving.expertstore import TierConfig
    from repro.serving.scheduler import BatchedOffloadEngine
    from repro.serving.telemetry import Telemetry

    t0 = time.time()
    cfg = get_reduced("deepseek-v2-lite")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    corpus = make_topic_corpus(cfg.vocab_size, n_topics=4, seed=0)
    # shared 16-token system prefix -> the prefix cache has adoptions
    prompts = _prefix_workload(cfg, corpus, n_requests=6, sys_len=16,
                               tail_len=6, seed=7)

    n_moe = len(moe_layer_ids(cfg))
    e = cfg.moe.num_experts
    n_total = n_moe * e
    batch, max_new, cache_len = 4, 6, 48
    pol = NextLayerAllPolicy(e)
    cap = max(batch * cfg.moe.top_k, n_total // 3)

    def build(tel, tiers=None, host_bw=100e9):
        serve = ServeConfig(max_batch=batch, block_size=8,
                            prefix_cache=True,
                            layer_compute_s="roofline" if tiers else 0.0,
                            tiers=tiers, telemetry=tel)
        return BatchedOffloadEngine(model, params, pol, cap,
                                    host_bw=host_bw, serve=serve)

    # single-host reference: the tiered runs must not change a token
    ref = build(None)
    ref_out = ref.generate(prompts, max_new=max_new, cache_len=cache_len)
    expert_bytes = ref.core.store.bytes_per_expert

    # tier hardware model scaled to this arch's roofline, as in the
    # --tiers sweep: slow-tier fetches cost layers of compute, so the
    # channel tracks carry visible transfer spans
    per_layer = decode_layer_roofline(cfg, batch=batch)
    mean_layer = sum(a + f for a, f in per_layer) / len(per_layer)
    shards = 4
    dram = max(1, n_total // (shards * 4))
    disk_per_layer = max(1, (n_total - shards * dram) // n_moe)
    peer_per_layer = max(1, (shards - 1) * dram // n_moe)
    dur_disk = 2.2 * mean_layer / disk_per_layer
    dur_peer = 1.5 * mean_layer / peer_per_layer
    tc = TierConfig(num_shards=shards, shard_dram_experts=dram,
                    cache_experts=max(2, n_total // 6),
                    peer_latency_s=0.3 * dur_peer,
                    peer_bw=expert_bytes / (0.7 * dur_peer),
                    disk_latency_s=0.3 * dur_disk,
                    disk_bw=expert_bytes / (0.7 * dur_disk),
                    horizons=(1, 1, 2, 3))
    host_bw = expert_bytes * e / (0.4 * mean_layer)

    def det_stats(eng):
        d = eng.stats.as_dict()
        d.pop("latency")          # wall-clock, legitimately differs
        return d

    off = build(None, tiers=tc, host_bw=host_bw)
    off_out = off.generate(prompts, max_new=max_new, cache_len=cache_len)
    off.core.store.close()

    tel = Telemetry()
    on = build(tel, tiers=tc, host_bw=host_bw)
    on_out = on.generate(prompts, max_new=max_new, cache_len=cache_len)
    on.core.store.close()

    assert on_out == off_out == ref_out, \
        "telemetry (or the tiered store) changed a token stream"
    assert det_stats(on) == det_stats(off), \
        "telemetry changed the engine's deterministic stats"

    trace = tel.to_chrome_trace()
    trace["scoreboard"] = tel.scoreboard(bucket_s=0.25)
    trace["wall_s"] = time.time() - t0

    evs = trace["traceEvents"]
    req_tracks = sum(1 for ev in evs if ev.get("ph") == "M"
                     and ev.get("name") == "thread_name"
                     and ev.get("pid") == 1)
    chan_tracks = sum(1 for ev in evs if ev.get("ph") == "M"
                      and ev.get("name") == "thread_name"
                      and ev.get("pid") == 2)
    total = trace["scoreboard"]["total"]
    log(f"  trace: {len(evs)} events, {req_tracks} request tracks, "
        f"{chan_tracks} channel tracks, "
        f"{len(trace['scoreboard']['windows'])} scoreboard windows")
    log(f"  predictor: precision={total['precision']:.3f} "
        f"recall={total['recall']:.3f} f1={total['f1']:.3f} "
        f"tier01_hit_rate={total['t01_hit_rate']:.3f}")
    log("  on/off parity: token streams identical, deterministic stats "
        "identical")
    if out_path:
        os.makedirs(os.path.dirname(os.path.abspath(out_path)),
                    exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(trace, f, indent=2)
        log(f"  wrote {out_path} (open in ui.perfetto.dev)")
    return trace


def _run_longctx(lengths, iters, out_path=None, log=print):
    """Build the untrained reduced backbone (attention timing only — parity
    is the tests' job), run the sweep, write the artifact."""
    import jax

    from repro.configs import get_reduced
    from repro.models import build_model

    t0 = time.time()
    cfg = get_reduced("deepseek-v2-lite")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    results = _longctx_sweep(model, params, cfg, lengths=lengths, batch=4,
                             block_size=16, iters=iters, log=log)
    results["wall_s"] = time.time() - t0
    if out_path:
        os.makedirs(os.path.dirname(os.path.abspath(out_path)),
                    exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
        log(f"  wrote {out_path}")
    return results


def run(log=print):
    from benchmarks.common import trained_predictor
    from repro.core.policies import (MoEInfinityPolicy, NextLayerAllPolicy,
                                     NoPrefetchPolicy, OnlineMoEBeyondPolicy)
    from repro.core.tracing import moe_layer_ids
    from repro.data import make_topic_corpus, sample_prompts
    from repro.serving.engine import OffloadEngine

    pcfg, pp, hist, bundle = trained_predictor(log=log)
    cfg, model, params, train_traces, _ = bundle
    n_moe = len(moe_layer_ids(cfg))
    e = cfg.moe.num_experts
    capacity = max(1, int(0.2 * n_moe * e))

    corpus = make_topic_corpus(cfg.vocab_size, n_topics=8, seed=3)
    prompt = sample_prompts(corpus, 1, 12, seed=5)[0]

    policies = {
        "none": NoPrefetchPolicy(),
        "next-layer-all": NextLayerAllPolicy(e),
        "moe-infinity": MoEInfinityPolicy(train_traces, n_moe, e, width=6),
        "moe-beyond-online": OnlineMoEBeyondPolicy(pp, pcfg, width=6),
    }
    out = {}
    log("  policy,cache_hit,fetch_MiB,stall_ms,blocking_ms "
        "(engine, capacity 20%, layer_compute 50us)")
    for name, pol in policies.items():
        eng = OffloadEngine(model, params, pol, capacity,
                            layer_compute_s=50e-6)
        eng.generate(prompt, max_new=36, cache_len=64)
        s = eng.stats
        log(f"  {name},{s.hit_rate:.3f},{s.fetch_bytes / 2**20:.1f},"
            f"{s.sim_stall_s * 1e3:.1f},{s.blocking_stall_s * 1e3:.1f}")
        out[f"engine_{name}_hit"] = s.hit_rate
        out[f"engine_{name}_stall_ms"] = s.sim_stall_s * 1e3

    prompts = sample_prompts(corpus, 4, 12, seed=6)
    tp = _throughput(model, params, cfg, prompts, max_new=24, cache_len=64,
                     batch=4, log=log)
    out.update({f"batched_{k}": v for k, v in tp.items()})
    return out


def run_tiny(out_path=None, mixed=False, longctx=False, prefix=False,
             tiers=False, slo=False, trace=False, replacement="both",
             cold_dtype="both", dispatch="fetch", sanitize=False,
             log=print):
    """CI smoke: briefly-trained reduced backbone, no cached artifacts;
    writes the JSON artifact the workflow uploads. ``mixed`` switches to the
    ragged-length admission-latency / memory-high-water workload;
    ``longctx`` to the cache-length sweep (kernel vs gather read path —
    untrained weights, attention timing only); ``prefix`` to the
    shared-system-prompt workload (prefix cache on vs off); ``tiers`` to
    the tiered expert-store sweep (untrained weights — stream parity and
    modeled stall); ``slo`` to the open-loop SLO load sweep (untrained
    weights — preemptive vs FIFO scheduling under Poisson traffic);
    ``trace`` to the telemetry-trace mode (untrained weights — Chrome
    trace + predictor scoreboard artifact, telemetry on/off parity
    asserted); ``sanitize`` wraps any of the above in the retrace/leak
    sanitizer layer and adds a ``"sanitizer"`` section to the
    artifact."""
    from repro.configs import get_reduced
    from repro.core.policies import NextLayerAllPolicy, NoPrefetchPolicy
    from repro.core.tracing import moe_layer_ids
    from repro.data import make_topic_corpus, sample_prompts
    from repro.launch.train import train
    from repro.models import build_model
    from repro.serving.engine import OffloadEngine

    if sanitize:
        with _SanitizerSession() as ses:
            results = run_tiny(out_path=None, mixed=mixed, longctx=longctx,
                               prefix=prefix, tiers=tiers, slo=slo,
                               trace=trace, replacement=replacement,
                               cold_dtype=cold_dtype, dispatch=dispatch,
                               sanitize=False, log=log)
        # zero observed compile events would mean the hook is dead and the
        # compile counts vacuous — fail the bench rather than report them
        ses.guard.self_check()
        results["sanitizer"] = ses.report()
        log(f"  sanitizer: {json.dumps(results['sanitizer'], indent=2)}")
        if out_path:
            os.makedirs(os.path.dirname(os.path.abspath(out_path)),
                        exist_ok=True)
            with open(out_path, "w") as f:
                json.dump(results, f, indent=2)
            log(f"  wrote {out_path}")
        return results

    t0 = time.time()
    arch = "deepseek-v2-lite"
    if longctx:
        return _run_longctx(lengths=(1024, 2048, 4096, 8192), iters=5,
                            out_path=out_path, log=log)
    if tiers:
        return _run_tiers(out_path=out_path, replacement=replacement,
                          cold_dtype=cold_dtype, dispatch=dispatch,
                          log=log)
    if slo:
        return _run_slo(n_requests=16, load_factors=(0.4, 1.5, 4.0),
                        out_path=out_path, log=log)
    if trace:
        return _run_trace(out_path=out_path, log=log)
    params, _ = train(arch, reduced=True, steps=30, batch_size=8,
                      seq_len=64, lr=3e-3, log=log)
    cfg = get_reduced(arch)
    model = build_model(cfg)
    corpus = make_topic_corpus(cfg.vocab_size, n_topics=4, seed=0)
    n_moe = len(moe_layer_ids(cfg))
    e = cfg.moe.num_experts

    if mixed or prefix:
        if prefix:
            sys_len = 64
            prompts = _prefix_workload(cfg, corpus, n_requests=8,
                                       sys_len=sys_len, tail_len=8, seed=13)
            results = _prefix_sharing(model, params, cfg, prompts,
                                      shared_len=sys_len, max_new=8,
                                      cache_len=96, batch=4, block_size=8,
                                      log=log)
        else:
            prompts = _mixed_workload(cfg, corpus, n_requests=8, seed=11)
            results = _mixed_latency(model, params, cfg, prompts, max_new=8,
                                     cache_len=48, batch=4, log=log)
        results["wall_s"] = time.time() - t0
        mode = "prefix" if prefix else "mixed"
        log(f"  tiny {mode} bench: {json.dumps(results, indent=2)}")
        if out_path:
            os.makedirs(os.path.dirname(os.path.abspath(out_path)),
                        exist_ok=True)
            with open(out_path, "w") as f:
                json.dump(results, f, indent=2)
            log(f"  wrote {out_path}")
        return results

    prompts = sample_prompts(corpus, 4, 8, seed=1)
    results = _throughput(model, params, cfg, prompts, max_new=12,
                          cache_len=32, batch=4, log=log)

    cap = max(model.cfg.moe.top_k * 4 + 1, (n_moe * e) // 4)
    eng = OffloadEngine(model, params, NoPrefetchPolicy(), cap,
                        layer_compute_s=50e-6)
    eng.generate(prompts[0], max_new=12, cache_len=32)
    s = eng.stats
    # prefetch-ahead engine: transfers hide behind modeled compute
    pre = OffloadEngine(model, params, NextLayerAllPolicy(e), cap,
                        layer_compute_s=50e-6)
    pre.generate(prompts[0], max_new=12, cache_len=32)
    results.update({
        "hit_rate_small_cache": s.hit_rate,
        "stall_ms": s.sim_stall_s * 1e3,
        "blocking_stall_ms": s.blocking_stall_s * 1e3,
        "prefetch_hit_rate": pre.stats.hit_rate,
        "prefetch_stall_ms": pre.stats.sim_stall_s * 1e3,
        "prefetch_blocking_stall_ms": pre.stats.blocking_stall_s * 1e3,
        "prefetch_overlapped_ms": pre.stats.overlapped_s * 1e3,
        "wall_s": time.time() - t0,
    })
    log(f"  tiny bench: {json.dumps(results, indent=2)}")
    if out_path:
        os.makedirs(os.path.dirname(os.path.abspath(out_path)),
                    exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
        log(f"  wrote {out_path}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: tiny backbone, no cached artifacts")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--mixed", action="store_true",
                      help="mixed-length workload: admission-to-first-token "
                           "latency + KV memory high-water, paged vs token "
                           "prompt path")
    mode.add_argument("--longctx", action="store_true",
                      help="cache-length sweep: per-step decode latency + "
                           "bytes read, paged flash-decode kernel vs gather")
    mode.add_argument("--prefix", action="store_true",
                      help="shared-system-prompt workload: prefix cache on "
                           "vs off — hit rate, skipped prefill, TTFT, KV "
                           "high-water")
    mode.add_argument("--tiers", action="store_true",
                      help="tiered expert store: shard count x tier-0 "
                           "capacity sweep (per-tier hit rates, "
                           "stall-by-tier, tok/s) + horizon-aware vs "
                           "fixed-horizon prefetch")
    mode.add_argument("--slo", action="store_true",
                      help="open-loop Poisson load sweep: preemptive vs "
                           "FIFO scheduling — p50/p95/p99 TTFT, "
                           "goodput-under-SLO, preemption counts, with "
                           "streams pinned to an uncontended reference")
    mode.add_argument("--trace", action="store_true",
                      help="telemetry trace: tiered paged engine with the "
                           "runtime telemetry layer on — Chrome-trace "
                           "artifact (open in ui.perfetto.dev) with the "
                           "predictor scoreboard, on/off parity asserted")
    ap.add_argument("--replacement", choices=("lru", "learned", "both"),
                    default="both",
                    help="--tiers only: eviction policies to sweep "
                         "(learned = predictor-driven reuse-distance "
                         "replacement)")
    ap.add_argument("--cold-dtype", choices=("none", "int8", "both"),
                    default="both",
                    help="--tiers only: cold-tier (peer/disk) storage "
                         "dtype comparison; int8 halves fetch bytes but "
                         "is lossy")
    ap.add_argument("--dispatch", choices=("fetch", "ship", "auto", "all"),
                    default="fetch",
                    help="--tiers only: compute-dispatch modes to compare "
                         "in a cold-expert regime (ship = send the token "
                         "group to the expert's shard instead of pulling "
                         "its weights; auto = roofline-priced per "
                         "(expert, token-count))")
    ap.add_argument("--sanitize", action="store_true",
                    help="tiny modes: wrap the run in the retrace/leak "
                         "sanitizer layer — per-program XLA compile counts "
                         "in the artifact plus a pool/residency ledger "
                         "check at every request retire")
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args()
    if args.longctx and not args.tiny:
        _run_longctx(lengths=(1024, 4096, 8192, 16384, 32768), iters=3,
                     out_path=args.out)
    elif args.slo and not args.tiny:
        _run_slo(n_requests=40, load_factors=(0.4, 1.0, 1.5, 2.5, 4.0),
                 out_path=args.out)
    elif (args.tiny or args.mixed or args.prefix or args.tiers or args.slo
          or args.trace):
        run_tiny(args.out, mixed=args.mixed, longctx=args.longctx,
                 prefix=args.prefix, tiers=args.tiers, slo=args.slo,
                 trace=args.trace, replacement=args.replacement,
                 cold_dtype=args.cold_dtype, dispatch=args.dispatch,
                 sanitize=args.sanitize)
    else:
        results = run()
        if args.out:
            os.makedirs(os.path.dirname(os.path.abspath(args.out)),
                        exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=2)


if __name__ == "__main__":
    main()
