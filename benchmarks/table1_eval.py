"""Paper Table 1: predictor accuracy + macro F1 on held-out test prompts
(our WebGLM-QA stand-in). Reports both accuracy readings (DESIGN.md §10)."""
from __future__ import annotations

import numpy as np


def run(log=print):
    import jax
    import jax.numpy as jnp

    from benchmarks.common import trained_predictor
    from repro.core import metrics as M
    from repro.core.predictor import predictor_apply

    pcfg, pp, hist, bundle = trained_predictor(log=log)
    cfg, model, params, train_traces, test_traces = bundle

    apply = jax.jit(lambda e, l, m: predictor_apply(pp, pcfg, e, l, m))
    preds, trues = [], []
    for tr in test_traces:
        t = min(tr.num_tokens, pcfg.max_seq)
        emb = jnp.asarray(tr.embeddings[None, :t])
        mask = jnp.ones((1, t), bool)
        for layer in range(tr.experts.shape[1]):
            logits = np.asarray(apply(emb, jnp.full((1, t), layer, jnp.int32),
                                      mask))[0]
            sel = M.select_experts(logits, pcfg.top_k, pcfg.threshold)
            hot = np.zeros((t, pcfg.num_experts), bool)
            for tok in range(t):
                hot[tok, tr.experts[tok, layer]] = True
            preds.append(sel)
            trues.append(hot)
    pred = np.concatenate(preds)
    true = np.concatenate(trues)
    out = {
        "table1_accuracy_elementwise": M.elementwise_accuracy(pred, true),
        "table1_accuracy_exact_set": M.exact_set_accuracy(pred, true),
        "table1_macro_f1": M.macro_f1(pred, true),
    }
    log(f"  paper Table 1 reference: accuracy 97.55%, macro-F1 86.18% "
        f"(DeepSeek-V2-Lite @ 66M traces)")
    for k, v in out.items():
        log(f"  {k} = {v:.4f}")
    return out
