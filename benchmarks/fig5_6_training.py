"""Paper Figs 5-6: predictor training/validation dynamics (accuracy, F1,
loss per epoch)."""
from __future__ import annotations


def run(log=print):
    from benchmarks.common import trained_predictor
    pcfg, pp, hist, bundle = trained_predictor(log=log)
    log("  epoch,train_loss,train_acc,train_f1,val_loss,val_acc,val_f1")
    for i in range(len(hist.train_loss)):
        log(f"  {i},{hist.train_loss[i]:.4f},{hist.train_acc[i]:.4f},"
            f"{hist.train_f1[i]:.4f},{hist.val_loss[i]:.4f},"
            f"{hist.val_acc[i]:.4f},{hist.val_f1[i]:.4f}")
    out = {
        "fig5_final_train_acc": hist.train_acc[-1],
        "fig5_final_train_f1": hist.train_f1[-1],
        "fig5_final_train_loss": hist.train_loss[-1],
        "fig6_final_val_acc": hist.val_acc[-1],
        "fig6_final_val_f1": hist.val_f1[-1],
        "fig6_final_val_loss": hist.val_loss[-1],
        "fig6_train_val_f1_gap": abs(hist.train_f1[-1] - hist.val_f1[-1]),
    }
    for k, v in out.items():
        log(f"  {k} = {v:.4f}")
    return out
