"""§Roofline: per (arch x shape) three-term roofline table, read from the
dry-run artifacts (dryrun_single_pod.json / dryrun_multi_pod.json)."""
from __future__ import annotations

import json
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(path):
    p = os.path.join(REPO, path)
    if not os.path.exists(p):
        return None
    return json.load(open(p))


def run(log=print):
    out = {}
    for tag, path in (("1pod", "dryrun_single_pod.json"),
                      ("2pod", "dryrun_multi_pod.json")):
        rs = load(path)
        if rs is None:
            log(f"  [{tag}] missing {path}; run: PYTHONPATH=src python -m "
                f"repro.launch.dryrun --all --json {path}"
                + (" --multi-pod" if tag == "2pod" else ""))
            continue
        log(f"  [{tag}] arch,shape,compute_s,memory_s,collective_s,"
            f"dominant,useful_ratio,peak_GiB_per_dev")
        for r in rs:
            if r["status"] != "ok":
                log(f"  [{tag}] {r['arch']},{r['shape']},{r['status']}"
                    f"({r.get('reason', '')})")
                continue
            t = r["terms_s"]
            peak = r["bytes_per_device"]["peak"] / 2 ** 30
            log(f"  [{tag}] {r['arch']},{r['shape']},{t['compute_s']:.4g},"
                f"{t['memory_s']:.4g},{t['collective_s']:.4g},"
                f"{r['dominant'].replace('_s', '')},"
                f"{r['useful_ratio']:.3f},{peak:.2f}")
            out[f"{tag}_{r['arch']}_{r['shape']}_dominant"] = r["dominant"]
        n_ok = sum(1 for r in rs if r["status"] == "ok")
        n_skip = sum(1 for r in rs if r["status"] == "skip")
        n_fail = len(rs) - n_ok - n_skip
        out[f"{tag}_ok"] = n_ok
        log(f"  [{tag}] {n_ok} ok / {n_skip} skip / {n_fail} fail")
    return out
