"""Paper Figs 1-3: expert-activation sparsity — aggregate-uniform vs
single-prompt-skewed, and layer-wise reuse."""
from __future__ import annotations

import numpy as np

from benchmarks.common import backbone_and_traces
from repro.core.eam import build_ream
from repro.core.tracing import moe_layer_ids


def run(log=print):
    cfg, _, _, train_traces, test_traces = backbone_and_traces(log=log)
    traces = train_traces
    n_moe = len(moe_layer_ids(cfg))
    e = cfg.moe.num_experts

    agg = np.zeros((n_moe, e))
    per_prompt_cov = []
    per_prompt_gini = []
    for tr in traces:
        r = build_ream(tr, n_moe, e)
        agg += r
        per_prompt_cov.append((r > 0).mean())
        p = r.sum(0) / max(r.sum(), 1)
        sp = np.sort(p)
        n = len(sp)
        gini = (2 * np.arange(1, n + 1) - n - 1) @ sp / max(n * sp.sum(),
                                                            1e-9)
        per_prompt_gini.append(gini)

    # Fig 1: aggregate layer-0 distribution (uniformity)
    l0 = agg[min(1, n_moe - 1)]
    cv_agg = float(l0.std() / max(l0.mean(), 1e-9))
    # Fig 2: single-prompt sparsity
    cov_single = float(np.mean(per_prompt_cov))
    cov_agg = float((agg > 0).mean())
    # Fig 3: layer-wise reuse — fraction of consecutive-token expert overlap
    overlaps = []
    for tr in traces:
        ex = tr.experts
        for li in range(n_moe):
            a = ex[:-1, li]
            b = ex[1:, li]
            inter = [len(set(x) & set(y)) / len(set(x) | set(y))
                     for x, y in zip(a, b)]
            overlaps.append(np.mean(inter))
    reuse = float(np.mean(overlaps))

    rows = [
        ("fig1_aggregate_layer_cv", cv_agg,
         "coeff-of-variation of aggregate activations (low = uniform, "
         "paper: 800-1400 band)"),
        ("fig2_single_prompt_coverage", cov_single,
         "mean fraction of (layer,expert) pairs active within ONE prompt"),
        ("fig1_aggregate_coverage", cov_agg,
         "fraction active across ALL prompts (paper: ~1.0)"),
        ("fig2_sparsity_gap", cov_agg - cov_single,
         "aggregate minus single-prompt coverage (>0 = request locality)"),
        ("fig2_mean_gini", float(np.mean(per_prompt_gini)),
         "per-prompt expert-mass Gini (higher = more skewed)"),
        ("fig3_consecutive_token_reuse", reuse,
         "mean Jaccard overlap of expert sets for consecutive tokens"),
    ]
    for name, val, desc in rows:
        log(f"  {name} = {val:.4f}   # {desc}")
    return {name: val for name, val, _ in rows}
