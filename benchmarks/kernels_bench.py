"""Kernel microbenchmarks: jnp oracle vs Pallas-interpret correctness and
call latency (CPU timings are regression signals, not TPU predictions)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, n=20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def run(log=print):
    rng = np.random.default_rng(0)
    out = {}

    # router gating at DeepSeek-V2 scale
    logits = jnp.asarray(rng.normal(size=(1024, 160)), jnp.float32)
    us_ref = _time(lambda x: ref.topk_gating_ref(x, 6), logits)
    us_pal = _time(lambda x: ops.topk_gating(x, 6, backend="pallas"), logits)
    wr, ir = ref.topk_gating_ref(logits, 6)
    wp, ip = ops.topk_gating(logits, 6, backend="pallas")
    np.testing.assert_allclose(np.sort(wr), np.sort(wp), rtol=1e-4, atol=1e-6)
    out["topk_gating_ref_us"] = us_ref
    out["topk_gating_pallas_interp_us"] = us_pal

    # batch-1 decode expert FFN at Lite scale
    k, d, f = 6, 2048, 1408
    x = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    w = jnp.asarray(rng.random(k), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(k, d, f)) * 0.02, jnp.float32)
    wu = jnp.asarray(rng.normal(size=(k, d, f)) * 0.02, jnp.float32)
    wd = jnp.asarray(rng.normal(size=(k, f, d)) * 0.02, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ref.expert_ffn_ref(x, w, wg, wu, wd)),
        np.asarray(ops.expert_ffn(x, w, wg, wu, wd, backend="pallas")),
        rtol=2e-3, atol=2e-4)
    out["expert_ffn_ref_us"] = _time(
        lambda *a: ref.expert_ffn_ref(*a), x, w, wg, wu, wd)
    out["expert_ffn_pallas_interp_us"] = _time(
        lambda *a: ops.expert_ffn(*a, backend="pallas"), x, w, wg, wu, wd)

    # flash decode at 32k cache
    s, kvh, g, hd = 32768, 8, 4, 128
    q = jnp.asarray(rng.normal(size=(kvh * g, hd)), jnp.bfloat16)
    kk = jnp.asarray(rng.normal(size=(s, kvh, hd)), jnp.bfloat16)
    vv = jnp.asarray(rng.normal(size=(s, kvh, hd)), jnp.bfloat16)
    np.testing.assert_allclose(
        np.asarray(ref.flash_decode_ref(q, kk, vv, s), np.float32),
        np.asarray(ops.flash_decode(q, kk, vv, s, backend="pallas"),
                   np.float32), rtol=3e-2, atol=3e-2)
    out["flash_decode_ref_us"] = _time(
        lambda *a: ref.flash_decode_ref(*a), q, kk, vv, s)
    out["flash_decode_pallas_interp_us"] = _time(
        lambda *a: ops.flash_decode(*a, backend="pallas"), q, kk, vv, s)

    for kname, v in out.items():
        log(f"  {kname} = {v:.1f}us")
    return out
