"""Paper Fig 7: GPU-cache hit rate vs expert capacity, all policies.

Paper reference points (DeepSeek-V2-Lite, 100 WebGLM-QA prompts):
at 10% capacity MoE-Beyond 72% vs MoE-Infinity 17%; +10-25pp elsewhere."""
from __future__ import annotations

import numpy as np

FRACTIONS = [0.05, 0.1, 0.2, 0.3, 0.5, 0.7, 0.9, 1.0]


def run(log=print):
    from benchmarks.common import trained_predictor
    from repro.core.policies import (GlobalFrequencyPolicy, MoEBeyondPolicy,
                                     MoEInfinityPolicy, NextLayerAllPolicy,
                                     NoPrefetchPolicy, OraclePolicy,
                                     RandomPolicy)
    from repro.core.simulator import SimConfig, sweep_capacity
    from repro.core.tracing import moe_layer_ids

    pcfg, pp, hist, bundle = trained_predictor(log=log)
    cfg, model, params, train_traces, test_traces = bundle
    n_moe = len(moe_layer_ids(cfg))
    e = cfg.moe.num_experts
    k = cfg.moe.top_k
    d, f = cfg.d_model, cfg.moe.d_ff_expert
    sim = SimConfig(num_layers=n_moe, num_experts=e, warm_tokens=8,
                    expert_bytes=2 * 3 * d * f)

    factories = {
        "lru-on-demand": lambda: NoPrefetchPolicy(),
        "random": lambda: RandomPolicy(e, k),
        "next-layer-all": lambda: NextLayerAllPolicy(e),
        "global-frequency": lambda: GlobalFrequencyPolicy(
            train_traces, n_moe, e, width=k),
        "moe-infinity": lambda: MoEInfinityPolicy(train_traces, n_moe, e,
                                                  width=k),
        "moe-beyond": lambda: MoEBeyondPolicy(pp, pcfg),
        "oracle": lambda: OraclePolicy(),
    }
    out = {}
    log("  policy,capacity_frac,cache_hit,pred_hit,stall_ms_per_token")
    for name, fac in factories.items():
        rs = sweep_capacity(test_traces, fac, sim, FRACTIONS)
        for r in rs:
            log("  " + r.row())
            out[f"fig7_{name}_@{r.capacity_fraction:g}"] = r.cache_hit_rate
    # headline numbers (the paper's 10% point)
    log(f"  paper reference @0.1: moe-beyond 0.72 vs moe-infinity 0.17")
    return out
