"""Benchmark harness: one module per paper table/figure + framework benches.
Prints ``name,us_per_call,derived`` CSV rows; artifacts cached in artifacts/.

  PYTHONPATH=src python -m benchmarks.run [--only fig7_cache_hit] [--fresh]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = [
    ("fig1_3_sparsity", "benchmarks.fig1_3_sparsity",
     "paper Figs 1-3: activation sparsity"),
    ("fig5_6_training", "benchmarks.fig5_6_training",
     "paper Figs 5-6: predictor training dynamics"),
    ("table1_eval", "benchmarks.table1_eval",
     "paper Table 1: predictor accuracy/F1"),
    ("fig7_cache_hit", "benchmarks.fig7_cache_hit",
     "paper Fig 7: cache hit rate vs capacity"),
    ("engine_bench", "benchmarks.engine_bench",
     "beyond-paper: integrated offload engine"),
    ("horizon_bench", "benchmarks.horizon_bench",
     "beyond-paper: multi-layer prediction horizon"),
    ("kernels_bench", "benchmarks.kernels_bench",
     "Pallas kernels vs oracles"),
    ("roofline", "benchmarks.roofline",
     "dry-run roofline table (reads dryrun_*.json)"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--fresh", action="store_true",
                    help="ignore cached artifacts")
    args = ap.parse_args()
    picked = set(args.only.split(",")) if args.only else None

    if args.fresh:
        import shutil

        from benchmarks.common import ART
        shutil.rmtree(ART, ignore_errors=True)

    all_rows = []
    failures = []
    for name, module, desc in SUITES:
        if picked and name not in picked:
            continue
        print(f"\n=== {name}: {desc} ===")
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["run"])
            results = mod.run(log=print) or {}
            dt = (time.time() - t0) * 1e6
            for key, val in results.items():
                all_rows.append(
                    f"{name}.{key},{dt / max(len(results), 1):.0f},{val}")
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures.append(name)

    print("\n=== CSV (name,us_per_call,derived) ===")
    for row in all_rows:
        print(row)
    if failures:
        print(f"\nFAILED suites: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
