"""Beyond-paper: multi-layer prediction horizon (the paper's §5/§6 stated
future work — its predictor sees only ONE layer ahead, so DMA can overlap
only one layer's compute).

We train the same predictor with horizon H=2 (two sigmoid blocks: experts
of layer l and layer l+1 from the same context) and measure how much
look-ahead quality degrades with depth — the number that decides whether a
deeper prefetch pipeline is worth it.
"""
from __future__ import annotations

import numpy as np


def run(log=print):
    import dataclasses

    import jax
    import jax.numpy as jnp

    from benchmarks.common import backbone_and_traces, predictor_cfg
    from repro.core import metrics as M
    from repro.core.predictor import predictor_apply
    from repro.core.predictor_train import train_predictor
    from repro.core.tracing import moe_layer_ids

    cfg, model, params, train_traces, test_traces = backbone_and_traces(
        log=log)
    n_moe = len(moe_layer_ids(cfg))
    pcfg = dataclasses.replace(predictor_cfg(cfg, n_moe), horizon=2)

    log("[horizon] training horizon-2 predictor...")
    pp, hist = train_predictor(train_traces, test_traces, pcfg, epochs=12,
                               batch_size=4, base_lr=3e-3, patience=4,
                               log=log)

    apply = jax.jit(lambda e, l, m: predictor_apply(pp, pcfg, e, l, m))
    e_dim = pcfg.num_experts
    hits = {0: [0, 0], 1: [0, 0]}
    for tr in test_traces:
        t = min(tr.num_tokens, pcfg.max_seq)
        emb = jnp.asarray(tr.embeddings[None, :t])
        mask = jnp.ones((1, t), bool)
        for layer in range(n_moe):
            logits = np.asarray(apply(
                emb, jnp.full((1, t), layer, jnp.int32), mask))[0]
            for h in range(pcfg.horizon):
                ll = layer + h
                if ll >= n_moe:
                    continue
                sel = M.select_experts(
                    logits[:, h * e_dim:(h + 1) * e_dim], pcfg.top_k, -1e9)
                for tok in range(t):
                    gt = set(tr.experts[tok, ll].tolist())
                    pred = set(np.nonzero(sel[tok])[0].tolist())
                    hits[h][0] += len(gt & pred)
                    hits[h][1] += len(gt)
    out = {}
    for h in range(pcfg.horizon):
        ph = hits[h][0] / max(hits[h][1], 1)
        out[f"horizon_slot{h}_pred_hit"] = ph
        log(f"  pred-hit @ +{h + 1} layer look-ahead: {ph:.4f}")
    out["horizon_degradation"] = (out["horizon_slot0_pred_hit"]
                                  - out["horizon_slot1_pred_hit"])
    log(f"  degradation per extra layer of look-ahead: "
        f"{out['horizon_degradation']:.4f} "
        f"(small => deeper prefetch pipelines are viable)")
    return out
