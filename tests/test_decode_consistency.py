"""Decode-path correctness: token-by-token decode (and prefill+decode) must
reproduce the full-sequence forward logits for every attention/recurrence
variant — this is the test that catches cache/mask/rope bugs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model

from helpers import make_batch

# cover every block kind: global GQA, local ring, chunked ring, MLA absorbed
# decode, RG-LRU, SSD, enc-dec cross-attention
CASES = ["yi-6b", "gemma3-27b", "llama4-scout-17b-a16e", "deepseek-v2-lite",
         "recurrentgemma-9b", "mamba2-130m", "seamless-m4t-large-v2"]


def _no_drop(cfg):
    """Full-vs-decode equivalence requires drop-free routing: the dispatch
    einsum drops tokens past expert capacity in full mode (correct MoE
    semantics) while batch-1 decode never drops."""
    if cfg.moe is None:
        return cfg
    import dataclasses
    moe = dataclasses.replace(cfg.moe,
                              capacity_factor=float(cfg.moe.num_experts))
    return cfg.replace(moe=moe)


@pytest.mark.parametrize("arch", CASES)
def test_decode_matches_full_forward(arch):
    cfg = _no_drop(get_reduced(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    t = 80   # crosses the reduced local window (32) and llama4 chunk (64)
    batch = make_batch(cfg, batch=1, seq=t, seed=3)

    full_logits = np.asarray(model.forward(params, batch))  # (1, T(+px), V)

    state = model.init_decode_state(1, t + 1)
    if cfg.encdec is not None:
        # encoder memory comes from prefill; decode continues after 1 token
        first = {k: (v[:, :1] if k == "tokens" else v)
                 for k, v in batch.items()}
        logit0, state = model.prefill(params, first, cache_len=t + 1)
        np.testing.assert_allclose(np.asarray(logit0), full_logits[:, 0],
                                   rtol=2e-4, atol=2e-4)
        start = 1
    else:
        start = 0

    toks = np.asarray(batch["tokens"])
    n_prefix = cfg.frontend_len if cfg.frontend == "vision" else 0
    if n_prefix:
        pytest.skip("vision prefix exercised in test_prefill_then_decode")
    step_fn = jax.jit(model.decode_step)
    for i in range(start, t):
        step = {"tokens": jnp.asarray(toks[:, i: i + 1])}
        logits, state = step_fn(params, state, step)
        np.testing.assert_allclose(
            np.asarray(logits), full_logits[:, i], rtol=2e-4, atol=2e-4,
            err_msg=f"{arch} step {i}")


def test_prefill_then_decode_vlm():
    """pixtral: prefill consumes patches + prompt, decode continues; logits
    must match the full fused-sequence forward."""
    cfg = get_reduced("pixtral-12b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    t = 48
    batch = make_batch(cfg, batch=1, seq=t, seed=3)
    full_logits = np.asarray(model.forward(params, batch))
    n_prefix = cfg.frontend_len

    cache_len = n_prefix + t + 1
    t0 = 40
    pre = {"tokens": batch["tokens"][:, :t0], "patches": batch["patches"]}
    last, state = model.prefill(params, pre, cache_len=cache_len)
    np.testing.assert_allclose(np.asarray(last),
                               full_logits[:, n_prefix + t0 - 1],
                               rtol=2e-4, atol=2e-4)
    toks = np.asarray(batch["tokens"])
    step_fn = jax.jit(model.decode_step)
    for i in range(t0, t):
        step = {"tokens": jnp.asarray(toks[:, i: i + 1])}
        logits, state = step_fn(params, state, step)
        np.testing.assert_allclose(np.asarray(logits),
                                   full_logits[:, n_prefix + i],
                                   rtol=2e-4, atol=2e-4, err_msg=f"step {i}")


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-130m",
                                  "recurrentgemma-9b"])
def test_prefill_then_decode(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    t = 64
    batch = make_batch(cfg, batch=2, seq=t, seed=5)
    full_logits = np.asarray(model.forward(params, batch))

    t0 = 48
    pre = {"tokens": batch["tokens"][:, :t0]}
    last, state = model.prefill(params, pre, cache_len=t + 1)
    np.testing.assert_allclose(np.asarray(last), full_logits[:, t0 - 1],
                               rtol=3e-4, atol=3e-4)
    toks = np.asarray(batch["tokens"])
    step_fn = jax.jit(model.decode_step)
    for i in range(t0, t):
        step = {"tokens": jnp.asarray(toks[:, i: i + 1])}
        logits, state = step_fn(params, state, step)
        np.testing.assert_allclose(np.asarray(logits), full_logits[:, i],
                                   rtol=3e-4, atol=3e-4,
                                   err_msg=f"{arch} step {i}")
