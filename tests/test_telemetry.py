"""Runtime telemetry layer: span balance, metric registration, the
zero-overhead-off contract (token streams and deterministic engine stats
pinned identical with telemetry on vs off), the predictor scoreboard's
exact aggregation, and Chrome-trace export validated by the same checker
CI runs (``tools/check_trace.py``)."""
import os
import sys

import numpy as np
import pytest

from repro.core.metrics import (f1_over_window, prediction_hit_rate,
                                prf_from_counts)
from repro.core.policies import NextLayerAllPolicy
from repro.core.tracing import moe_layer_ids
from repro.serving.config import ServeConfig
from repro.serving.scheduler import BatchedOffloadEngine
from repro.serving.telemetry import (METRICS, NULL_TELEMETRY, PID_ENGINE,
                                     PID_REQUESTS, Telemetry)

from helpers import tiny_backbone

TOOLS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tools")


def _check_trace():
    sys.path.insert(0, TOOLS)
    try:
        import check_trace
    finally:
        sys.path.remove(TOOLS)
    return check_trace


# ---------------------------------------------------------------------------
# unit: spans, counters, series, off-mode
# ---------------------------------------------------------------------------

def test_span_nesting_balanced():
    tel = Telemetry()
    with tel.span(PID_ENGINE, 1, "outer"):
        with tel.span(PID_ENGINE, 1, "inner"):
            tel.instant(PID_ENGINE, 1, "tick")
    spans = tel.spans()
    names = [s.name for s in spans]
    assert names == ["outer", "inner"]  # sorted by start time
    outer, inner = spans
    assert outer.t0_s <= inner.t0_s and inner.t1_s <= outer.t1_s


def test_unbalanced_end_raises():
    tel = Telemetry()
    tel.begin(PID_ENGINE, 1, "a")
    with pytest.raises(ValueError, match="unbalanced"):
        tel.end(PID_ENGINE, 1, "b")
    tel.end(PID_ENGINE, 1, "a")  # correct close still works


def test_counters_series_and_histograms():
    tel = Telemetry()
    tel.counter("cache.hit", 2, t=0.1)
    tel.counter("cache.hit", 3, t=0.9)
    tel.counter("cache.hit", 5, t=1.1)
    assert tel.total("cache.hit") == 10
    pts = tel.series("cache.hit", 1.0)
    assert [(p.t_s, p.total, p.count) for p in pts] == [(0.0, 5, 2),
                                                        (1.0, 5, 1)]
    tel.gauge("kv.blocks_in_use", 7, t=0.2)
    tel.gauge("kv.blocks_in_use", 4, t=0.3)
    assert tel.total("kv.blocks_in_use") == 4  # last write wins
    for v in (1.0, 2.0, 3.0, 4.0):
        tel.histogram("step.wall_s", v, t=0.1)
    (h,) = tel.hist("step.wall_s")
    assert h["count"] == 4 and h["max"] == 4.0 and h["mean"] == 2.5


def test_unregistered_metric_raises():
    tel = Telemetry()
    with pytest.raises(ValueError, match="unregistered"):
        tel.counter("cache.hitz")
    assert "cache.hit" in METRICS  # the near-miss the typo was after


def test_off_mode_records_nothing_and_reuses_null_span():
    tel = Telemetry(enabled=False)
    s1, s2 = tel.span(1, 1, "a"), tel.span(2, 2, "b")
    assert s1 is s2  # shared null CM: no per-call allocation
    with s1:
        pass
    tel.counter("definitely.not.registered")  # no validation when off
    tel.begin(1, 1, "x")
    tel.end(1, 1, "mismatch-would-raise-when-on")
    tel.instant(1, 1, "i")
    tel.complete(1, 1, "c", 0.0, 1.0)
    assert tel.events() == [] and tel.spans() == []
    assert NULL_TELEMETRY.enabled is False


# ---------------------------------------------------------------------------
# f1_over_window vs the paper-era batch metrics (satellite pin)
# ---------------------------------------------------------------------------

def test_f1_over_window_matches_batch_metrics():
    rng = np.random.default_rng(0)
    predicted = [rng.choice(16, size=rng.integers(1, 8), replace=False)
                 for _ in range(20)]
    actual = [rng.choice(16, size=rng.integers(1, 8), replace=False)
              for _ in range(20)]
    w = f1_over_window(predicted, actual)
    # recall over routed experts IS the paper's prediction hit rate;
    # precision is the same quantity with the roles swapped
    assert w.recall == pytest.approx(prediction_hit_rate(predicted, actual))
    assert w.precision == pytest.approx(
        prediction_hit_rate(actual, predicted))
    # micro-F1 over the equivalent binary membership arrays
    pb = np.zeros((20, 16), bool)
    ab = np.zeros((20, 16), bool)
    for i in range(20):
        pb[i, predicted[i]] = True
        ab[i, actual[i]] = True
    tp = int((pb & ab).sum())
    fp = int((pb & ~ab).sum())
    fn = int((~pb & ab).sum())
    assert (w.tp, w.fp, w.fn) == (tp, fp, fn)
    assert w.f1 == pytest.approx(2 * tp / max(2 * tp + fp + fn, 1))
    assert (w.precision, w.recall, w.f1) == prf_from_counts(tp, fp, fn)


# ---------------------------------------------------------------------------
# engine integration: on/off parity, scoreboard, chrome export
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def backbone():
    return tiny_backbone()


PROMPTS = [[3, 17, 5, 9, 12, 7], [99, 255, 7, 42, 11, 4], [13, 5, 8, 21],
           [21, 8, 9, 77]]
MAX_NEW = 5
CACHE_LEN = 24


def _run(backbone, tel):
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    serve = ServeConfig(max_batch=2, block_size=4, prefix_cache=True,
                        telemetry=tel)
    pol = NextLayerAllPolicy(cfg.moe.num_experts)
    eng = BatchedOffloadEngine(model, params, pol,
                               max(cfg.moe.top_k * 2, n_total // 3),
                               serve=serve)
    out = eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    return eng, out


@pytest.fixture(scope="module")
def on_off(backbone):
    tel = Telemetry()
    eng_on, out_on = _run(backbone, tel)
    eng_off, out_off = _run(backbone, None)
    return tel, eng_on, out_on, eng_off, out_off


def test_streams_and_stats_identical_on_off(on_off):
    """The zero-overhead contract: telemetry must be purely passive."""
    tel, eng_on, out_on, eng_off, out_off = on_off
    assert out_on == out_off
    d_on, d_off = eng_on.stats.as_dict(), eng_off.stats.as_dict()
    d_on.pop("latency"), d_off.pop("latency")  # wall-clock, may differ
    assert d_on == d_off
    assert len(tel.events()) > 0
    assert eng_off.tel is NULL_TELEMETRY and not eng_off.tel.events()


def test_request_lifecycle_spans(on_off):
    """Every admitted request gets a track with queued + request spans,
    decode step events, and a retire instant."""
    tel = on_off[0]
    spans = tel.spans()
    req_spans = [s for s in spans if s.pid == PID_REQUESTS]
    tids = {s.tid for s in req_spans}
    assert len(tids) == len(PROMPTS)  # one track per request
    for tid in tids:
        names = [s.name for s in req_spans if s.tid == tid]
        assert "request" in names and "queued" in names
        assert any(n == "decode" for n in names)
    retires = [e for e in tel.events() if e["name"] == "retire"]
    assert len(retires) == len(PROMPTS)
    # engine track carries decode_step completes and prefetch instants
    eng_names = {s.name for s in spans if s.pid == PID_ENGINE}
    assert "decode_step" in eng_names
    assert tel.total("sched.admitted") == len(PROMPTS)
    assert tel.total("sched.retired") == len(PROMPTS)


def test_scoreboard_matches_offline_recompute(on_off):
    """Per-window rows aggregate exactly to the run-level F1, and both
    match a recompute from the raw recorded series."""
    tel = on_off[0]
    sb = tel.scoreboard(bucket_s=0.05)
    assert sb["windows"], "engine run recorded no predictor windows"
    for key in ("tp", "fp", "fn", "t01_hits", "t01_misses"):
        assert sum(w[key] for w in sb["windows"]) == \
            pytest.approx(sb["total"][key])
    tp = sum(v for _, v in tel._points["predictor.tp"])
    fp = sum(v for _, v in tel._points["predictor.fp"])
    fn = sum(v for _, v in tel._points["predictor.fn"])
    assert (sb["total"]["tp"], sb["total"]["fp"], sb["total"]["fn"]) == \
        (tp, fp, fn)
    p, r, f1 = prf_from_counts(tp, fp, fn)
    assert sb["total"]["f1"] == pytest.approx(f1)
    assert sb["total"]["precision"] == pytest.approx(p)
    assert sb["total"]["recall"] == pytest.approx(r)
    for w in sb["windows"]:
        assert w["f1"] == pytest.approx(
            prf_from_counts(w["tp"], w["fp"], w["fn"])[2])
    # counter totals mirror the EngineStats the run already pins
    eng_on = on_off[1]
    assert tel.total("cache.hit") == eng_on.stats.hits
    assert tel.total("cache.miss") == eng_on.stats.misses


def test_chrome_trace_roundtrips_through_validator(on_off):
    tel = on_off[0]
    doc = tel.to_chrome_trace()
    doc["scoreboard"] = tel.scoreboard(bucket_s=0.05)
    ct = _check_trace()
    assert ct.check_artifact(doc, min_request_tracks=len(PROMPTS)) == []
    names = ct.track_names(doc["traceEvents"])
    assert "requests" in names and "engine" in names
    assert len(names["requests"]) == len(PROMPTS)


def test_validator_catches_broken_traces():
    ct = _check_trace()
    tel = Telemetry()
    with tel.span(PID_ENGINE, 1, "ok"):
        pass
    good = tel.to_chrome_trace()
    assert ct.check_artifact(good) == []
    # unbalanced: drop the E event
    bad = {"traceEvents": [e for e in good["traceEvents"]
                           if e["ph"] != "E"]}
    assert any("never closed" in p for p in ct.check_artifact(bad))
    # non-monotonic ts on one track
    ooo = {"traceEvents": list(good["traceEvents"]) + [
        {"name": "late", "ph": "i", "pid": PID_ENGINE, "tid": 1,
         "ts": -1.0, "s": "t"}]}
    assert any("ts" in p for p in ct.check_artifact(ooo))
    # unnamed track
    anon = {"traceEvents": [
        {"name": "x", "ph": "i", "pid": 9, "tid": 9, "ts": 0.0, "s": "t"}]}
    assert any("process_name" in p for p in ct.check_artifact(anon))
    # scoreboard whose windows don't sum to the total
    lying = dict(good)
    lying["scoreboard"] = {
        "windows": [{"tp": 1, "fp": 0, "fn": 0, "f1": 1.0,
                     "t01_hits": 0, "t01_misses": 0}],
        "total": {"tp": 2, "fp": 0, "fn": 0, "f1": 1.0,
                  "t01_hits": 0, "t01_misses": 0}}
    assert any("windows sum" in p for p in ct.check_artifact(lying))
