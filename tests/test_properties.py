"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional dev dependency: locally the module skips
cleanly when it is absent; CI installs it and runs these for real.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cache import ExpertCache
from repro.core.eam import kmeans
from repro.core.metrics import select_experts
from repro.kernels import ref

import jax.numpy as jnp

keys = st.integers(min_value=0, max_value=30)
ops_strategy = st.lists(
    st.tuples(st.sampled_from(["access", "prefetch"]), keys),
    min_size=1, max_size=200)


@given(capacity=st.integers(1, 16), ops=ops_strategy,
       policy=st.sampled_from(["lru", "lfu"]))
@settings(max_examples=60, deadline=None)
def test_cache_invariants(capacity, ops, policy):
    c = ExpertCache(capacity, policy)
    for op, k in ops:
        if op == "access":
            c.access(k)
        else:
            c.prefetch([k])
    # capacity never exceeded
    assert len(c) <= capacity
    # accounting identities
    assert c.stats.hits + c.stats.misses == \
        sum(1 for op, _ in ops if op == "access")
    assert c.stats.demand_fetches == c.stats.misses
    # any just-accessed key must be resident (it is inserted on miss)
    if ops and ops[-1][0] == "access":
        assert ops[-1][1] in c


@given(st.data())
@settings(max_examples=40, deadline=None)
def test_lru_keeps_most_recent(data):
    capacity = data.draw(st.integers(2, 8))
    n_ops = data.draw(st.integers(capacity, 50))
    c = ExpertCache(capacity, "lru")
    seq = [data.draw(keys) for _ in range(n_ops)]
    for k in seq:
        c.access(k)
    # the `capacity` most recent *distinct* keys are exactly the residents
    recent = []
    for k in reversed(seq):
        if k not in recent:
            recent.append(k)
        if len(recent) == capacity:
            break
    for k in recent:
        assert k in c


@given(t=st.integers(1, 40), e=st.integers(2, 64),
       k=st.integers(1, 8), seed=st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_topk_gating_properties(t, e, k, seed):
    k = min(k, e)
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(t, e)), jnp.float32)
    w, idx = ref.topk_gating_ref(logits, k)
    w, idx = np.asarray(w), np.asarray(idx)
    # weights are a distribution over the selected experts
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)
    assert (w >= 0).all()
    # indices are unique per row and within range
    for row in range(t):
        assert len(set(idx[row].tolist())) == k
        assert (idx[row] >= 0).all() and (idx[row] < e).all()
    # selected experts really are the k largest logits
    for row in range(t):
        top = set(np.argsort(-np.asarray(logits)[row])[:k].tolist())
        assert set(idx[row].tolist()) == top


@given(t=st.integers(1, 20), e=st.integers(2, 32), k=st.integers(1, 8),
       seed=st.integers(0, 1000))
@settings(max_examples=40, deadline=None)
def test_select_experts_cardinality(t, e, k, seed):
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(t, e)) * 3
    sel = select_experts(logits, top_k=k, threshold=0.5)
    # never more than k experts selected; all selected have prob > .5
    assert (sel.sum(-1) <= min(k, e)).all()
    probs = 1 / (1 + np.exp(-logits))
    assert ((probs > 0.5) | ~sel).all()


@given(n=st.integers(4, 40), d=st.integers(2, 10), k=st.integers(1, 6),
       seed=st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_kmeans_properties(n, d, k, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)) + 0.1
    cents, assign = kmeans(x, k, seed=seed)
    k_eff = min(k, n)
    assert cents.shape == (k_eff, d)
    assert assign.shape == (n,)
    assert (assign >= 0).all() and (assign < k_eff).all()
    # centroids are unit-normalised (cosine k-means)
    norms = np.linalg.norm(cents, axis=1)
    np.testing.assert_allclose(norms[norms > 1e-9], 1.0, atol=1e-6)


@given(st.data())
@settings(max_examples=25, deadline=None)
def test_paged_kernel_ignores_unreferenced_blocks(data):
    """Live lanes' paged flash-decode output is invariant to the contents
    of the scratch block and every pool block their tables don't reference
    below ``pos`` — garbage there must contribute exactly zero, even with a
    scratch-table padding lane sharing the launch."""
    from repro.kernels import ops
    from repro.serving.kvpool import blocks_for

    bs = data.draw(st.sampled_from([4, 8]), label="block_size")
    w = data.draw(st.integers(1, 4), label="table_width")
    kvh = data.draw(st.sampled_from([1, 2]), label="kv_heads")
    g = data.draw(st.sampled_from([1, 2]), label="group")
    hd = 8
    n_live = data.draw(st.integers(1, 3), label="live_lanes")
    seed = data.draw(st.integers(0, 10_000), label="seed")
    rng = np.random.default_rng(seed)

    nb = n_live * w + 3                       # leaves blocks unreferenced
    n = n_live + 1                            # plus one all-scratch pad lane
    pos = np.array([rng.integers(0, w * bs) for _ in range(n_live)] + [0])
    tables = np.zeros((n, w), np.int32)
    perm = rng.permutation(nb - 1)[: n_live * w] + 1
    for i in range(n_live):
        used = blocks_for(int(pos[i]) + 1, bs)
        tables[i, :used] = perm[i * w: i * w + used]   # scratch-padded tail
    q = jnp.asarray(rng.normal(size=(n, kvh, g, hd)), jnp.float32)
    kp = np.asarray(rng.normal(size=(nb, bs, kvh, hd)), np.float32)
    vp = np.asarray(rng.normal(size=(nb, bs, kvh, hd)), np.float32)

    referenced = {int(b) for row in tables for b in row if b != 0}
    kp2, vp2 = kp.copy(), vp.copy()
    for b in set(range(nb)) - referenced:     # scratch + unreferenced
        kp2[b] = rng.normal(size=kp[b].shape) * 100
        vp2[b] = rng.normal(size=vp[b].shape) * 100

    args = (jnp.asarray(tables), jnp.asarray(pos, jnp.int32))
    out1 = np.asarray(ops.paged_flash_decode(
        q, jnp.asarray(kp), jnp.asarray(vp), *args, backend="jnp"))
    out2 = np.asarray(ops.paged_flash_decode(
        q, jnp.asarray(kp2), jnp.asarray(vp2), *args, backend="jnp"))
    np.testing.assert_array_equal(out1[:n_live], out2[:n_live])


@given(seed=st.integers(0, 500), cap_frac=st.floats(0.1, 1.0))
@settings(max_examples=20, deadline=None)
def test_oracle_dominates_random(seed, cap_frac):
    """Oracle prefetch must never lose to random prefetch."""
    from repro.core.policies import OraclePolicy, RandomPolicy
    from repro.core.simulator import SimConfig, simulate
    from test_core import make_trace
    traces = [make_trace(t=15, layers=2, k=2, e=8, seed=seed + i)
              for i in range(2)]
    sim = SimConfig(num_layers=2, num_experts=8,
                    capacity_fraction=cap_frac, warm_tokens=3)
    r_o = simulate(traces, OraclePolicy(), sim)
    r_r = simulate(traces, RandomPolicy(8, 2, seed), sim)
    assert r_o.cache_hit_rate >= r_r.cache_hit_rate - 1e-9
    assert r_o.prediction_hit_rate == 1.0
