"""Learned cache replacement + quantized cold tiers.

The predictor as *replacement policy*: a ReuseDistanceScorer maps the
multi-horizon prediction window to per-key predicted-next-use distances,
and both the tier-0 ExpertCache and the store's tier-1 cache evict the
unpinned key predicted furthest from reuse — degrading to exact LRU when
no prediction covers a candidate. Streams must stay token-identical
across policies. Cold tiers (2/3) optionally store int8: round-trip
error is bounded by half a quantization step per element, the ledger
invariants survive the new demote path, and the full-precision default
stays bit-exact.
"""
import numpy as np
import pytest

from repro.core.cache import ExpertCache
from repro.core.policies import (NextLayerAllPolicy, Policy,
                                 ReuseDistanceScorer)
from repro.core.tracing import moe_layer_ids
from repro.serving.expertstore import (TierConfig, TieredExpertStore)
from repro.serving.offload import (TIER_DISK, TIER_HOST, TIER_PEER,
                                   HostExpertStore)

from helpers import tiny_backbone
from test_expertstore import make_store_layers

PROMPTS = [[3, 17, 5], [99, 255, 7, 42], [13, 5], [21, 8, 9]]
MAX_NEW = 6
CACHE_LEN = 16


# ---------------------------------------------------------------------------
# ReuseDistanceScorer semantics

def test_scorer_record_tick_staleness():
    s = ReuseDistanceScorer()
    assert s.distance(("a")) is None             # nothing recorded
    s.record([("a")], distance=0)
    s.record([("b")], distance=2)
    assert s.distance(("a")) == 1 and s.distance(("b")) == 3
    s.tick()
    # a key whose predicted use has passed is stale, not imminent: the
    # just-computed layer's keys must look like the BEST victims
    assert s.distance(("a")) is None
    assert s.distance(("b")) == 2
    # a sooner prediction overwrites, a later one does not (keep the
    # soonest live estimate)
    s.record([("b")], distance=0)
    assert s.distance(("b")) == 1
    s.record([("b")], distance=5)
    assert s.distance(("b")) == 1
    s.reset()
    assert s.clock == 0 and s.distance(("b")) is None


def test_scorer_prunes_stale_entries():
    s = ReuseDistanceScorer()
    s.PRUNE_AT = 8
    s.record([(0, e) for e in range(10)], distance=0)
    s.tick()                                     # all 10 now stale
    s.record([(1, 0)], distance=3)
    s.tick()
    assert len(s._next_use) <= s.PRUNE_AT
    assert s.distance((1, 0)) == 3               # live entries survive


# ---------------------------------------------------------------------------
# tier-0 learned eviction

def test_learned_evicts_furthest_keeps_predicted_soon():
    s = ReuseDistanceScorer()
    c = ExpertCache(3, policy="learned", scorer=s)
    s.record([(0, 0)], distance=0)               # reuse imminent
    s.record([(0, 1)], distance=4)               # reuse far away
    c.access((0, 0))
    c.access((0, 1))
    c.access((9, 9))                             # no prediction at all
    c.access((5, 5))                             # forces one eviction
    assert (9, 9) not in c                       # unpredicted goes first
    c.access((6, 6))                             # second eviction: (5,5)
    assert (5, 5) not in c
    assert (0, 0) in c and (0, 1) in c           # predicted keys survive
    assert c.stats.evictions_learned == 2
    assert c.stats.evictions_lru == 0


def test_learned_requires_scorer():
    with pytest.raises(AssertionError):
        ExpertCache(2, policy="learned")


def test_learned_degrades_to_lru_and_never_evicts_pinned():
    """Property: with NO recorded predictions a learned cache makes
    exactly the LRU choices (same residents in the same recency order),
    and with arbitrary predictions pinned keys are never evicted."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    keys = [(0, e) for e in range(8)]
    ops = st.lists(
        st.tuples(
            st.sampled_from(["access", "prefetch", "pin", "unpin",
                             "record", "tick"]),
            st.sampled_from(keys),
            st.integers(min_value=0, max_value=4)),
        min_size=1, max_size=80)

    @settings(deadline=None, max_examples=60)
    @given(ops=ops, use_predictions=st.booleans())
    def run(ops, use_predictions):
        cap = 4
        scorer = ReuseDistanceScorer()
        learned = ExpertCache(cap, "learned", scorer=scorer)
        lru = ExpertCache(cap, "lru")
        pinned = set()
        for op, k, d in ops:
            if op == "access":
                learned.access(k)
                lru.access(k)
            elif op == "prefetch":
                learned.prefetch([k], horizon=d % 2)
                lru.prefetch([k], horizon=d % 2)
            elif op == "pin":
                # keep one slot always evictable so inserts can't dead-end
                if k in learned and k in lru and len(pinned | {k}) < cap:
                    learned.pin(k)
                    lru.pin(k)
                    pinned.add(k)
            elif op == "unpin":
                if k in pinned:
                    learned.unpin(k)
                    lru.unpin(k)
                    pinned.discard(k)
            elif op == "record" and use_predictions:
                scorer.record([k], distance=d)
            elif op == "tick" and use_predictions:
                scorer.tick()
            # pinned keys are NEVER evicted, predictions or not
            for p in pinned:
                assert p in learned and p in lru
        if not use_predictions:
            # no predictions ever recorded -> exact LRU behaviour
            assert list(learned._entries) == list(lru._entries)
            assert learned.stats.evictions == lru.stats.evictions
            assert learned.stats.evictions_learned == 0

    run()


# ---------------------------------------------------------------------------
# tier-1 learned eviction (TieredExpertStore cache)

def test_store_learned_shrink_keeps_predicted():
    layers = make_store_layers()
    scorer = ReuseDistanceScorer()
    tc = TierConfig(num_shards=3, shard_dram_experts=2, cache_experts=2)
    store = TieredExpertStore(layers, tc, scorer=scorer)
    slow = [k for k in sorted(store.home_shard)
            if store.tier_of(k) in (TIER_PEER, TIER_DISK)]
    k0, k1, k2 = slow[:3]
    scorer.record([k0], distance=0)              # k0 reused imminently
    store.fetch(k0)
    store.fetch(k1)                              # k1 unpredicted
    store.fetch(k2)                              # overflow: evict one
    assert k0 in store._cache                    # predicted copy survives
    assert k1 not in store._cache                # unpredicted one went
    assert store.stats.cache_evictions_learned == 1
    assert store.stats.cache_evictions_lru == 0
    store.close()


def test_store_without_scorer_counts_no_learned_evictions():
    layers = make_store_layers()
    tc = TierConfig(num_shards=3, shard_dram_experts=2, cache_experts=2)
    store = TieredExpertStore(layers, tc)
    for k in sorted(store.home_shard):
        store.fetch(k)
    assert store.stats.cache_evictions > 0
    assert store.stats.cache_evictions_learned == 0
    assert store.stats.cache_evictions_lru == 0
    store.close()


# ---------------------------------------------------------------------------
# int8 cold tiers

def _roundtrip_bound(a, b):
    """|dequant(quant(b)) - b| <= scale/2 per element, scale from b."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    s = np.max(np.abs(b), axis=0) / 127.0
    assert np.all(np.abs(a - b) <= np.maximum(s, 1e-12) * 0.5 + 1e-6)


def test_int8_roundtrip_bound_and_fetch_bytes():
    layers = make_store_layers()
    ref = HostExpertStore(layers)
    tc = TierConfig(num_shards=3, shard_dram_experts=2, cache_experts=0,
                    cold_dtype="int8")
    store = TieredExpertStore(layers, tc)
    assert store.cold_bytes_per_expert < store.bytes_per_expert
    # int8 payload + f32 scales vs full precision: >= 2x smaller for f32
    assert store.bytes_per_expert / store.cold_bytes_per_expert >= 2.0
    cold_seen = 0
    for key in sorted(store.home_shard):
        w, info = store.fetch(key)
        if info.tier in (TIER_PEER, TIER_DISK):
            cold_seen += 1
            assert info.nbytes == store.cold_bytes_per_expert
            for a, b in zip(w, ref.get(key)):
                _roundtrip_bound(a, b)
        else:
            for a, b in zip(w, ref.get(key)):    # warm tier stays bit-exact
                np.testing.assert_array_equal(a, b)
    assert cold_seen > 0
    assert store.stats.quantized_fetches == cold_seen
    store.close()


def test_cold_dtype_none_is_bit_exact():
    layers = make_store_layers()
    ref = HostExpertStore(layers)
    tc = TierConfig(num_shards=3, shard_dram_experts=2, cache_experts=2)
    store = TieredExpertStore(layers, tc)
    for key in sorted(store.home_shard):
        for a, b in zip(store.fetch(key)[0], ref.get(key)):
            np.testing.assert_array_equal(a, b)
    assert store.stats.quantized_fetches == 0
    store.close()


def test_ledger_invariants_under_cold_demote_path():
    """The store-level interleaving property with int8 cold tiers: the
    ledger stays consistent and every fetch's weights stay within the
    quantization bound of the reference."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    layers = make_store_layers(n_layers=2, e=6)
    ref = HostExpertStore(layers)
    keys = [(li, e) for li in range(2) for e in range(6)]
    ops = st.lists(
        st.tuples(st.sampled_from(["fetch", "demote", "pin", "unpin"]),
                  st.sampled_from(keys)),
        min_size=1, max_size=60)

    @settings(deadline=None, max_examples=30)
    @given(ops=ops)
    def run(ops):
        tc = TierConfig(num_shards=3, shard_dram_experts=2, cache_experts=3,
                        cold_dtype="int8")
        store = TieredExpertStore(layers, tc)
        pins = []
        try:
            for op, k in ops:
                if op == "fetch":
                    w, info = store.fetch(k)
                    assert info.tier in (TIER_HOST, TIER_PEER, TIER_DISK)
                    for a, b in zip(w, ref.get(k)):
                        _roundtrip_bound(a, b)
                elif op == "demote":
                    store.demote(k)
                elif op == "pin":
                    store.pin(k)
                    pins.append(k)
                elif op == "unpin" and k in pins:
                    store.unpin(k)
                    pins.remove(k)
                store.ledger.check(keys)
        finally:
            store.close()

    run()


def test_int8_logit_deviation_pinned(backbone):
    """Quantize->dequantize every routed expert weight in the trained
    backbone and forward the model: the max logit deviation stays small
    (bounded numerics) but nonzero (it IS lossy — which is why
    ``cold_dtype`` is opt-in)."""
    import jax
    import jax.numpy as jnp
    cfg, model, params, _ = backbone

    def qdq(w):
        w = np.asarray(w, np.float32)
        s = np.max(np.abs(w), axis=-2, keepdims=True) / 127.0
        s = np.where(s > 0, s, 1.0)
        q = np.clip(np.rint(w / s), -127, 127)
        return jnp.asarray((q * s).astype(np.float32))

    from jax.tree_util import DictKey, tree_map_with_path

    def maybe_q(path, leaf):
        names = [p.key for p in path if isinstance(p, DictKey)]
        if "moe" in names and names[-1] in ("w_gate", "w_up", "w_down"):
            return qdq(leaf)
        return leaf

    params_q = tree_map_with_path(maybe_q, params)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)
    lg = np.asarray(model.forward(params, {"tokens": tokens}))
    lq = np.asarray(model.forward(params_q, {"tokens": tokens}))
    dev = float(np.max(np.abs(lg - lq)))
    assert 0 < dev < 0.25, dev

    # the test's vectorised round-trip matches the store's per-expert one
    tc = TierConfig(cold_dtype="int8")
    store = TieredExpertStore(make_store_layers(), tc)
    ws = store.base.get((0, 0))
    deq = store._dequantize(*store._quantize(ws))
    for a, b in zip(deq, ws):
        np.testing.assert_allclose(a, np.asarray(qdq(b)), rtol=0, atol=1e-6)
    store.close()


# ---------------------------------------------------------------------------
# engine integration

@pytest.fixture(scope="module")
def backbone():
    return tiny_backbone()


def _gen(eng, prompts):
    return eng.generate(prompts, max_new=MAX_NEW, cache_len=CACHE_LEN)


def test_learned_replacement_stream_parity_and_win(backbone):
    """learned vs lru at equal capacity: token-identical streams, victim
    provenance counted at both cache levels, and fewer slow-tier fetches
    (the tier-1 cache retains the copies predicted soonest-reused instead
    of cycling them out LRU-style)."""
    cfg, model, params, _ = backbone
    from repro.serving.scheduler import BatchedOffloadEngine
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    tc = TierConfig(num_shards=4, shard_dram_experts=3,
                    cache_experts=n_total // 2)
    runs = {}
    for pol in ("lru", "learned"):
        eng = BatchedOffloadEngine(model, params,
                                   NextLayerAllPolicy(cfg.moe.num_experts),
                                   capacity=16, eviction=pol, max_batch=4,
                                   tiers=tc)
        outs = _gen(eng, PROMPTS)
        f = eng.stats.fetches_by_tier
        runs[pol] = (outs, f.get(TIER_PEER, 0) + f.get(TIER_DISK, 0), eng)
        eng.core.store.close()
    assert runs["lru"][0] == runs["learned"][0]          # streams identical
    assert runs["learned"][1] < runs["lru"][1]           # fewer slow fetches
    lrn = runs["learned"][2]
    assert lrn.stats.evictions_learned > 0               # tier 0 informed
    assert lrn.core.store.stats.cache_evictions_learned > 0   # tier 1 too
    assert runs["lru"][2].stats.evictions_learned == 0


def test_learned_single_host_stream_parity(backbone):
    """Learned replacement without tiers: the scorer still drives the
    tier-0 slots and streams stay identical to the LRU engine."""
    cfg, model, params, _ = backbone
    from repro.serving.scheduler import BatchedOffloadEngine
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    outs = {}
    for pol in ("lru", "learned"):
        eng = BatchedOffloadEngine(model, params,
                                   NextLayerAllPolicy(cfg.moe.num_experts),
                                   capacity=max(8, n_total // 3),
                                   eviction=pol, max_batch=4)
        outs[pol] = _gen(eng, PROMPTS)
    assert outs["lru"] == outs["learned"]


def test_horizon_clamp_recovers_thrash_regime(backbone):
    """At admission-minimum tier-0 capacity, deep prefetch used to evict
    the next layer's own working set (PR 5 measured hit 0.57). The clamp
    suppresses deep insertions when they cannot fit, so the horizon-aware
    config now matches the fixed-horizon one instead of losing to it —
    and the clamps are counted."""
    cfg, model, params, _ = backbone
    from repro.serving.scheduler import BatchedOffloadEngine
    min_cap = 4 * cfg.moe.top_k
    res = {}
    for name, hz in (("aware", (1, 1, 2, 3)), ("fixed", (1, 1, 1, 1))):
        tc = TierConfig(num_shards=4, shard_dram_experts=3, cache_experts=8,
                        horizons=hz)
        eng = BatchedOffloadEngine(model, params,
                                   NextLayerAllPolicy(cfg.moe.num_experts),
                                   capacity=min_cap, eviction="lru",
                                   max_batch=4, tiers=tc)
        res[name] = (_gen(eng, PROMPTS), eng.stats.hit_rate,
                     eng.stats.horizon_clamps)
        eng.core.store.close()
    assert res["aware"][0] == res["fixed"][0]            # parity holds
    assert res["aware"][1] >= res["fixed"][1]            # no thrash loss
    assert res["aware"][2] > 0                           # clamp engaged
    assert res["fixed"][2] == 0                          # nothing to clamp


class _ConfidencePolicy(Policy):
    """All-experts prediction with a fixed reported confidence."""
    name = "confidence-stub"
    stateless = True

    def __init__(self, num_experts, conf):
        self.e = num_experts
        self.conf = conf

    def predict(self, t, layer):
        return np.arange(self.e)

    def predict_scored(self, t, layer):
        ids = np.arange(self.e)
        return ids, np.full(self.e, self.conf, np.float64)


def test_deep_confidence_gates_deep_prefetch(backbone):
    """TierConfig.deep_confidence prunes deep prefetch per key: below the
    threshold a slow-tier prediction is NOT submitted early (it still
    goes at distance 0), above it deep prefetch proceeds. Streams never
    change — only the submit timeline."""
    cfg, model, params, _ = backbone
    from repro.serving.engine import OffloadEngine
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    res = {}
    for name, thresh in (("open", 0.2), ("shut", 0.95), ("off", None)):
        tc = TierConfig(num_shards=4, shard_dram_experts=2, cache_experts=4,
                        horizons=(1, 1, 2, 3), deep_confidence=thresh,
                        peer_latency_s=1e-4, peer_bw=1e12,
                        disk_latency_s=3.4e-4, disk_bw=1e12)
        pol = _ConfidencePolicy(cfg.moe.num_experts, conf=0.5)
        eng = OffloadEngine(model, params, pol, n_total,
                            layer_compute_s=1e-3, tiers=tc)
        streams = [eng.generate(p, MAX_NEW, CACHE_LEN) for p in PROMPTS]
        res[name] = (streams, eng.stats.deep_prefetch_hits)
        eng.core.store.close()
    assert res["open"][0] == res["shut"][0] == res["off"][0]
    assert res["open"][1] > 0                    # conf 0.5 >= 0.2: deep runs
    assert res["shut"][1] == 0                   # conf 0.5 < 0.95: pruned
    assert res["off"][1] == res["open"][1]       # None == static gate only


def test_predict_many_layers_with_scores_matches_scalar():
    """The fused multi-layer scored forward returns the same (ids, conf)
    pairs as per-policy predict_scored."""
    import jax

    from repro.configs.base import PredictorConfig
    from repro.core.policies import OnlineMoEBeyondPolicy, PerRequestPolicy
    from repro.core.predictor import predictor_init

    pc = PredictorConfig(token_emb_dim=16, num_model_layers=3, num_experts=8,
                         layer_emb_dim=8, d_model=16, num_layers=2,
                         num_heads=2, d_ff=32, max_seq=16, top_k=3)
    pp = predictor_init(jax.random.PRNGKey(0), pc)
    prp = PerRequestPolicy(lambda: OnlineMoEBeyondPolicy(pp, pc, width=3))
    rng = np.random.default_rng(1)
    rids, lens = [0, 1, 2], [5, 3, 0]
    for r, n in zip(rids, lens):
        prp.begin_request(r)
        for t in range(n):
            prp._get(r).observe(t, 0, [1],
                                rng.normal(size=16).astype(np.float32))
    layers = [1, 2]
    fused = prp.predict_batch_multi_scored(rids, lens, layers)
    for layer in layers:
        for i, rid in enumerate(rids):
            ids_f, conf_f = fused[layer][i]
            ids_s, conf_s = prp._get(rid).predict_scored(lens[i], layer)
            assert sorted(ids_f.tolist()) == sorted(ids_s.tolist())
            order_f, order_s = np.argsort(ids_f), np.argsort(ids_s)
            np.testing.assert_allclose(np.asarray(conf_f)[order_f],
                                       np.asarray(conf_s)[order_s],
                                       rtol=1e-5, atol=1e-6)
