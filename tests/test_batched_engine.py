"""Batched async-prefetch serving engine: batched-vs-batch-1 parity,
continuous batching, expert pinning, and overlap stall accounting."""
import numpy as np
import pytest

from repro.core.cache import ExpertCache
from repro.core.policies import (MoEInfinityPolicy, NextLayerAllPolicy,
                                 NoPrefetchPolicy, PerRequestPolicy, Policy)
from repro.core.tracing import moe_layer_ids
from repro.serving.engine import OffloadEngine, bucket_size
from repro.serving.scheduler import BatchedOffloadEngine

from helpers import tiny_backbone

PROMPTS = [[3, 17, 5], [99, 255, 7, 42], [13, 5], [21, 8, 9]]
MAX_NEW = 6
CACHE_LEN = 16


@pytest.fixture(scope="module")
def backbone():
    return tiny_backbone()


@pytest.fixture(scope="module")
def ref_streams(backbone):
    """Batch-1 token streams, the parity reference for everything below."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    eng = OffloadEngine(model, params, None, n_total)
    return [eng.generate(p, MAX_NEW, CACHE_LEN) for p in PROMPTS]


def test_batched_matches_batch1_streams(backbone, ref_streams):
    """batch=4 at full capacity: per-request streams identical to batch-1."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    eng = BatchedOffloadEngine(model, params, None, n_total, max_batch=4)
    outs = eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    for i, (ref, got) in enumerate(zip(ref_streams, outs)):
        assert ref == got, f"request {i} diverged"
    # 4 concurrent requests: far fewer steps than 4 sequential decodes
    total_steps = sum(min(len(p) + MAX_NEW, CACHE_LEN) for p in PROMPTS)
    assert eng.stats.steps < total_steps
    assert eng.stats.tokens == total_steps
    assert eng.stats.mean_batch > 2.0


def test_continuous_batching_admits_queued_requests(backbone, ref_streams):
    """More requests than rows: finished requests free rows for queued
    ones and every stream still matches batch-1."""
    cfg, model, params, _ = backbone
    e = cfg.moe.num_experts
    n_moe = len(moe_layer_ids(cfg))
    cap = max(2 * cfg.moe.top_k + 1, (n_moe * e) // 4)
    eng = BatchedOffloadEngine(model, params, NoPrefetchPolicy(), cap,
                               max_batch=2)
    outs = eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    for i, (ref, got) in enumerate(zip(ref_streams, outs)):
        assert ref == got, f"request {i} diverged"
    assert eng.stats.misses > 0          # small shared cache really misses
    assert eng.stats.mean_batch <= 2.0


def test_stateful_policy_per_request(backbone, ref_streams):
    """A stateful policy factory gives every request its own state; a bare
    stateful instance is rejected."""
    cfg, model, params, _ = backbone
    e = cfg.moe.num_experts
    n_moe = len(moe_layer_ids(cfg))
    cap = max(4 * cfg.moe.top_k, (n_moe * e) // 3)
    eng = BatchedOffloadEngine(
        model, params, lambda: MoEInfinityPolicy([], n_moe, e, width=4),
        cap, max_batch=4)
    outs = eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    for i, (ref, got) in enumerate(zip(ref_streams, outs)):
        assert ref == got, f"request {i} diverged"
    with pytest.raises(ValueError, match="per-request state"):
        PerRequestPolicy(MoEInfinityPolicy([], n_moe, e, width=4))


def test_capacity_guard(backbone):
    cfg, model, params, _ = backbone
    with pytest.raises(ValueError, match="pin more experts"):
        BatchedOffloadEngine(model, params, None,
                             capacity=cfg.moe.top_k, max_batch=4)


# ---------------------------------------------------------------------------
# pinning

def test_cache_pinning_semantics():
    c = ExpertCache(2, "lru")
    c.access("a")
    c.access("b")
    c.pin("a")
    c.access("c")                        # must evict b, not pinned a
    assert "a" in c and "b" not in c and "c" in c
    c.pin("c")
    with pytest.raises(RuntimeError, match="pinned"):
        c.access("d")                    # both residents pinned
    c.unpin("a")
    c.access("d")                        # now a is the victim
    assert "a" not in c and "c" in c and "d" in c
    # refcounting: two pins need two unpins
    c.pin("d")
    c.pin("d")
    c.unpin("d")
    assert c.pinned("d")
    c.unpin("d")
    assert not c.pinned("d")
    with pytest.raises(AssertionError):
        c.pin("zz")                      # pinning non-resident keys is a bug


def test_pinning_under_concurrent_requests(backbone):
    """Tight capacity + max_batch concurrent lanes: one lane's demand fetch
    must not evict an expert another lane computes with this step — streams
    stay correct right at the pinning floor."""
    cfg, model, params, _ = backbone
    cap = 2 * cfg.moe.top_k              # exactly the concurrent working set
    eng = BatchedOffloadEngine(model, params, None, cap, max_batch=2)
    ref = OffloadEngine(model, params, None, cap)
    outs = eng.generate(PROMPTS[:2], max_new=MAX_NEW, cache_len=CACHE_LEN)
    refs = [ref.generate(p, MAX_NEW, CACHE_LEN) for p in PROMPTS[:2]]
    assert outs == refs


# ---------------------------------------------------------------------------
# overlap accounting

def test_overlap_stall_bounds(backbone):
    """sim_stall_s <= blocking stall always; equal when no compute overlaps
    the channel (layer_compute_s=0, demand fetches only)."""
    cfg, model, params, _ = backbone
    e = cfg.moe.num_experts
    n_moe = len(moe_layer_ids(cfg))
    cap = max(4 * cfg.moe.top_k, (n_moe * e) // 4)

    eng0 = BatchedOffloadEngine(model, params, NoPrefetchPolicy(), cap,
                                max_batch=4, layer_compute_s=0.0)
    eng0.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    assert eng0.stats.sim_stall_s > 0
    assert eng0.stats.sim_stall_s == pytest.approx(
        eng0.stats.blocking_stall_s)

    eng1 = BatchedOffloadEngine(model, params, NextLayerAllPolicy(e), cap,
                                max_batch=4, layer_compute_s=1e-4)
    eng1.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    assert eng1.stats.sim_stall_s <= eng1.stats.blocking_stall_s
    assert eng1.stats.overlapped_s > 0   # prefetch really hid transfers


def test_batch1_engine_overlap_aware(backbone):
    """The refactored batch-1 engine prefetches ahead too: with modeled
    compute, prefetched fetches stop stalling the critical path."""
    cfg, model, params, _ = backbone
    e = cfg.moe.num_experts
    n_moe = len(moe_layer_ids(cfg))
    cap = max(2, (n_moe * e) // 2)
    eng = OffloadEngine(model, params, NextLayerAllPolicy(e), cap,
                        layer_compute_s=1e-3)
    eng.generate(PROMPTS[0], max_new=MAX_NEW, cache_len=CACHE_LEN)
    assert eng.stats.sim_stall_s < eng.stats.blocking_stall_s


# ---------------------------------------------------------------------------
# batched policy API

def test_policy_predict_batch_default():
    class Fixed(Policy):
        stateless = True

        def predict(self, t, layer):
            return np.asarray([t, layer])

    p = Fixed()
    out = p.predict_batch([1, 2, 3], 5)
    assert [o.tolist() for o in out] == [[1, 5], [2, 5], [3, 5]]
    seen = []

    class Rec(Policy):
        stateless = True

        def observe(self, t, layer, experts, embedding=None):
            seen.append((t, layer, list(experts)))

    Rec().observe_batch([0, 1], 2, [[3], [4]])
    assert seen == [(0, 2, [3]), (1, 2, [4])]


def test_bucket_size():
    assert [bucket_size(n, 8) for n in (1, 2, 3, 4, 5, 8)] == \
        [1, 2, 4, 4, 8, 8]


def test_online_policy_vectorised_predict_batch():
    """PerRequestPolicy serves OnlineMoEBeyondPolicy instances with ONE
    cross-request predictor forward; results match the scalar path."""
    import jax

    from repro.configs.base import PredictorConfig
    from repro.core.policies import OnlineMoEBeyondPolicy, PerRequestPolicy
    from repro.core.predictor import predictor_init

    pc = PredictorConfig(token_emb_dim=16, num_model_layers=3, num_experts=8,
                         layer_emb_dim=8, d_model=16, num_layers=2,
                         num_heads=2, d_ff=32, max_seq=16, top_k=3)
    pp = predictor_init(jax.random.PRNGKey(0), pc)
    prp = PerRequestPolicy(lambda: OnlineMoEBeyondPolicy(pp, pc, width=3))
    rng = np.random.default_rng(0)
    rids, lens = [0, 1, 2, 3], [5, 2, 9, 0]     # ragged histories, one empty
    for r, n in zip(rids, lens):
        prp.begin_request(r)
        for t in range(n):
            prp._get(r).observe(t, 0, [1],
                                rng.normal(size=16).astype(np.float32))
    pols = [prp._get(r) for r in rids]
    assert OnlineMoEBeyondPolicy.batchable(pols)
    batched = prp.predict_batch(rids, lens, layer=1)
    scalar = [p.predict(t, 1) for p, t in zip(pols, lens)]
    for i, (b, s) in enumerate(zip(batched, scalar)):
        assert sorted(b.tolist()) == sorted(s.tolist()), f"request {i}"
    assert batched[3].size == 0                 # no observations yet
    # mixed-policy batches fall back to the scalar loop
    assert not OnlineMoEBeyondPolicy.batchable(
        pols[:1] + [NoPrefetchPolicy()])
