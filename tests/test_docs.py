"""Tier-1 hook for the docs lint: config/stats docstring coverage and
markdown link integrity (the same checks CI runs via
``tools/check_docs.py``)."""
import os
import sys

TOOLS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tools")


def _checker():
    sys.path.insert(0, TOOLS)
    try:
        import check_docs
    finally:
        sys.path.remove(TOOLS)
    return check_docs


def test_dataclass_fields_documented():
    assert _checker().check_docstrings() == []


def test_markdown_links_resolve():
    assert _checker().check_markdown() == []
