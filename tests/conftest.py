# NOTE: no XLA_FLAGS / device-count overrides here — smoke tests and benches
# must see the single real CPU device. The multi-device dry-run test shells
# out to repro.launch.dryrun in a subprocess, which sets its own flags.
import jax

jax.config.update("jax_enable_x64", False)
