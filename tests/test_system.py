"""End-to-end behaviour tests: the full paper pipeline at tiny scale —
backbone -> traces -> predictor -> simulator -> policy ordering."""
import numpy as np
import pytest

from repro.configs.base import PredictorConfig
from repro.core.eam import build_ream
from repro.core.policies import (MoEBeyondPolicy, MoEInfinityPolicy,
                                 NextLayerAllPolicy, NoPrefetchPolicy,
                                 OraclePolicy, RandomPolicy)
from repro.core.simulator import SimConfig, simulate, sweep_capacity
from repro.core.tracing import load_traces, moe_layer_ids, save_traces

from helpers import tiny_traces


@pytest.fixture(scope="module")
def pipeline():
    return tiny_traces()


def test_traces_schema(pipeline):
    cfg, model, params, traces = pipeline
    n_moe = len(moe_layer_ids(cfg))
    assert n_moe == cfg.num_layers - cfg.moe.first_dense_layers
    for tr in traces:
        t, l, k = tr.experts.shape
        assert l == n_moe and k == cfg.moe.top_k
        assert tr.embeddings.shape == (t, cfg.d_model)
        assert (tr.experts >= 0).all()
        assert (tr.experts < cfg.moe.num_experts).all()


def test_trace_roundtrip(tmp_path, pipeline):
    _, _, _, traces = pipeline
    p = str(tmp_path / "traces.npz")
    save_traces(p, traces[:3])
    back = load_traces(p)
    assert len(back) == 3
    np.testing.assert_array_equal(back[0].experts, traces[0].experts)
    np.testing.assert_array_equal(back[0].tokens, traces[0].tokens)


def test_within_prompt_locality(pipeline):
    """Paper Fig 1-3: single-prompt expert usage is narrower than the
    all-prompt aggregate (request-level locality)."""
    cfg, _, _, traces = pipeline
    n_moe = len(moe_layer_ids(cfg))
    e = cfg.moe.num_experts
    agg = np.zeros((n_moe, e))
    per_prompt = []
    for tr in traces:
        r = build_ream(tr, n_moe, e)
        agg += r
        per_prompt.append((r > 0).mean())
    agg_coverage = (agg > 0).mean()
    assert np.mean(per_prompt) <= agg_coverage + 1e-9


def test_policy_ordering(pipeline):
    """oracle >= {moe-infinity, next-layer-all} >= random at small capacity
    (paper Fig 7's qualitative ordering)."""
    cfg, _, _, traces = pipeline
    n_moe = len(moe_layer_ids(cfg))
    e = cfg.moe.num_experts
    train, test = traces[:7], traces[7:]
    sim = SimConfig(num_layers=n_moe, num_experts=e, capacity_fraction=0.25,
                    warm_tokens=4)
    r_oracle = simulate(test, OraclePolicy(), sim)
    r_inf = simulate(test, MoEInfinityPolicy(train, n_moe, e,
                                             width=cfg.moe.top_k), sim)
    r_rand = simulate(test, RandomPolicy(e, cfg.moe.top_k), sim)
    r_none = simulate(test, NoPrefetchPolicy(), sim)
    assert r_oracle.cache_hit_rate >= r_inf.cache_hit_rate - 1e-9
    assert r_inf.cache_hit_rate >= r_rand.cache_hit_rate - 0.02
    assert r_oracle.cache_hit_rate == pytest.approx(1.0)
    assert r_none.prediction_hit_rate == 0.0


def test_capacity_sweep_monotone(pipeline):
    """Hit rate grows (weakly) with cache capacity."""
    cfg, _, _, traces = pipeline
    n_moe = len(moe_layer_ids(cfg))
    e = cfg.moe.num_experts
    sim = SimConfig(num_layers=n_moe, num_experts=e, warm_tokens=4)
    rs = sweep_capacity(traces[7:], NoPrefetchPolicy, sim,
                        [0.1, 0.4, 0.8, 1.0])
    rates = [r.cache_hit_rate for r in rs]
    assert all(b >= a - 0.03 for a, b in zip(rates, rates[1:])), rates


def test_learned_predictor_mechanism(pipeline):
    """MoE-Beyond policy wired through the simulator on real backbone
    traces: the mechanism must produce nonzero prediction hits (quality on
    a 60-step backbone is benchmarked, not asserted)."""
    from repro.core.predictor_train import train_predictor
    cfg, _, _, traces = pipeline
    n_moe = len(moe_layer_ids(cfg))
    e = cfg.moe.num_experts
    train, test = traces[:7], traces[7:]
    pcfg = PredictorConfig(
        token_emb_dim=cfg.d_model, num_model_layers=n_moe, num_experts=e,
        layer_emb_dim=16, d_model=48, num_layers=2, num_heads=4, d_ff=96,
        max_seq=48, top_k=cfg.moe.top_k)
    params, hist = train_predictor(train, test, pcfg, epochs=6,
                                   batch_size=4, base_lr=1e-2, patience=6,
                                   log=lambda *_: None)
    sim = SimConfig(num_layers=n_moe, num_experts=e, capacity_fraction=0.15,
                    warm_tokens=4)
    r_beyond = simulate(test, MoEBeyondPolicy(params, pcfg), sim)
    assert r_beyond.prediction_hit_rate > 0.0
    assert r_beyond.prefetches > 0


def test_good_predictor_beats_no_prefetch():
    """With learnable routing (deterministic rule + noise), the trained
    MoE-Beyond policy must clearly beat reactive LRU — the paper's claim at
    test scale."""
    import numpy as np

    from repro.core.predictor_train import train_predictor
    from repro.core.tracing import Trace
    n_moe, e, k, emb_d = 4, 16, 2, 60   # emb = exact one-hot token id
    rng = np.random.default_rng(0)

    def mk(seed):
        r = np.random.default_rng(seed)
        t = 40
        toks = r.integers(0, 60, t).astype(np.int32)
        emb = np.zeros((t, emb_d), np.float32)
        emb[np.arange(t), toks % emb_d] = 1.0
        ex = np.zeros((t, n_moe, k), np.int32)
        for l in range(n_moe):
            ex[:, l, 0] = (toks + 3 * l) % e
            ex[:, l, 1] = np.where(r.random(t) < 0.15,
                                   r.integers(0, e, t),
                                   (toks + 3 * l + 7) % e)
        return Trace(toks, emb, ex, prompt_len=4)

    traces = [mk(s) for s in range(10)]
    train, test = traces[:8], traces[8:]
    pcfg = PredictorConfig(token_emb_dim=emb_d, num_model_layers=n_moe,
                           num_experts=e, layer_emb_dim=8, d_model=32,
                           num_layers=2, num_heads=4, d_ff=64, max_seq=48,
                           top_k=k)
    params, hist = train_predictor(train, test, pcfg, epochs=30,
                                   batch_size=4, base_lr=5e-3, patience=30,
                                   log=lambda *_: None)
    sim = SimConfig(num_layers=n_moe, num_experts=e, capacity_fraction=0.15,
                    warm_tokens=4)
    r_beyond = simulate(test, MoEBeyondPolicy(params, pcfg), sim)
    r_none = simulate(test, NoPrefetchPolicy(), sim)
    assert r_beyond.prediction_hit_rate > 0.5
    assert r_beyond.cache_hit_rate > r_none.cache_hit_rate + 0.1, \
        (r_beyond.cache_hit_rate, r_none.cache_hit_rate)
