"""Tiered sharded expert store: consistent-hash placement, the residency
ledger's invariants, real disk-spill round-trips, per-tier stall
accounting, and engine parity — a config whose expert set exceeds tier-1
capacity must decode token-identical to the single-host HostExpertStore
path, with horizon-aware prefetch shrinking the modeled stall."""
import numpy as np
import pytest

from repro.core.tracing import moe_layer_ids
from repro.serving.expertstore import (ConsistentHashRing, ResidencyLedger,
                                       StoreStats, TierConfig,
                                       TieredExpertStore)
from repro.serving.offload import (TIER_DISK, TIER_HOST, TIER_PEER,
                                   HostExpertStore, OverlapTracker)

from helpers import tiny_backbone

PROMPTS = [[3, 17, 5], [99, 255, 7, 42], [13, 5], [21, 8, 9]]
MAX_NEW = 6
CACHE_LEN = 16


def make_store_layers(n_layers=3, e=8, d=4, f=6, seed=0):
    rng = np.random.default_rng(seed)
    return [
        {"w_gate": rng.normal(size=(e, d, f)).astype(np.float32),
         "w_up": rng.normal(size=(e, d, f)).astype(np.float32),
         "w_down": rng.normal(size=(e, f, d)).astype(np.float32)}
        for _ in range(n_layers)
    ]


# ---------------------------------------------------------------------------
# consistent-hash placement

def test_ring_deterministic_and_covering():
    keys = [(layer, e) for layer in range(4) for e in range(32)]
    r1 = ConsistentHashRing(range(4), vnodes=64, seed=0)
    r2 = ConsistentHashRing(range(4), vnodes=64, seed=0)
    homes = {k: r1.lookup(k) for k in keys}
    assert homes == {k: r2.lookup(k) for k in keys}
    assert set(homes.values()) == {0, 1, 2, 3}   # every shard owns keys


def test_ring_stability_on_add_and_remove():
    """Adding (removing) a shard only moves keys onto (off) that shard —
    placement of every other key is stable."""
    keys = [(layer, e) for layer in range(8) for e in range(64)]
    ring = ConsistentHashRing(range(4), vnodes=64, seed=0)
    before = {k: ring.lookup(k) for k in keys}
    ring.add_shard(4)
    after = {k: ring.lookup(k) for k in keys}
    moved = {k for k in keys if before[k] != after[k]}
    assert all(after[k] == 4 for k in moved)     # moves only ONTO shard 4
    assert 0 < len(moved) < len(keys) // 2       # and only a minority
    ring.remove_shard(4)
    assert {k: ring.lookup(k) for k in keys} == before   # exact rollback


def test_rebalance_counts_moved_keys():
    tc = TierConfig(num_shards=2, cache_experts=2)
    store = TieredExpertStore(make_store_layers(), tc)
    before = dict(store.home_shard)
    moved = store.rebalance(3)
    after = store.home_shard
    assert moved == sum(1 for k in before if before[k] != after[k])
    assert all(after[k] == before[k] or after[k] == 2 for k in before)
    store.ledger.check()
    store.close()


# ---------------------------------------------------------------------------
# residency ledger

def test_ledger_basics():
    led = ResidencyLedger()
    led.place((0, 1), shard=1, tier=TIER_PEER)
    with pytest.raises(AssertionError):          # exactly one home
        led.place((0, 1), shard=0, tier=TIER_HOST)
    led.add_copy((0, 1), TIER_HOST)
    with pytest.raises(AssertionError):          # no double-residency
        led.add_copy((0, 1), TIER_HOST)
    assert led.tier_of((0, 1)) == TIER_HOST
    led.pin((0, 1))
    with pytest.raises(AssertionError):          # pinned => unevictable
        led.drop_copy((0, 1), TIER_HOST)
    led.unpin((0, 1))
    led.drop_copy((0, 1), TIER_HOST)
    assert led.tier_of((0, 1)) == TIER_PEER      # home copy never lost
    led.check()


def test_ledger_property_interleaved_ops():
    """Random interleavings of fetch/promote/demote/evict/pin/unpin across
    tiers: no expert is ever lost, double-resident in one tier, or evicted
    while pinned."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    keys = [(0, e) for e in range(6)]
    ops = st.lists(
        st.tuples(st.sampled_from(["promote", "evict", "pin", "unpin"]),
                  st.sampled_from(keys)),
        min_size=1, max_size=80)

    @settings(deadline=None, max_examples=60)
    @given(ops=ops)
    def run(ops):
        led = ResidencyLedger()
        for i, k in enumerate(keys):             # homes spread across tiers
            led.place(k, shard=i % 3,
                      tier=(TIER_HOST, TIER_PEER, TIER_DISK)[i % 3])
        for op, k in ops:
            if op == "promote" and led.home(k)[1] != TIER_HOST \
                    and TIER_HOST not in led.cached_tiers(k):
                led.add_copy(k, TIER_HOST)
            elif op == "evict" and TIER_HOST in led.cached_tiers(k) \
                    and not led.pinned(k):
                led.drop_copy(k, TIER_HOST)
            elif op == "pin":
                led.pin(k)
            elif op == "unpin":
                led.unpin(k)
            led.check(keys)                      # invariants after every op
            for k2 in keys:
                assert led.tier_of(k2) in (TIER_HOST, TIER_PEER, TIER_DISK)

    run()


def test_store_property_interleaved_ops():
    """The same interleaving property at the TieredExpertStore level:
    fetches (which promote), demotes (tier-0 eviction), pins, and cache
    evictions keep the ledger consistent and every expert fetchable with
    bit-identical weights."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    layers = make_store_layers(n_layers=2, e=6)
    ref = HostExpertStore(layers)
    keys = [(li, e) for li in range(2) for e in range(6)]
    ops = st.lists(
        st.tuples(st.sampled_from(["fetch", "demote", "pin", "unpin"]),
                  st.sampled_from(keys)),
        min_size=1, max_size=60)

    @settings(deadline=None, max_examples=30)
    @given(ops=ops)
    def run(ops):
        tc = TierConfig(num_shards=3, shard_dram_experts=2, cache_experts=3)
        store = TieredExpertStore(layers, tc)
        pins = []
        try:
            for op, k in ops:
                if op == "fetch":
                    w, info = store.fetch(k)
                    assert info.tier in (TIER_HOST, TIER_PEER, TIER_DISK)
                    for a, b in zip(w, ref.get(k)):
                        np.testing.assert_array_equal(a, b)
                elif op == "demote":
                    store.demote(k)
                elif op == "pin":
                    store.pin(k)
                    pins.append(k)
                elif op == "unpin" and k in pins:
                    store.unpin(k)
                    pins.remove(k)
                store.ledger.check(keys)
                # the tier-1 cache respects its cap unless pins force it
                unpinned = sum(1 for c in store._cache
                               if not store.ledger.pinned(c))
                assert (len(store._cache) <= tc.cache_experts
                        or unpinned == 0)
        finally:
            store.close()

    run()


# ---------------------------------------------------------------------------
# tiered store behaviour

def test_all_tiers_serve_identical_weights():
    layers = make_store_layers()
    ref = HostExpertStore(layers)
    tc = TierConfig(num_shards=3, shard_dram_experts=2, cache_experts=0)
    store = TieredExpertStore(layers, tc)
    tiers_seen = set()
    for key in sorted(store.home_shard):
        w, info = store.fetch(key)
        tiers_seen.add(info.tier)
        for a, b in zip(w, ref.get(key)):
            np.testing.assert_array_equal(a, b)  # disk round-trip exact
    assert tiers_seen == {TIER_HOST, TIER_PEER, TIER_DISK}
    assert store.stats.spilled_experts > 0
    store.close()


def test_promotion_demotion_and_pinning():
    layers = make_store_layers()
    tc = TierConfig(num_shards=3, shard_dram_experts=2, cache_experts=2)
    store = TieredExpertStore(layers, tc)
    slow = [k for k in sorted(store.home_shard)
            if store.tier_of(k) in (TIER_PEER, TIER_DISK)]
    k0, k1, k2 = slow[:3]
    first = store.fetch(k0)[1]
    assert first.tier in (TIER_PEER, TIER_DISK)
    assert store.fetch(k0)[1].tier == TIER_HOST  # promoted on access
    assert store.fetch(k0)[1].duration is None   # host fetch: host-bw model

    # demote(k1) absorbs a tier-0 eviction: next fetch is tier 1
    store.demote(k1)
    assert store.fetch(k1)[1].tier == TIER_HOST

    # pinned entries are unevictable: k0+k1 fill the 2-slot cache; pin
    # them and promote a third — the cache overflows rather than evict
    store.pin(k0)
    store.pin(k1)
    store.fetch(k2)
    assert store.tier_of(k0) == TIER_HOST and store.tier_of(k1) == TIER_HOST
    store.unpin(k0)
    store.unpin(k1)                              # deferred evictions land
    assert len(store._cache) <= tc.cache_experts
    store.ledger.check()
    store.close()


def test_prefetch_horizon_tracks_tier():
    layers = make_store_layers()
    tc = TierConfig(num_shards=3, shard_dram_experts=2, cache_experts=2,
                    horizons=(1, 1, 2, 3))
    store = TieredExpertStore(layers, tc)
    by_tier = {}
    for key in sorted(store.home_shard):
        by_tier.setdefault(store.tier_of(key), key)
    assert store.prefetch_horizon(by_tier[TIER_HOST]) == 1
    assert store.prefetch_horizon(by_tier[TIER_PEER]) == 2
    assert store.prefetch_horizon(by_tier[TIER_DISK]) == 3
    k = by_tier[TIER_DISK]
    store.fetch(k)                               # promotes to tier 1
    assert store.prefetch_horizon(k) == 1        # horizon follows residency
    store.close()


def test_tracker_per_tier_channels_and_stall():
    tr = OverlapTracker(host_bw=1e9)
    tr.submit(("a"), 1e9, tier=TIER_HOST)            # 1 s on host channel
    tr.submit(("b"), 0, tier=TIER_DISK, duration=3.0)  # 3 s on disk channel
    # channels run in parallel: 1 s of compute hides the host transfer
    # fully and a third of the disk one
    tr.advance(1.0)
    stall = tr.wait(["a", "b"])
    assert stall == pytest.approx(2.0)
    assert tr.stall_by_tier[TIER_DISK] == pytest.approx(2.0)
    assert tr.stall_by_tier.get(TIER_HOST, 0.0) == 0.0
    assert tr.overlapped_by_tier[TIER_HOST] == pytest.approx(1.0)
    assert tr.overlapped_by_tier[TIER_DISK] == pytest.approx(1.0)
    assert tr.overlapped_s == pytest.approx(2.0)
    assert tr.stall_s == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# engine integration: streams must not change, stalls must break down

@pytest.fixture(scope="module")
def backbone():
    return tiny_backbone()


def _tier_cfg(cfg, horizons=(1, 1, 2, 3)):
    """Shards sized so the expert set EXCEEDS tier-1 capacity: most
    experts live on peers or spill to disk."""
    return TierConfig(num_shards=4, shard_dram_experts=2, cache_experts=4,
                      horizons=horizons)


def test_batch1_tiered_stream_parity(backbone):
    cfg, model, params, _ = backbone
    from repro.serving.engine import OffloadEngine
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    plain = OffloadEngine(model, params, None, n_total)
    tiered = OffloadEngine(model, params, None, n_total,
                           tiers=_tier_cfg(cfg))
    for p in PROMPTS:
        assert (tiered.generate(p, MAX_NEW, CACHE_LEN)
                == plain.generate(p, MAX_NEW, CACHE_LEN))
    st = tiered.core.store.stats
    assert st.spilled_experts > 0                # disk tier really in play
    assert set(st.fetches_by_tier) >= {TIER_PEER, TIER_DISK}
    assert tiered.stats.fetches_by_tier == st.fetches_by_tier
    tiered.core.store.close()


def test_batched_tiered_stream_parity(backbone):
    cfg, model, params, _ = backbone
    from repro.serving.config import ServeConfig
    from repro.serving.scheduler import BatchedOffloadEngine
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    plain = BatchedOffloadEngine(model, params, None, n_total, max_batch=4)
    sc = ServeConfig(max_batch=4, tiers=_tier_cfg(cfg))
    tiered = BatchedOffloadEngine(model, params, None, n_total, serve=sc)
    outs_p = plain.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    outs_t = tiered.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    assert outs_p == outs_t
    assert sum(tiered.stats.fetches_by_tier.values()) > 0
    tiered.core.store.close()


def test_horizon_aware_prefetch_cuts_stall(backbone):
    """At equal tier-0 capacity, tier-scaled lookahead must stall less
    than fixed single-layer lookahead — slower tiers get submitted layers
    earlier, so more compute hides their longer fetches. Streams stay
    token-identical (prefetch never changes math, only the timeline).

    The tier model is scaled so one MoE layer's batch of disk fetches
    costs ~2 layers of modeled compute: a single layer of lookahead
    cannot hide the spilled experts but a deeper one hides more. At full
    tier-0 capacity the prefetch *sets* are identical across horizons —
    only submit times differ — so the comparison is exact."""
    cfg, model, params, _ = backbone
    from repro.core.policies import NextLayerAllPolicy
    from repro.serving.engine import OffloadEngine
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    pol = NextLayerAllPolicy(cfg.moe.num_experts)
    streams, stalls = {}, {}
    for name, hz in (("fixed", (1, 1, 1, 1)), ("aware", (1, 1, 2, 3))):
        # ~13 disk-homed experts per MoE layer at these shard sizes: one
        # layer's disk batch = 13 x 0.34ms ~ 2.2 layer-pairs of compute —
        # unhideable at lookahead 1, mostly hidden at lookahead 3. (A
        # saturated channel shows NO difference: if total fetch work
        # dwarfs total compute, submit order cannot matter.)
        tc = TierConfig(num_shards=4, shard_dram_experts=2,
                        cache_experts=4, horizons=hz,
                        peer_latency_s=1e-4, peer_bw=1e12,
                        disk_latency_s=3.4e-4, disk_bw=1e12)
        eng = OffloadEngine(model, params, pol, n_total,
                            layer_compute_s=1e-3, tiers=tc)
        streams[name] = [eng.generate(p, MAX_NEW, CACHE_LEN)
                         for p in PROMPTS]
        stalls[name] = eng.stats.sim_stall_s
        eng.core.store.close()
        if name == "aware":
            assert eng.stats.deep_prefetch_hits > 0
    assert streams["aware"] == streams["fixed"]
    assert stalls["fixed"] > 0
    assert stalls["aware"] < stalls["fixed"]


def test_layer_compute_roofline_and_measured(backbone):
    """layer_compute_s is derived, not a knob: 'roofline' uses per-layer
    analytic estimates, 'measured' rescales them to real step walltime."""
    cfg, model, params, _ = backbone
    from repro.launch.dryrun import decode_layer_roofline
    from repro.serving.engine import OffloadEngine
    per_layer = decode_layer_roofline(cfg, batch=1)
    assert len(per_layer) == cfg.num_layers
    assert all(a > 0 for a, _ in per_layer)
    moe_lids = set(moe_layer_ids(cfg))
    assert all(f > 0 for li, (_, f) in enumerate(per_layer)
               if li in moe_lids)

    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    eng = OffloadEngine(model, params, None, n_total,
                        layer_compute_s="roofline")
    eng.generate(PROMPTS[0], MAX_NEW, CACHE_LEN)
    # the compute clock advanced by the roofline terms, not a knob
    assert eng.core.tracker.clock > 0
    assert eng.core._calib == 1.0

    meas = OffloadEngine(model, params, None, n_total,
                         layer_compute_s="measured")
    meas.generate(PROMPTS[0], MAX_NEW, CACHE_LEN)
    # walltime on any real machine dwarfs the TPU roofline estimate
    assert meas.core._calib != 1.0

    with pytest.raises(ValueError):
        OffloadEngine(model, params, None, n_total, layer_compute_s="nope")


def test_single_host_reports_tier1_only(backbone):
    cfg, model, params, _ = backbone
    from repro.serving.engine import OffloadEngine
    n_moe = len(moe_layer_ids(cfg))
    cap = max(4, (n_moe * cfg.moe.num_experts) // 4)
    eng = OffloadEngine(model, params, None, cap)
    eng.generate(PROMPTS[0], MAX_NEW, CACHE_LEN)
    assert set(eng.stats.fetches_by_tier) == {TIER_HOST}
    assert set(eng.stats.stall_by_tier) <= {TIER_HOST}
    assert eng.stats.fetch_bytes_by_tier[TIER_HOST] == eng.stats.fetch_bytes
