"""Predictor model + training: shapes, causality, overfit capacity, early
stopping, dataset mechanics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PredictorConfig
from repro.core.predictor import (bce_loss, predictor_apply, predictor_init)
from repro.core.tracing import Trace
from repro.data.traces import PredictorDataset, SequenceCache

PC = PredictorConfig(token_emb_dim=16, num_model_layers=4, num_experts=8,
                     layer_emb_dim=8, d_model=32, num_layers=2, num_heads=4,
                     d_ff=64, max_seq=24, top_k=2)


def _toy_traces(n=6, t=20, seed=0):
    rng = np.random.default_rng(seed)
    traces = []
    for i in range(n):
        toks = rng.integers(0, 50, t).astype(np.int32)
        emb = np.zeros((t, PC.token_emb_dim), np.float32)
        emb[np.arange(t), toks % PC.token_emb_dim] = 1.0   # learnable signal
        # deterministic rule: expert = (token + layer) % E, plus expert 0
        experts = np.zeros((t, 4, 2), np.int32)
        for l in range(4):
            experts[:, l, 0] = (toks + l) % PC.num_experts
            experts[:, l, 1] = 0
        traces.append(Trace(toks, emb, experts, prompt_len=4))
    return traces


def test_predictor_shapes_and_finite():
    params = predictor_init(jax.random.PRNGKey(0), PC)
    emb = jnp.zeros((2, 10, PC.token_emb_dim))
    lids = jnp.zeros((2, 10), jnp.int32)
    mask = jnp.ones((2, 10), bool)
    logits = predictor_apply(params, PC, emb, lids, mask)
    assert logits.shape == (2, 10, PC.num_experts)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_predictor_is_causal():
    """Changing a future token must not change past predictions."""
    params = predictor_init(jax.random.PRNGKey(0), PC)
    rng = np.random.default_rng(0)
    emb = rng.normal(size=(1, 12, PC.token_emb_dim)).astype(np.float32)
    lids = jnp.zeros((1, 12), jnp.int32)
    mask = jnp.ones((1, 12), bool)
    l1 = predictor_apply(params, PC, jnp.asarray(emb), lids, mask)
    emb2 = emb.copy()
    emb2[0, 8:] += 10.0
    l2 = predictor_apply(params, PC, jnp.asarray(emb2), lids, mask)
    np.testing.assert_allclose(np.asarray(l1)[0, :8], np.asarray(l2)[0, :8],
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(l1)[0, 8:], np.asarray(l2)[0, 8:])


def test_predictor_overfits_rule():
    """On a deterministic routing rule the predictor should reach high F1
    quickly — this is the learning-capacity sanity check."""
    from repro.core.predictor_train import train_predictor
    traces = _toy_traces(n=8)
    params, hist = train_predictor(traces[:6], traces[6:], PC, epochs=28,
                                   batch_size=4, base_lr=1e-2, patience=28,
                                   log=lambda *_: None)
    assert max(hist.val_f1) > 0.85, hist.val_f1
    assert max(hist.val_acc) > 0.95, hist.val_acc


def test_early_stopping_triggers():
    from repro.core.predictor_train import train_predictor
    traces = _toy_traces(n=4)
    # zero LR -> no improvement -> early stop after `patience` epochs
    params, hist = train_predictor(traces[:3], traces[3:], PC, epochs=10,
                                   batch_size=2, base_lr=0.0, patience=2,
                                   log=lambda *_: None)
    assert len(hist.val_loss) < 10


def test_bce_loss_masking():
    logits = jnp.zeros((1, 4, 8))
    tgt = jnp.zeros((1, 4, 8))
    mask_all = jnp.ones((1, 4))
    mask_none = jnp.zeros((1, 4))
    l1 = bce_loss(logits, tgt, mask_all)
    assert abs(float(l1) - float(np.log(2))) < 1e-5
    assert float(bce_loss(logits, tgt, mask_none)) == 0.0


def test_dataset_padding_and_targets():
    traces = _toy_traces(n=2, t=10)
    ds = PredictorDataset(traces, PC)
    assert len(ds) == 2 * 4                     # (trace, layer) pairs
    emb, lids, mask, tgt = ds.example(0)
    assert emb.shape == (PC.max_seq, PC.token_emb_dim)
    assert mask[:10].all() and not mask[10:].any()
    # targets: exactly the rule's experts are hot
    t0 = traces[0]
    for tok in range(10):
        hot = set(np.nonzero(tgt[tok])[0].tolist())
        assert hot == set(t0.experts[tok, 0].tolist())
    # padded positions have empty targets
    assert tgt[10:].sum() == 0


def test_sequence_cache_lru():
    c = SequenceCache(capacity=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1
    c.put("c", 3)                               # evicts b (LRU)
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3


def test_dataset_cache_accelerates_epochs():
    traces = _toy_traces(n=2, t=10)
    ds = PredictorDataset(traces, PC, cache_capacity=1000)
    list(ds.batches(2, shuffle=False))
    m0 = ds.cache.misses
    list(ds.batches(2, shuffle=False))
    assert ds.cache.misses == m0               # all hits on second epoch
    assert ds.cache.hits >= len(ds)
