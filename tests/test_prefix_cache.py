"""Prefix-sharing KV cache: radix index mechanics, copy-on-write, expert
replay, scheduler admission — and the shared-prefix parity suite (streams
token-identical with the cache on and off, across stacks and read paths).

Also pins the admission bugfixes that ride along: graceful rejection of
impossible requests, ``submit([])`` validation, and degenerate-case parity
between the paged and row engines.
"""
import numpy as np
import pytest

from repro.core.policies import MoEInfinityPolicy
from repro.core.tracing import moe_layer_ids
from repro.serving.engine import OffloadEngine
from repro.serving.kvpool import BlockTable, KVBlockPool, blocks_for
from repro.serving.prefixcache import PrefixCache
from repro.serving.scheduler import BatchedOffloadEngine

from helpers import tiny_backbone

# 8 requests sharing a 24-token system prompt with ragged unique tails —
# same-wave admissions (first max_batch) can only share via mid-prefill
# extension; later waves hit at admission
SYS = [7, 99, 23, 5, 81, 3, 250, 17, 44, 2, 9, 60, 31, 4, 77, 12,
       8, 55, 20, 1, 33, 6, 90, 13]
TAILS = [[11, 42], [200, 9, 71, 30], [5], [88, 14, 3, 97, 21, 50, 2],
         [61, 7, 7], [110, 4], [19, 19, 19, 19, 19], [240]]
PROMPTS = [SYS + t for t in TAILS]
MAX_NEW = 5
CACHE_LEN = 48


@pytest.fixture(scope="module")
def backbone():
    return tiny_backbone()


@pytest.fixture(scope="module")
def ref_streams(backbone):
    """prefix_cache=False streams: the sharing-off reference."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    eng = BatchedOffloadEngine(model, params, None, n_total, max_batch=4,
                               block_size=4)
    return eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)


# ---------------------------------------------------------------------------
# radix index unit mechanics (bare pool, no model)

def _mk(num_blocks=32, bs=4):
    pool = KVBlockPool(num_blocks, bs)
    return pool, PrefixCache(pool)


def _stash(pool, n):
    """Simulate a retired request's blocks: allocated, refcount 1."""
    return [pool.alloc() for _ in range(n)]


def test_index_match_insert_roundtrip():
    pool, pc = _mk(bs=4)
    toks = list(range(40, 52))                       # 3 whole blocks
    bids = _stash(pool, 3)
    assert pc.insert(toks, 3, bids, {0: {0: {1, 2}}, 2: {1: {3}}}) == 3
    assert pc.cached_blocks == 3
    assert all(pool.ref_count(b) == 2 for b in bids)

    m = pc.match(toks + [9, 9], limit=10)            # cap mid-block 3
    assert m.tokens == 10 and m.bids == bids         # partial last block
    assert m.experts[0].tolist() == [1, 2]
    m2 = pc.match(toks[:8] + [999] * 8, limit=15)    # diverges at block 2
    assert m2.tokens == 8 and m2.bids == bids[:2]
    assert not pc.match([999] * 12, limit=11)
    # idempotent re-insert of the same path adds nothing
    assert pc.insert(toks, 3, bids, {}) == 0
    for b in bids:
        assert pool.ref_count(b) == 2


def test_index_match_respects_limit_and_whole_blocks():
    pool, pc = _mk(bs=4)
    toks = list(range(8))
    bids = _stash(pool, 2)
    pc.insert(toks, 2, bids, {})
    assert pc.match(toks, limit=0).tokens == 0       # nothing to skip
    assert pc.match(toks, limit=3).tokens == 3       # partial first block
    assert pc.match(toks, limit=3).bids == bids[:1]
    assert pc.match(toks[:7], limit=7).tokens == 4   # block 2 not whole


def test_index_eviction_lru_leaves_only():
    pool, pc = _mk(num_blocks=12, bs=2)
    a = _stash(pool, 2)
    b = _stash(pool, 1)
    pc.insert([1, 2, 3, 4], 2, a, {})                # path a0 -> a1
    pc.insert([9, 9], 1, b, {})
    for bid in a + b:
        pool.free(bid)                               # "requests retired"
    pc.match([1, 2, 3, 4], limit=4)                  # freshen path a
    # leaf eviction: LRU leaf is b's node; a's inner node a0 is untouched
    assert pc.evict(1) == 1
    assert pool.ref_count(b[0]) == 0                 # back in the free list
    assert pc.cached_blocks == 2
    # a1 (leaf) goes before a0 (inner) even though a0 is older
    assert pc.evict(2) == 2 and pc.cached_blocks == 0
    pool.check_leaks(expected_in_use=0)


def test_index_eviction_skips_blocks_with_holders():
    pool, pc = _mk(num_blocks=8, bs=2)
    bids = _stash(pool, 1)
    pc.insert([5, 6], 1, bids, {})
    t = BlockTable(pool)
    t.adopt(bids)                                    # a live request holds it
    pool.free(bids[0])                               # drop the stash ref
    assert pc.evict(5) == 0                          # unevictable
    t.release()
    assert pc.evict(5) == 1
    pool.check_leaks(expected_in_use=0)


def test_block_table_cow():
    pool = KVBlockPool(8, 2)
    owner = _stash(pool, 1)
    t = BlockTable(pool)
    t.adopt(owner)
    assert t.is_shared(0)
    old, new = t.make_private(0)
    assert (old, new) == (owner[0], t.ids[0]) and old != new
    assert not t.is_shared(0)
    assert pool.ref_count(owner[0]) == 1             # sibling unaffected
    assert pool.stats.cow_copies == 1
    # sole holder: adopting then privatising without siblings copies nothing
    pool.free(owner[0])
    t2 = BlockTable(pool)
    t2.adopt([t.ids[0]])
    t.release()
    assert t2.make_private(0) is None                # took exclusive ownership
    t2.release()
    pool.check_leaks(expected_in_use=0)


def test_pool_stats_split_symmetry():
    """allocs counts every allocation, releases only zero-ref returns; the
    ledger invariants hold through sharing (the pre-split counters could
    not balance once a block had two holders)."""
    pool = KVBlockPool(8, 2)
    a = pool.alloc()
    pool.retain(a)
    pool.free(a)                                     # drop, not release
    assert pool.stats.ref_drops == 1 and pool.stats.releases == 0
    pool.check_leaks()                               # ledger balances mid-run
    pool.free(a)
    assert pool.stats.ref_drops == 2 and pool.stats.releases == 1
    assert pool.stats.frees == pool.stats.releases   # back-compat alias
    assert pool.stats.allocs == 1 and pool.stats.retains == 1
    pool.check_leaks(expected_in_use=0)
    with pytest.raises(AssertionError):
        b = pool.alloc()
        pool.check_leaks(expected_in_use=0)          # b is still live
    pool.free(b)


# ---------------------------------------------------------------------------
# shared-prefix parity: streams identical with the cache on and off

def test_shared_prefix_parity_and_savings(backbone, ref_streams):
    """The tentpole acceptance: 8 requests sharing a system prompt stream
    token-identically with prefix_cache on, while prefill work and KV
    high-water strictly drop and the pool stays leak-free."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    off = BatchedOffloadEngine(model, params, None, n_total, max_batch=4,
                               block_size=4)
    off.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    on = BatchedOffloadEngine(model, params, None, n_total, max_batch=4,
                              block_size=4, prefix_cache=True)
    outs = on.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    assert outs == ref_streams
    st = on.prefix.stats
    assert st.hits > 0 and st.hit_tokens > 0
    # later waves match the whole system prompt at admission; the first
    # wave shares via chunk-boundary extension
    assert st.hits + st.extensions >= len(PROMPTS) - 1
    # prefill compute actually skipped, not just remapped
    assert on.stats.prefill_tokens < off.stats.prefill_tokens
    assert on.stats.prefill_tokens + st.hit_tokens >= \
        off.stats.prefill_tokens
    # shared blocks counted once: the working set shrinks
    assert on.pool.stats.high_water < off.pool.stats.high_water
    # leak-free with exactly the indexed blocks still alive
    on.pool.check_leaks(expected_in_use=on.prefix.cached_blocks)
    assert on.prefix.cached_blocks > 0


def test_shared_prefix_parity_across_block_sizes(backbone, ref_streams):
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    for bs in (2, 3, 8):
        eng = BatchedOffloadEngine(model, params, None, n_total,
                                   max_batch=4, block_size=bs,
                                   prefix_cache=True)
        outs = eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
        assert outs == ref_streams, f"diverged at block_size={bs}"
        eng.pool.check_leaks(expected_in_use=eng.prefix.cached_blocks)


def test_shared_prefix_parity_kernel_and_gather(backbone, ref_streams):
    """COW pages and matched-offset prefill behave identically on the
    flash-decode kernel route and the gather parity reference."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    for kw in ({"use_kernel": False}, {"kernel_backend": "jnp"}):
        eng = BatchedOffloadEngine(model, params, None, n_total,
                                   max_batch=4, block_size=4,
                                   prefix_cache=True, **kw)
        outs = eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
        assert outs == ref_streams, f"diverged with {kw}"


def test_shared_prefix_parity_gqa_stack(ref_streams):
    """A pure-GQA global-attention MoE stack (no MLA): paged K/V pools COW
    and share exactly like the latent pools."""
    import jax

    from repro.configs import get_reduced
    from repro.models import build_model

    cfg = get_reduced("llama4-scout-17b-a16e").replace(
        block_pattern=("global",), frontend=None)
    assert set(cfg.layer_kinds()) == {"global"}
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))       # untrained: parity only
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    prompts = [p[:20] for p in PROMPTS[:6]]
    base = BatchedOffloadEngine(model, params, None, n_total, max_batch=3,
                                block_size=4)
    refs = base.generate(prompts, max_new=4, cache_len=32)
    eng = BatchedOffloadEngine(model, params, None, n_total, max_batch=3,
                               block_size=4, prefix_cache=True)
    outs = eng.generate(prompts, max_new=4, cache_len=32)
    assert outs == refs
    assert eng.prefix.stats.hits + eng.prefix.stats.extensions > 0
    eng.pool.check_leaks(expected_in_use=eng.prefix.cached_blocks)


def test_prefix_cache_gated_off_for_ring_stacks():
    """Stacks with ring-buffer layers can't share KV through block tables;
    the knob silently stays off instead of corrupting streams."""
    import jax

    from repro.configs import get_reduced
    from repro.models import build_model

    cfg = get_reduced("llama4-scout-17b-a16e")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    eng = BatchedOffloadEngine(model, params, None, n_total, max_batch=2,
                               block_size=4, prefix_cache=True)
    assert eng.paged and not eng.prefix_enabled
    eng.generate([p[:6] for p in PROMPTS[:2]], max_new=3, cache_len=16)
    assert eng.prefix is None


def test_expert_replay_warms_cache_and_policy(backbone):
    """A prefix hit replays the recorded activations: the ExpertCache sees
    prefetches before the request computes anything, and rEAM-style policy
    state is warmed without running a predictor."""
    cfg, model, params, _ = backbone
    e = cfg.moe.num_experts
    n_moe = len(moe_layer_ids(cfg))
    eng = BatchedOffloadEngine(
        model, params, lambda: MoEInfinityPolicy([], n_moe, e, width=4),
        n_moe * e, max_batch=1, block_size=4, prefix_cache=True)
    # max_batch=1 serialises the requests within one run: the second can
    # only share via an admission-time index hit (no same-wave extension)
    outs = eng.generate([PROMPTS[0], PROMPTS[0]], max_new=MAX_NEW,
                        cache_len=CACHE_LEN)
    assert outs[1] == outs[0]                        # same prompt, greedy
    assert eng.prefix.stats.hits >= 1
    assert eng.prefix.stats.extensions == 0          # never co-resident
    assert eng.prefix.stats.hit_tokens >= len(SYS)
    # every indexed block carries the activations its prefill observed —
    # the payload replayed into the ExpertCache / policy on a hit
    nodes = eng.prefix.walk(PROMPTS[0], len(SYS) // 4)
    assert nodes and all(n.experts for n in nodes)
    assert all(len(ids) > 0 for n in nodes for ids in n.experts.values())


def test_cow_partial_block_match_parity(backbone):
    """Identical block-aligned prompts force a match into the middle of the
    last shared block (m = len-1 is mid-block): the writer COWs the shared
    page — including the device copy — and streams stay identical."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    prompt = (SYS + TAILS[3])[:28]                   # 7 whole blocks at bs=4
    assert len(prompt) % 4 == 0
    prompts = [prompt] * 3                           # 3rd hits at admission
    off = BatchedOffloadEngine(model, params, None, n_total, max_batch=2,
                               block_size=4)
    ref = off.generate(prompts, max_new=MAX_NEW, cache_len=CACHE_LEN)
    on = BatchedOffloadEngine(model, params, None, n_total, max_batch=2,
                              block_size=4, prefix_cache=True)
    outs = on.generate(prompts, max_new=MAX_NEW, cache_len=CACHE_LEN)
    assert outs == ref and ref[0] == ref[1] == ref[2]
    assert on.pool.stats.cow_copies > 0              # shared page privatised
    on.pool.check_leaks(expected_in_use=on.prefix.cached_blocks)


def test_prefix_eviction_under_pool_pressure(backbone, ref_streams):
    """A pool too small to hold every cached prefix: admission evicts
    zero-extra-ref prefixes instead of deadlocking, streams stay identical,
    and the final leak check accounts for what stayed indexed."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    bs = 4
    # just above the longest single request's worst case: the index's
    # accumulated tail blocks must be evicted for later admissions to fit
    worst = blocks_for(min(max(len(p) for p in PROMPTS) + MAX_NEW,
                           CACHE_LEN), bs)
    eng = BatchedOffloadEngine(model, params, None, n_total, max_batch=4,
                               block_size=bs, kv_blocks=worst + 4,
                               prefix_cache=True)
    outs = eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    assert outs == ref_streams
    assert eng.prefix.stats.evicted_blocks > 0       # pressure really hit
    eng.pool.check_leaks(expected_in_use=eng.prefix.cached_blocks)


def test_matched_blocks_survive_admission_eviction(backbone):
    """Regression: the admission evict-retry must not free the blocks the
    pending match returned (until adopted, the index's reference is their
    only one). Pool sized so the cached prefix IS the pool pressure: the
    match is given up and the request admits as a plain prefill instead of
    crashing ``run`` with a retain-of-freed-block error."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    bs = 4
    prompt = SYS[:20]                                # 5 whole blocks
    # worst case 6 blocks; pool of exactly 6 allocatable: after request 1
    # caches 5 blocks, request 2's match (5 bids, need 2, 1 free) cannot
    # be satisfied without evicting the matched path itself
    eng = BatchedOffloadEngine(model, params, None, n_total, max_batch=1,
                               block_size=bs, kv_blocks=7,
                               prefix_cache=True)
    outs = eng.generate([prompt, prompt], max_new=4, cache_len=24)
    assert outs[0] == outs[1]
    assert eng.prefix.stats.evicted_blocks > 0       # pressure path taken
    eng.pool.check_leaks(expected_in_use=eng.prefix.cached_blocks)

    # ample pool: same prompts, match survives — parity across both paths
    ample = BatchedOffloadEngine(model, params, None, n_total, max_batch=1,
                                 block_size=bs, prefix_cache=True)
    assert ample.generate([prompt, prompt], max_new=4,
                          cache_len=24) == outs


def test_prefix_cache_blocks_cap(backbone, ref_streams):
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    eng = BatchedOffloadEngine(model, params, None, n_total, max_batch=4,
                               block_size=4, prefix_cache=True,
                               prefix_cache_blocks=4)
    outs = eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    assert outs == ref_streams
    # holders can transiently exceed the cap; at rest it is enforced
    assert eng.prefix.cached_blocks <= 4


# ---------------------------------------------------------------------------
# admission bugfixes

def test_impossible_request_rejected_gracefully(backbone):
    """A request whose worst case exceeds the whole pool used to raise
    mid-run, abandoning every in-flight request with lanes held and blocks
    unreleased. Now: empty result, counted, run continues, no leaks."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    bs = 4
    eng = BatchedOffloadEngine(model, params, None, n_total, max_batch=2,
                               block_size=bs,
                               kv_blocks=blocks_for(8, bs) + 1)
    ok1 = eng.submit(PROMPTS[0][:5], max_new=3)      # worst case 2 blocks
    big = eng.submit(PROMPTS[1][:8], max_new=40)     # worst case > pool
    ok2 = eng.submit(PROMPTS[2][:5], max_new=3)      # must still run
    results = eng.run(cache_len=16)
    assert results[big] == []
    assert eng.stats.rejected_requests == 1
    assert len(results[ok1]) > 0 and len(results[ok2]) > 0
    eng.pool.check_leaks(expected_in_use=0)

    # parity: the same fitting requests through an ample pool
    ref = BatchedOffloadEngine(model, params, None, n_total, max_batch=2,
                               block_size=bs)
    r1 = ref.submit(PROMPTS[0][:5], max_new=3)
    r2 = ref.submit(PROMPTS[2][:5], max_new=3)
    ref_results = ref.run(cache_len=16)
    assert results[ok1] == ref_results[r1]
    assert results[ok2] == ref_results[r2]


def test_impossible_matched_request_rejected_without_wiping_index(backbone):
    """Regression: the whole-pool reject must use the request's FULL
    footprint, not the match-reduced reservation — otherwise an impossible
    request slips past the check and the eviction fallback destroys every
    cached prefix before it is finally rejected anyway."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    base = (SYS + TAILS[3] + SYS)[:40]               # 10 whole blocks
    eng = BatchedOffloadEngine(model, params, None, n_total, max_batch=1,
                               block_size=4, kv_blocks=11,
                               prefix_cache=True)
    ok = eng.submit(base, max_new=0)                 # footprint exactly 10
    big = eng.submit(base + TAILS[1], max_new=4)     # 12 blocks > pool
    results = eng.run(cache_len=52)
    assert results[big] == [] and eng.stats.rejected_requests == 1
    assert len(results[ok]) > 0
    assert eng.prefix.cached_blocks == 10            # index survived
    eng.pool.check_leaks(expected_in_use=eng.prefix.cached_blocks)


def test_submit_validation(backbone):
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    eng = BatchedOffloadEngine(model, params, None, n_total, max_batch=2)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([], max_new=4)
    with pytest.raises(ValueError, match="max_new"):
        eng.submit([1, 2], max_new=-1)
    one = OffloadEngine(model, params, None, n_total)
    with pytest.raises(ValueError, match="empty prompt"):
        one.generate([], max_new=4, cache_len=16)


def test_degenerate_cases_pinned_identical(backbone):
    """max_new=0, cache_len=0, and cache_len-truncated prompts retire the
    same way on the paged and row engines."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    cases = [
        ([3, 17, 5], 0, 24),        # max_new=0
        ([3], 0, 24),               # one-token prompt, max_new=0
        ([3, 17, 5], 4, 0),         # cache_len=0: zero steps admitted
        ([3], 4, 0),
        ([3, 17, 5, 9, 11], 4, 3),  # truncated mid-prompt
        ([3, 17, 5], 4, 3),         # cache_len == len(prompt)
    ]
    for prompt, max_new, cache_len in cases:
        paged = BatchedOffloadEngine(model, params, None, n_total,
                                     max_batch=2, block_size=4)
        rows = BatchedOffloadEngine(model, params, None, n_total,
                                    max_batch=2, paged=False)
        got_p = paged.generate([prompt], max_new=max_new,
                               cache_len=cache_len)
        got_r = rows.generate([prompt], max_new=max_new,
                              cache_len=cache_len)
        assert got_p == got_r, (prompt, max_new, cache_len)
        if paged.pool is not None:
            paged.pool.check_leaks(expected_in_use=0)


# ---------------------------------------------------------------------------
# property test: interleaved admit/match/COW/insert/retire/evict never
# double-frees or leaks (pure pool+index level, no model)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:
    OPS = st.sampled_from(
        ["admit", "grow", "cow", "insert", "release", "evict"])
    ACTIONS = st.lists(st.tuples(st.integers(0, 3), OPS),
                       min_size=1, max_size=60)

    def hyp_property(f):
        return settings(max_examples=200, deadline=None)(given(
            actions=ACTIONS, num_blocks=st.integers(4, 24),
            prompt_seed=st.integers(0, 3))(f))
else:
    def hyp_property(f):                         # hypothesis optional locally
        return pytest.mark.skip(reason="hypothesis not installed")(f)


@hyp_property
def test_prefix_pool_never_double_frees_or_leaks(actions, num_blocks,
                                                 prompt_seed):
    bs = 2
    pool = KVBlockPool(num_blocks, bs)
    pc = PrefixCache(pool)
    # 4 slots; slots share a prompt prefix pairwise so matches really occur
    prompts = [[(prompt_seed + s % 2) * 10 + i for i in range(8)]
               for s in range(4)]
    tables = {}
    pos = {}
    for slot, op in actions:
        if op == "admit" and slot not in tables:
            limit = len(prompts[slot]) - 1
            m = pc.match(prompts[slot], limit)
            need = (blocks_for(len(prompts[slot]), bs) - len(m.bids)
                    + (1 if m.tokens % bs else 0))
            if pool.try_reserve(max(0, need)):
                t = BlockTable(pool, max(0, need))
                t.adopt(m.bids)
                tables[slot] = t
                pos[slot] = m.tokens
        elif op == "grow" and slot in tables:
            t, p = tables[slot], pos[slot]
            if p < len(prompts[slot]):
                idx = p // bs
                if (idx < len(t.ids) and t.is_shared(idx)
                        and t.reserved + pool.available > 0):
                    t.make_private(idx)          # device copy not modeled
                need = idx + 1 - len(t.ids)
                if need <= t.reserved + pool.available:
                    t.ensure(p)
                    pos[slot] = p + 1
        elif op == "cow" and slot in tables:
            # privatise MORE than the scheduler ever would (it only COWs
            # the block a write targets) — the refcount ledger must hold
            for idx in range(len(tables[slot].ids)):
                if tables[slot].reserved + pool.available > 0:
                    tables[slot].make_private(idx)
        elif op == "insert" and slot in tables:
            n = min(pos[slot], len(prompts[slot])) // bs
            # only fully-written private prompt blocks are publishable
            n = min(n, len(tables[slot].ids))
            if n > 0:
                pc.insert(prompts[slot], n, tables[slot].ids, {})
        elif op == "release" and slot in tables:
            tables[slot].release()
            del tables[slot], pos[slot]
        elif op == "evict":
            pc.evict(2)
        pool.check_leaks()                       # invariants after EVERY op
        held = sum(len(t.ids) for t in tables.values())
        # cached-only blocks + held blocks cover everything allocated, with
        # shared blocks counted once
        assert pool.blocks_in_use <= held + pc.cached_blocks
    for t in tables.values():
        t.release()
    pc.evict(pc.cached_blocks)
    pool.check_leaks(expected_in_use=0)
    assert pool.reserved == 0


# ---------------------------------------------------------------------------
# sub-block (partial tail) matching

def test_index_tail_insert_and_match():
    """Partial tail blocks are indexed and matched on the longest common
    prefix — the sub-block keys whole-block tries could never share."""
    pool, pc = _mk(bs=4)
    toks = list(range(40, 50))                       # 2 whole blocks + 2
    bids = _stash(pool, 3)
    added = pc.insert(toks, 2, bids, {2: {0: {5}}}, tail_len=2)
    assert added == 3 and pc.stats.inserted_tails == 1
    assert pc.cached_blocks == 3
    assert pool.ref_count(bids[2]) == 2

    # full tail match: 10 of 10 positions covered
    m = pc.match(toks + [7, 7], limit=12)
    assert m.tokens == 10 and m.bids == bids
    assert m.experts[0].tolist() == [5]
    # partial tail match: common prefix of the tail only
    m2 = pc.match(toks[:9] + [999, 999], limit=12)
    assert m2.tokens == 9 and m2.bids == bids
    # limit caps inside the tail
    assert pc.match(toks, limit=9).tokens == 9
    # a longer competing tail wins
    bid4 = _stash(pool, 1)[0]
    pc.insert(toks[:8] + [50, 51, 52], 2, bids[:2] + [bid4], {}, tail_len=3)
    assert pc.match(toks[:8] + [50, 51, 52, 53], limit=12).tokens == 11
    # idempotent tail re-insert
    assert pc.insert(toks, 2, bids, {}, tail_len=2) == 0


def test_index_tail_eviction_last():
    """Tail nodes are leaves: they evict before their parents, and a node
    with only tail children is protected like any inner node."""
    pool, pc = _mk(bs=4)
    toks = list(range(6))
    bids = _stash(pool, 2)
    pc.insert(toks, 1, bids, {}, tail_len=2)
    for bid in bids:
        pool.free(bid)                               # "request retired"
    assert pc.evict(1) == 1                          # the tail goes first
    assert pc.cached_blocks == 1
    assert pc.match(toks, limit=6).tokens == 4       # whole block remains
    assert pc.evict(1) == 1
    assert pc.cached_blocks == 0
    pool.check_leaks(expected_in_use=0)


def test_sub_block_prefix_parity_and_savings(backbone):
    """Prompts sharing a NON-block-aligned prefix (6 tokens at bs=4):
    whole-block matching alone could share only 4; sub-block matching
    shares the partial tail too — streams must stay token-identical and
    hit_tokens must exceed the block-aligned bound."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    shared = SYS[:6]                                 # 1.5 blocks at bs=4
    prompts = [shared + t for t in TAILS[:6]]
    off = BatchedOffloadEngine(model, params, None, n_total, max_batch=2,
                               block_size=4)
    ref = off.generate(prompts, max_new=MAX_NEW, cache_len=CACHE_LEN)
    on = BatchedOffloadEngine(model, params, None, n_total, max_batch=2,
                              block_size=4, prefix_cache=True)
    outs = on.generate(prompts, max_new=MAX_NEW, cache_len=CACHE_LEN)
    assert outs == ref
    st = on.prefix.stats
    assert st.inserted_tails > 0                     # tails really indexed
    # at least one late admission matched past the whole-block boundary:
    # more tokens skipped than whole-block matching could ever deliver
    n_hit_waves = len(prompts) - 2                   # first wave must miss
    assert st.hit_tokens > 4 * n_hit_waves
    assert on.pool.stats.cow_copies > 0              # tail adopts COWed
    on.pool.check_leaks(expected_in_use=on.prefix.cached_blocks)
