"""Optimizer substrate: AdamW convergence, clipping, layerwise LR groups,
loss scaler, checkpoint round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.predictor import predictor_lr_fn
from repro.training.checkpoint import load, save
from repro.training.optimizer import (DynamicLossScaler, clip_by_global_norm,
                                      cosine_schedule, make_adamw)


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0]), "b": jnp.asarray([2.0])}
    oi, ou = make_adamw(lr=0.1, weight_decay=0.0, clip=0.0)
    st = oi(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, st, _ = ou(g, st, params)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 10}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    from repro.training.optimizer import global_norm
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_layerwise_lr_groups():
    """Params in different groups move at different rates (paper §3.2.3)."""
    params = {"in_w": jnp.ones((4,)), "enc_w": jnp.ones((4,)),
              "head_w1": jnp.ones((4,))}
    lr_fn = predictor_lr_fn(1e-2)
    assert lr_fn("in_w") == pytest.approx(1e-2)
    assert lr_fn("enc/0/wq") == pytest.approx(0.9e-2)
    assert lr_fn("head_w1") == pytest.approx(0.8e-2)
    oi, ou = make_adamw(lr=lr_fn, weight_decay=0.0, clip=0.0)
    st = oi(params)
    g = jax.tree.map(jnp.ones_like, params)
    p2, _, _ = ou(g, st, params)
    d_in = float(jnp.abs(params["in_w"] - p2["in_w"]).mean())
    d_head = float(jnp.abs(params["head_w1"] - p2["head_w1"]).mean())
    assert d_in > d_head  # input group has the larger LR


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(100))) <= 0.1 + 1e-6


def test_loss_scaler():
    sc = DynamicLossScaler(init_scale=8.0, growth_interval=2, enabled=True)
    g = {"w": jnp.asarray([8.0, 16.0])}
    unscaled, finite = sc.unscale_and_check(g)
    assert bool(finite)
    np.testing.assert_allclose(np.asarray(unscaled["w"]), [1.0, 2.0])
    sc.update(True)
    sc.update(True)
    assert sc.scale == 16.0
    bad = {"w": jnp.asarray([jnp.inf])}
    _, finite = sc.unscale_and_check(bad)
    assert not bool(finite)
    sc.update(False)
    assert sc.scale == 8.0
    # disabled scaler is identity
    sc2 = DynamicLossScaler(enabled=False)
    assert sc2.scale == 1.0


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "lst": [jnp.zeros((2,)), jnp.ones((2,))]}
    p = os.path.join(tmp_path, "ck.npz")
    save(p, tree)
    restored = load(p, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
        assert a.dtype == b.dtype
