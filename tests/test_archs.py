"""Per-assigned-architecture smoke tests: reduced variant of the same family
runs one forward/train step and one decode step on CPU; shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, get_reduced
from repro.models import build_model
from repro.training.optimizer import make_adamw

from helpers import make_batch

ALL_ARCHS = list(ASSIGNED_ARCHS) + ["deepseek-v2-lite"]


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, batch=2, seq=64)

    logits = model.forward(params, batch)
    exp_t = 64 + (cfg.frontend_len if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, exp_t, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    oi, ou = make_adamw(lr=1e-3, clip=1.0)
    ost = oi(params)

    def lf(p):
        return model.loss_fn(p, batch)

    (loss, mets), grads = jax.value_and_grad(lf, has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    p2, _, stats = ou(grads, ost, params)
    assert bool(jnp.isfinite(stats["grad_norm"]))
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = model.init_decode_state(2, 96)
    tok = {"tokens": jnp.ones((2, 1), jnp.int32)}
    logits, state2 = model.decode_step(params, state, tok)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(state2["pos"]) == 1
    logits2, _ = model.decode_step(params, state2, tok)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    """The full (non-reduced) config carries the assigned hyper-parameters."""
    spec = {
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "mamba2-130m": (24, 768, 24, 24, 0, 50280),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "deepseek-v2-lite": (27, 2048, 16, 16, 1408, 102400),
    }[arch]
    c = get_config(arch)
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == spec


def test_moe_configs():
    c = get_config("deepseek-v2-236b")
    assert c.moe.num_experts == 160 and c.moe.top_k == 6
    assert c.moe.num_shared == 2
    assert c.mla.kv_lora_rank == 512
    c = get_config("llama4-scout-17b-a16e")
    assert c.moe.num_experts == 16 and c.moe.top_k == 1
    c = get_config("mamba2-130m")
    assert c.ssm.d_state == 128
