"""Unit tests for the paper's core: EAM/EAMC, cache, metrics, simulator."""
import numpy as np
import pytest

from repro.core.cache import ExpertCache
from repro.core.eam import EAMC, REAMBuilder, build_ream, kmeans
from repro.core.metrics import (elementwise_accuracy, exact_set_accuracy,
                                macro_f1, select_experts)
from repro.core.policies import NoPrefetchPolicy, OraclePolicy
from repro.core.simulator import SimConfig, simulate
from repro.core.tracing import Trace


def make_trace(t=20, layers=3, k=2, e=8, seed=0, emb=4):
    rng = np.random.default_rng(seed)
    return Trace(
        tokens=rng.integers(0, 100, t).astype(np.int32),
        embeddings=rng.normal(size=(t, emb)).astype(np.float32),
        experts=rng.integers(0, e, (t, layers, k)).astype(np.int32),
        prompt_len=4,
    )


# --------------------------------------------------------------- EAM / EAMC
def test_ream_builder():
    b = REAMBuilder(3, 8)
    b.add(0, [1, 2])
    b.add(0, [2])
    b.add(2, [7])
    assert b.counts[0, 2] == 2 and b.counts[0, 1] == 1
    assert b.counts[2, 7] == 1
    assert abs(np.linalg.norm(b.flat()) - 1) < 1e-9


def test_build_ream_counts():
    tr = make_trace(t=10, layers=2, k=3, e=5)
    r = build_ream(tr, 2, 5)
    assert r.sum() == 10 * 2 * 3
    r4 = build_ream(tr, 2, 5, upto_token=4)
    assert r4.sum() == 4 * 2 * 3


def test_kmeans_separates_clusters():
    rng = np.random.default_rng(0)
    a = rng.normal(loc=5, size=(20, 6))
    b = rng.normal(loc=-5, size=(20, 6))
    x = np.concatenate([a, b])
    cents, assign = kmeans(x, 2, seed=1)
    assert len(set(assign[:20])) == 1
    assert len(set(assign[20:])) == 1
    assert assign[0] != assign[20]


def test_eamc_match_returns_nearest():
    reams = [np.zeros((2, 4)), np.zeros((2, 4))]
    reams[0][0, 0] = 10.0
    reams[1][1, 3] = 10.0
    c = EAMC(2, 4, capacity=8)
    c.fit(reams)
    q = np.zeros((2, 4))
    q[0, 0] = 3.0
    m = c.match(q)
    assert m[0, 0] > 0 and m[1, 3] == 0
    pred = c.predict_layer(q, 0, width=2)
    assert 0 in pred


# --------------------------------------------------------------------- cache
def test_lru_eviction_order():
    c = ExpertCache(2, "lru")
    c.access("a")
    c.access("b")
    c.access("a")      # refresh a
    c.access("c")      # evicts b
    assert "a" in c and "c" in c and "b" not in c


def test_lfu_eviction():
    c = ExpertCache(2, "lfu")
    for _ in range(3):
        c.access("hot")
    c.access("cold1")
    c.access("cold2")  # evicts cold1 (freq 1 < hot 3)
    assert "hot" in c and "cold2" in c and "cold1" not in c


def test_prefetch_counts():
    c = ExpertCache(4, "lru")
    c.prefetch(["a", "b"])
    assert c.stats.prefetches == 2 and c.stats.accesses == 0
    assert c.access("a") and c.stats.prefetch_hits == 1
    assert not c.access("z")
    assert c.stats.demand_fetches == 1


def test_reprefetch_is_noop_hit():
    """Regression: re-prefetching a resident key must not count as a fresh
    insert, touch slot callbacks, or change provenance — but it DOES
    refresh recency (intent-to-use eviction protection)."""
    fills = []
    c = ExpertCache(2, "lru", on_insert=fills.append)
    c.prefetch(["a"])
    c.prefetch(["a", "a"])
    assert c.stats.prefetches == 1
    assert c.stats.redundant_prefetches == 2
    assert fills == ["a"]                  # the slot was filled exactly once
    # provenance survives a re-prefetch of a demand-fetched entry
    c.access("b")                          # miss -> demand insert
    c.prefetch(["b"])
    assert c.stats.redundant_prefetches == 3
    assert c.access("b")
    assert c.stats.prefetch_hits == 0      # still counted as a demand entry
    # recency IS refreshed: a re-prefetch declares intent-to-use, so the
    # key survives the next eviction instead of the older resident
    c.prefetch(["a"])
    c.access("d")                          # evicts b (oldest), not a
    assert "a" in c and "b" not in c and "d" in c
    assert c.stats.prefetches == 1         # still exactly one real insert


# ------------------------------------------------------------------- metrics
def test_select_experts_topk_threshold():
    logits = np.array([[4.0, 3.0, -5.0, 0.2, -0.2]])
    sel = select_experts(logits, top_k=3, threshold=0.5)
    # top-3 by prob = {0,1,3}; 3 has sigmoid(0.2)=.55>.5 -> kept
    assert sel[0].tolist() == [True, True, False, True, False]
    sel2 = select_experts(logits, top_k=1, threshold=0.5)
    assert sel2[0].tolist() == [True, False, False, False, False]


def test_metrics_perfect_and_disjoint():
    true = np.zeros((4, 6), bool)
    true[:, 0] = True
    assert elementwise_accuracy(true, true) == 1.0
    assert exact_set_accuracy(true, true) == 1.0
    assert macro_f1(true, true) == pytest.approx(1.0 / 6)  # only expert 0 has support
    pred = np.zeros_like(true)
    pred[:, 1] = True
    assert exact_set_accuracy(pred, true) == 0.0
    assert elementwise_accuracy(pred, true) == pytest.approx(4 / 6)


# ----------------------------------------------------------------- simulator
def test_oracle_beats_noprefetch_and_hits_100():
    traces = [make_trace(seed=s) for s in range(3)]
    sim = SimConfig(num_layers=3, num_experts=8, capacity_fraction=0.5,
                    warm_tokens=2)
    r_oracle = simulate(traces, OraclePolicy(), sim)
    r_none = simulate(traces, NoPrefetchPolicy(), sim)
    assert r_oracle.cache_hit_rate == pytest.approx(1.0)
    assert r_oracle.prediction_hit_rate == pytest.approx(1.0)
    assert r_none.cache_hit_rate < 1.0
    assert r_oracle.cache_hit_rate >= r_none.cache_hit_rate


def test_simulator_full_capacity_all_hits_after_warm():
    """With capacity = everything, misses only happen on first-ever use."""
    tr = make_trace(t=30, layers=2, k=2, e=4, seed=1)
    sim = SimConfig(num_layers=2, num_experts=4, capacity_fraction=1.0,
                    warm_tokens=10)
    r = simulate([tr], NoPrefetchPolicy(), sim)
    # after 10 warm tokens every (layer, expert) pair has been touched with
    # high probability; allow the rare cold pair
    assert r.cache_hit_rate > 0.9


def test_simulator_counts_tokens():
    tr = make_trace(t=25)
    sim = SimConfig(num_layers=3, num_experts=8, capacity_fraction=0.2)
    r = simulate([tr], NoPrefetchPolicy(), sim)
    assert r.tokens == 25
