"""Serving engine: the offloaded layer-by-layer decode must be numerically
identical to the monolithic decode_step, and the cache accounting sane."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policies import NextLayerAllPolicy, NoPrefetchPolicy
from repro.core.tracing import moe_layer_ids
from repro.serving.engine import OffloadEngine
from repro.serving.offload import HostExpertStore, make_offload_cache

from helpers import tiny_backbone


@pytest.fixture(scope="module")
def backbone():
    return tiny_backbone()


def test_engine_matches_monolithic_decode(backbone):
    cfg, model, params, corpus = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    engine = OffloadEngine(model, params, policy=None, capacity=n_total)

    toks = [3, 17, 99, 255, 7, 42]
    state_ref = model.init_decode_state(1, 16)
    state_eng = engine.init_state(16)
    step_fn = jax.jit(model.decode_step)
    for t, tok in enumerate(toks):
        ref_logits, state_ref = step_fn(
            params, state_ref, {"tokens": jnp.full((1, 1), tok, jnp.int32)})
        eng_logits, state_eng, _ = engine.decode_token(state_eng, tok)
        np.testing.assert_allclose(eng_logits, np.asarray(ref_logits)[0],
                                   rtol=2e-4, atol=2e-4, err_msg=f"tok {t}")
    # full capacity: after first touch, everything hits
    assert engine.stats.hit_rate > 0.0


def test_engine_small_cache_misses_and_stalls(backbone):
    cfg, model, params, corpus = backbone
    engine = OffloadEngine(model, params, policy=NoPrefetchPolicy(),
                           capacity=2)
    state = engine.init_state(16)
    for tok in [3, 17, 99, 255]:
        engine.decode_token(state, tok)
    s = engine.stats
    assert s.misses > 0
    assert s.fetch_bytes > 0
    assert s.sim_stall_s > 0
    assert 0.0 <= s.hit_rate < 1.0


def test_engine_prefetch_all_reduces_misses(backbone):
    cfg, model, params, corpus = backbone
    e = cfg.moe.num_experts
    n_layers = len(moe_layer_ids(cfg))
    cap = max(2, (n_layers * e) // 2)

    eng_none = OffloadEngine(model, params, NoPrefetchPolicy(), cap)
    eng_all = OffloadEngine(model, params, NextLayerAllPolicy(e), cap)
    toks = [3, 17, 99, 255, 7, 42, 13, 5]
    s1, s2 = eng_none.init_state(16), eng_all.init_state(16)
    for tok in toks:
        l1, s1, _ = eng_none.decode_token(s1, tok)
        l2, s2, _ = eng_all.decode_token(s2, tok)
        # prefetching must never change the computed logits
        np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)
    assert eng_all.stats.hit_rate >= eng_none.stats.hit_rate


def test_slot_buffer_mechanics(backbone):
    cfg, model, params, _ = backbone
    from repro.serving.engine import unstack_layers
    layers = unstack_layers(cfg, params)
    moe_layers = [layers[i]["moe"] for i in moe_layer_ids(cfg)]
    store = HostExpertStore(moe_layers)
    cache, buf = make_offload_cache(store, capacity=3)

    cache.access((0, 1))
    cache.access((0, 2))
    assert (0, 1) in buf.slot_of and (0, 2) in buf.slot_of
    wg, wu, wd = buf.gather([(0, 1), (0, 2)])
    np.testing.assert_allclose(np.asarray(wg[0]),
                               store.layers[0]["w_gate"][1])
    np.testing.assert_allclose(np.asarray(wd[1]),
                               store.layers[0]["w_down"][2])
    # eviction releases slots
    cache.access((1, 0))
    cache.access((1, 1))            # capacity 3 -> evicts (0,1)
    assert (0, 1) not in buf.slot_of
    assert len(buf.slot_of) == 3
    assert buf.fetch_count == 4


def test_engine_pallas_expert_backend(backbone):
    """The engine's expert compute via the Pallas kernel (interpret mode)
    must match the jnp backend — the TPU deployment path, exercised live."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    eng_jnp = OffloadEngine(model, params, None, n_total,
                            expert_backend="jnp")
    eng_pal = OffloadEngine(model, params, None, n_total,
                            expert_backend="pallas")
    s1, s2 = eng_jnp.init_state(8), eng_pal.init_state(8)
    for tok in [3, 17, 99]:
        l1, s1, _ = eng_jnp.decode_token(s1, tok)
        l2, s2, _ = eng_pal.decode_token(s2, tok)
        np.testing.assert_allclose(l1, l2, rtol=2e-4, atol=2e-4)
