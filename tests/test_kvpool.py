"""KVBlockPool: alloc/free/refcount/reservation invariants.

The hypothesis property test drives random interleavings of request
lifetimes (reserve -> grow -> release) against one pool and asserts no
interleaving can double-free or leak a block; it skips cleanly when
hypothesis isn't installed (CI installs it)."""
import pytest

from repro.serving.kvpool import (SCRATCH_BLOCK, BlockTable, KVBlockPool,
                                  blocks_for)


def test_blocks_for():
    assert [blocks_for(n, 4) for n in (0, 1, 4, 5, 8, 9)] == \
        [0, 1, 1, 2, 2, 3]


def test_alloc_free_refcount():
    p = KVBlockPool(4, block_size=2)          # 3 allocatable + scratch
    a = p.alloc()
    b = p.alloc()
    assert a != b and SCRATCH_BLOCK not in (a, b)
    assert p.blocks_in_use == 2 and p.num_free == 1
    p.retain(a)
    p.free(a)                                  # refcount 2 -> 1: still held
    assert p.blocks_in_use == 2
    p.free(a)
    assert p.blocks_in_use == 1 and p.num_free == 2
    with pytest.raises(RuntimeError, match="double free"):
        p.free(a)
    with pytest.raises(RuntimeError, match="unallocated"):
        p.retain(a)
    p.free(b)
    p.check_leaks()
    assert p.stats.allocs == 2 and p.stats.frees == 2
    assert p.stats.high_water == 2


def test_pool_exhaustion_and_reservation():
    p = KVBlockPool(4, block_size=2)
    assert p.try_reserve(2)
    assert p.available == 1
    assert not p.try_reserve(2)                # only 1 unpromised block left
    assert p.stats.failed_reserves == 1
    p.alloc()                                  # unreserved alloc uses the 1
    with pytest.raises(RuntimeError, match="exhausted"):
        p.alloc()                              # rest is promised elsewhere
    assert p.alloc(reserved=True) is not None  # promised capacity still works
    p.unreserve(1)
    with pytest.raises(RuntimeError):
        p.unreserve(1)


def test_block_table_growth_and_release():
    p = KVBlockPool(6, block_size=4)
    assert p.try_reserve(2)                    # admission promises 2 blocks
    t = BlockTable(p, reserved_blocks=2)
    t.ensure(0)
    assert len(t) == 1 and t.num_positions == 4
    t.ensure(3)                                # same block
    assert len(t) == 1
    t.ensure(11)                               # grows to 3 blocks: 2 from
    assert len(t) == 3                         # the reservation, 1 open
    assert p.reserved == 0
    padded = t.padded(5)
    assert padded.tolist()[:3] == t.ids and set(padded[3:]) == {SCRATCH_BLOCK}
    with pytest.raises(ValueError):
        t.padded(2)
    t.release()
    p.check_leaks()
    assert p.num_free == 5 and p.blocks_in_use == 0


def test_block_table_scratch_never_allocated():
    p = KVBlockPool(8, block_size=1)
    t = BlockTable(p)
    t.ensure(6)
    assert SCRATCH_BLOCK not in t.ids
    t.release()


# ---------------------------------------------------------------------------
# property test: no interleaving of alloc/free/grow double-frees or leaks

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:
    # a program is a sequence of (request_slot, op) actions over 4 slots
    ACTIONS = st.lists(
        st.tuples(st.integers(0, 3),
                  st.sampled_from(["admit", "grow", "grow_big", "release"])),
        min_size=1, max_size=60)

    def hyp_property(f):
        return settings(max_examples=200, deadline=None)(given(
            actions=ACTIONS, num_blocks=st.integers(2, 24),
            block_size=st.integers(1, 8))(f))
else:
    def hyp_property(f):                         # hypothesis optional locally
        return pytest.mark.skip(reason="hypothesis not installed")(f)


@hyp_property
def test_pool_never_double_frees_or_leaks(actions, num_blocks, block_size):
    pool = KVBlockPool(num_blocks, block_size)
    tables = {}
    pos = {}
    for slot, op in actions:
        if op == "admit" and slot not in tables:
            need = min(2, pool.available)
            if pool.try_reserve(need):
                tables[slot] = BlockTable(pool, need)
                pos[slot] = 0
        elif op in ("grow", "grow_big") and slot in tables:
            step = block_size if op == "grow" else 3 * block_size
            target = pos[slot] + step
            need = blocks_for(target + 1, block_size) - len(tables[slot])
            # grow only when the pool can actually serve it (the scheduler's
            # reservation discipline guarantees this in the engine)
            if need <= tables[slot].reserved + pool.available:
                tables[slot].ensure(target)
                pos[slot] = target
        elif op == "release" and slot in tables:
            tables[slot].release()
            del tables[slot], pos[slot]
        # global invariants hold after EVERY action
        pool.check_leaks()
        held = sum(len(t) for t in tables.values())
        assert held == pool.blocks_in_use
        assert pool.num_free + pool.blocks_in_use == num_blocks - 1
    for t in tables.values():
        t.release()
    pool.check_leaks()
    assert pool.blocks_in_use == 0 and pool.reserved == 0
    assert pool.num_free == num_blocks - 1
