"""Shared test fixtures: a tiny trained MoE backbone + traces (session-cached
so the expensive pipeline runs once)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.tracing import collect_traces
from repro.data import make_topic_corpus, sample_prompts
from repro.models import build_model
from repro.training.optimizer import make_adamw


def make_batch(cfg, batch=2, seq=64, seed=0):
    rng = np.random.default_rng(seed)
    out = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)}
    if cfg.frontend == "vision":
        out["patches"] = jnp.asarray(
            rng.normal(size=(batch, cfg.frontend_len, cfg.frontend_dim)),
            jnp.float32)
    if cfg.frontend == "audio":
        out["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.frontend_len, cfg.frontend_dim)),
            jnp.float32)
    return out


@functools.lru_cache(maxsize=1)
def tiny_backbone(steps: int = 60):
    """Train the reduced DeepSeek-V2-Lite backbone briefly; return
    (cfg, model, params, corpus)."""
    cfg = get_reduced("deepseek-v2-lite")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    corpus = make_topic_corpus(cfg.vocab_size, n_topics=4, seed=0)
    oi, ou = make_adamw(lr=3e-3, clip=1.0)
    ost = oi(params)

    from repro.data import lm_batches

    @jax.jit
    def step(params, ost, tokens):
        def lf(p):
            return model.loss_fn(p, {"tokens": tokens})
        (l, m), g = jax.value_and_grad(lf, has_aux=True)(params)
        params, ost, _ = ou(g, ost, params)
        return params, ost, l

    for tokens in lm_batches(corpus, 16, 64, steps, seed=1):
        params, ost, _ = step(params, ost, jnp.asarray(tokens[:, :64]))
    return cfg, model, params, corpus


@functools.lru_cache(maxsize=1)
def tiny_traces(n: int = 10):
    cfg, model, params, corpus = tiny_backbone()
    prompts = sample_prompts(corpus, n, 12, seed=2)
    traces = collect_traces(model, params, prompts, max_new=36, cache_len=64)
    return cfg, model, params, traces
