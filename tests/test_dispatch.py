"""Expert-parallel compute dispatch: the fetch-vs-ship roofline cost
model, ship accounting (access without insert) at the store/ledger level,
token-identical streams across ``dispatch`` modes (including int8 cold
tiers), stall reduction in the cold-expert regime, and the real
``all_to_all`` mesh program (multi-device lane in CI)."""
import jax
import numpy as np
import pytest

from repro.core.tracing import moe_layer_ids
from repro.serving.expertstore import (DispatchPlanner, TierConfig,
                                       TieredExpertStore)
from repro.serving.offload import (CHANNEL_SHIP, TIER_DISK, TIER_HOST,
                                   TIER_PEER, HostExpertStore,
                                   OverlapTracker)

from helpers import tiny_backbone
from test_expertstore import make_store_layers

PROMPTS = [[3, 17, 5], [99, 255, 7, 42], [13, 5], [21, 8, 9]]
MAX_NEW = 6
CACHE_LEN = 16


def make_planner(mode="auto", weight_bytes=1_000_000, act=256,
                 per_tok=1e-7, base=1e-6, lat=20e-6, bw=25e9):
    return DispatchPlanner(weight_bytes=weight_bytes,
                           act_bytes_per_token=act, ffn_s_per_token=per_tok,
                           ffn_s_base=base, peer_latency_s=lat, peer_bw=bw,
                           mode=mode)


# ---------------------------------------------------------------------------
# cost model

def test_planner_breakeven_is_single_crossover():
    """ship_s grows with tokens while fetch_s is flat, so auto has exactly
    one breakeven: ship below it, fetch above it, never a flip back."""
    p = make_planner()
    assert p.choose(1) == "ship"          # few tokens: activations are tiny
    assert p.choose(10**6) == "fetch"     # a flood of tokens: move weights
    decisions = [p.choose(t) for t in range(1, 5000)]
    flips = sum(1 for a, b in zip(decisions, decisions[1:]) if a != b)
    assert flips == 1
    assert decisions[0] == "ship" and decisions[-1] == "fetch"


def test_planner_forced_modes_and_bytes():
    assert make_planner(mode="fetch").choose(1) == "fetch"
    assert make_planner(mode="ship").choose(10**6) == "ship"
    p = make_planner()
    assert p.ship_bytes(3) == 3 * p.act_bytes_per_token


def _random_planner(rng):
    return make_planner(
        weight_bytes=int(rng.integers(1, 10**9)),
        act=int(rng.integers(1, 10**5)),
        per_tok=float(rng.uniform(0, 1e-3)),
        base=float(rng.uniform(0, 1e-2)),
        lat=float(rng.uniform(0, 1e-2)),
        bw=float(rng.uniform(1e3, 1e12)))


def test_planner_properties_seeded_sweep():
    """The hypothesis properties below, as a deterministic seeded sweep so
    the invariants run even where hypothesis isn't installed."""
    rng = np.random.default_rng(0)
    for _ in range(500):
        p = _random_planner(rng)
        t = int(rng.integers(1, 10**6))
        dt = int(rng.integers(1, 10**4))
        dw = int(rng.integers(1, 10**8))
        assert p.ship_s(t + dt) >= p.ship_s(t)
        heavier = make_planner(weight_bytes=p.weight_bytes + dw,
                               act=p.act_bytes_per_token,
                               per_tok=p.ffn_s_per_token, base=p.ffn_s_base,
                               lat=p.peer_latency_s, bw=p.peer_bw)
        assert heavier.fetch_s() > p.fetch_s()
        cost = {"fetch": p.fetch_s(), "ship": p.ship_s(t)}
        assert cost[p.choose(t)] == min(cost.values())


def test_planner_properties():
    """Monotonicity + auto-never-strictly-worse, over random rooflines."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    planners = st.builds(
        make_planner,
        weight_bytes=st.integers(min_value=1, max_value=10**9),
        act=st.integers(min_value=1, max_value=10**5),
        per_tok=st.floats(min_value=0, max_value=1e-3),
        base=st.floats(min_value=0, max_value=1e-2),
        lat=st.floats(min_value=0, max_value=1e-2),
        bw=st.floats(min_value=1e3, max_value=1e12))

    @settings(deadline=None, max_examples=200)
    @given(p=planners, t=st.integers(min_value=1, max_value=10**6),
           dt=st.integers(min_value=1, max_value=10**4),
           dw=st.integers(min_value=1, max_value=10**8))
    def run(p, t, dt, dw):
        # ship cost is monotone in token count
        assert p.ship_s(t + dt) >= p.ship_s(t)
        # fetch cost is monotone in weight bytes
        heavier = make_planner(weight_bytes=p.weight_bytes + dw,
                               act=p.act_bytes_per_token,
                               per_tok=p.ffn_s_per_token, base=p.ffn_s_base,
                               lat=p.peer_latency_s, bw=p.peer_bw)
        assert heavier.fetch_s() > p.fetch_s()
        # auto never picks the strictly more expensive path
        cost = {"fetch": p.fetch_s(), "ship": p.ship_s(t)}
        assert cost[p.choose(t)] == min(cost.values())

    run()


# ---------------------------------------------------------------------------
# store/ledger: a ship is an access, never an insert

def _peer_keys(store):
    return [k for k in sorted(store.home_shard)
            if store.ledger.home(k)[1] == TIER_PEER]


def test_ship_serves_fetch_identical_bytes_without_residency_change():
    layers = make_store_layers()
    ref = HostExpertStore(layers)
    tc = TierConfig(num_shards=3, shard_dram_experts=2, cache_experts=2,
                    dispatch="auto")
    store = TieredExpertStore(layers, tc)
    key = _peer_keys(store)[0]
    before_cache = set(store._cache)
    before_copies = store.ledger.cached_tiers(key)
    w = store.ship(key, tokens=3, wire_bytes=96)
    for a, b in zip(w, ref.get(key)):
        np.testing.assert_array_equal(a, b)
    # accounting happened ...
    assert store.stats.ships == 1
    assert store.stats.ship_bytes == 96
    assert store.stats.ship_tokens == 3
    assert store.ledger.accesses(key) == 1
    # ... but residency did not move: no promotion, no tier-0/1 insert
    assert store.tier_of(key) == TIER_PEER
    assert set(store._cache) == before_cache
    assert store.ledger.cached_tiers(key) == before_copies
    assert store.stats.promotions == 0
    store.ledger.check()
    store.close()


def test_ship_refreshes_existing_cached_copy_and_rejects_non_peer():
    layers = make_store_layers()
    tc = TierConfig(num_shards=3, shard_dram_experts=2, cache_experts=2,
                    dispatch="ship")
    store = TieredExpertStore(layers, tc)
    k0, k1 = _peer_keys(store)[:2]
    store.fetch(k0)                      # promotes a tier-1 copy of k0
    store.fetch(k1)                      # then k1 — k0 is now LRU victim
    store.ship(k0, tokens=1, wire_bytes=32)
    assert next(iter(store._cache)) == k1   # ship refreshed k0's recency
    local = next(k for k in sorted(store.home_shard)
                 if store.ledger.home(k)[1] == TIER_HOST)
    with pytest.raises(AssertionError):
        store.ship(local, tokens=1, wire_bytes=32)
    store.close()


def test_ship_int8_serves_dequantized_cold_copy():
    """With int8 cold tiers the ship computes against the peer's
    dequantized copy — the exact bytes a fetch would deliver — pinning
    the 'ship against the dequantized peer copy' choice."""
    layers = make_store_layers()
    tc = TierConfig(num_shards=3, shard_dram_experts=2, cache_experts=0,
                    cold_dtype="int8", dispatch="auto")
    store = TieredExpertStore(layers, tc)
    fetch_store = TieredExpertStore(layers, tc)
    key = _peer_keys(store)[0]
    shipped = store.ship(key, tokens=2, wire_bytes=64)
    fetched = fetch_store.fetch(key)[0]
    for a, b in zip(shipped, fetched):
        np.testing.assert_array_equal(a, b)
    ref = HostExpertStore(layers)
    assert any(not np.array_equal(a, b)      # really the quantized form
               for a, b in zip(shipped, ref.get(key)))
    store.close()
    fetch_store.close()


def test_tracker_ship_channel_serial_and_uncoalescable():
    tr = OverlapTracker(host_bw=1e9)
    # ship submits never ride each other (activations, not weights) ...
    assert not tr.submit(("s", 1), 0, tier=CHANNEL_SHIP, duration=1.0,
                         coalesce=False)
    assert not tr.submit(("s", 1), 0, tier=CHANNEL_SHIP, duration=1.0,
                         coalesce=False)
    assert tr.fetches_deduped == 0
    # ... and queue serially on their own channel, overlapping other tiers
    tr.submit(("w", 1), 1e9, tier=TIER_PEER)
    stall = tr.wait([("s", 1), ("w", 1)])
    assert stall == pytest.approx(2.0)       # two serial 1 s ships
    assert tr.stall_by_tier[CHANNEL_SHIP] == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# engine: token-identical streams across dispatch modes

@pytest.fixture(scope="module")
def backbone():
    return tiny_backbone()


def _tier_cfg(dispatch, cold=None, **kw):
    return TierConfig(num_shards=4, shard_dram_experts=2, cache_experts=4,
                      dispatch=dispatch, cold_dtype=cold, **kw)


def _gen_all(eng):
    out = [eng.generate(p, MAX_NEW, CACHE_LEN) for p in PROMPTS]
    eng.core.store.close()
    return out


def test_dispatch_modes_stream_parity(backbone):
    cfg, model, params, _ = backbone
    from repro.serving.engine import OffloadEngine
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    streams, ships = {}, {}
    for mode in ("fetch", "ship", "auto"):
        eng = OffloadEngine(model, params, None, n_total,
                            tiers=_tier_cfg(mode))
        streams[mode] = _gen_all(eng)
        ships[mode] = eng.stats.ships
    assert streams["fetch"] == streams["ship"] == streams["auto"]
    assert ships["fetch"] == 0
    # the tiny model's experts dwarf a one-token activation, so both ship
    # and auto really exercise the remote-compute path
    assert ships["ship"] > 0 and ships["auto"] > 0


def test_dispatch_batched_parity_and_summary(backbone):
    cfg, model, params, _ = backbone
    from repro.serving.config import ServeConfig
    from repro.serving.scheduler import BatchedOffloadEngine
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    outs, summaries = {}, {}
    for mode in ("fetch", "auto"):
        sc = ServeConfig(max_batch=4, tiers=_tier_cfg(mode))
        eng = BatchedOffloadEngine(model, params, None, n_total, serve=sc)
        outs[mode] = eng.generate(PROMPTS, max_new=MAX_NEW,
                                  cache_len=CACHE_LEN)
        summaries[mode] = eng.dispatch_summary()
        eng.core.store.close()
    assert outs["fetch"] == outs["auto"]
    assert summaries["fetch"]["ships"] == 0
    assert summaries["auto"]["ships"] > 0
    assert summaries["auto"]["ship_wire_bytes"] > 0
    # shipping replaces peer weight traffic, it doesn't add to it
    assert (summaries["auto"]["fetch_wire_bytes"]
            < summaries["fetch"]["fetch_wire_bytes"])
    # every ship carries >=1 token; batched lanes and prefill chunks group
    # several tokens per shipped expert, so tokens dominate ships
    assert summaries["auto"]["ships"] <= summaries["auto"]["ship_tokens"]


def test_dispatch_int8_parity_pinned(backbone):
    """auto/ship must not change the int8 stream: the ship computes with
    the dequantized peer copy, so whatever deviation int8 introduces is
    IDENTICAL across dispatch modes."""
    cfg, model, params, _ = backbone
    from repro.serving.engine import OffloadEngine
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    streams = {}
    for mode in ("fetch", "ship", "auto"):
        eng = OffloadEngine(model, params, None, n_total,
                            tiers=_tier_cfg(mode, cold="int8"))
        streams[mode] = _gen_all(eng)
    assert streams["fetch"] == streams["ship"] == streams["auto"]


def test_auto_cuts_stall_in_cold_expert_regime(backbone):
    """Many experts, few tokens each, no tier-1 promotion cache: fetch-only
    drags every cold expert's weights through a slow interconnect; auto
    ships the token instead. At equal tier-0 capacity the un-overlapped
    stall must strictly drop while streams stay token-identical — and the
    shipped accesses must not have churned tier 0 (no insert)."""
    cfg, model, params, _ = backbone
    from repro.serving.engine import OffloadEngine
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    results = {}
    for mode in ("fetch", "auto"):
        tc = TierConfig(num_shards=4, shard_dram_experts=None,
                        cache_experts=0, dispatch=mode,
                        peer_latency_s=1e-4, peer_bw=1e8)
        eng = OffloadEngine(model, params, None, cfg.moe.top_k + 1,
                            layer_compute_s=1e-3, tiers=tc)
        results[mode] = {
            "streams": _gen_all(eng),
            "stall": eng.stats.sim_stall_s,
            "ships": eng.stats.ships,
            "fetch_bytes": eng.stats.fetch_bytes,
        }
    assert results["auto"]["streams"] == results["fetch"]["streams"]
    assert results["fetch"]["stall"] > 0
    assert results["auto"]["stall"] < results["fetch"]["stall"]
    assert results["auto"]["ships"] > 0
    assert results["auto"]["fetch_bytes"] < results["fetch"]["fetch_bytes"]


def test_prefetch_skips_ship_priced_keys(backbone):
    """With a policy driving prefetch, peer-resident keys the planner
    prices cheaper to ship are not prefetched — they arrive as ships, not
    as cache inserts — and streams still match fetch mode exactly."""
    cfg, model, params, _ = backbone
    from repro.core.policies import NextLayerAllPolicy
    from repro.serving.engine import OffloadEngine
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    streams, stats = {}, {}
    for mode in ("fetch", "ship"):
        pol = NextLayerAllPolicy(cfg.moe.num_experts)
        eng = OffloadEngine(model, params, pol, n_total,
                            tiers=_tier_cfg(mode))
        streams[mode] = _gen_all(eng)
        stats[mode] = (eng.stats.ships, eng.stats.fetch_bytes)
    assert streams["ship"] == streams["fetch"]
    assert stats["ship"][0] > 0
    assert stats["ship"][1] < stats["fetch"][1]   # peer weights not pulled


def test_engine_ship_slots_and_planner_wiring(backbone):
    cfg, model, params, _ = backbone
    from repro.serving.engine import OffloadEngine
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    fetch_eng = OffloadEngine(model, params, None, n_total,
                              tiers=_tier_cfg("fetch"))
    assert fetch_eng.core.planner is None
    assert fetch_eng.core.slots.ship_slots == 0
    fetch_eng.core.store.close()
    auto_eng = OffloadEngine(model, params, None, n_total,
                             tiers=_tier_cfg("auto"))
    assert auto_eng.core.planner is not None
    assert auto_eng.core.planner.mode == "auto"
    assert auto_eng.core.slots.ship_slots > 0
    # ephemeral rows sit past the cache-managed region
    assert auto_eng.core.slots.w_gate.shape[0] == \
        n_total + auto_eng.core.slots.ship_slots
    auto_eng.core.store.close()


# ---------------------------------------------------------------------------
# the real all_to_all mesh program (CI runs this file under
# XLA_FLAGS=--xla_force_host_platform_device_count=8; on a single-device
# host the mesh tests skip)

def _dispatch_case(n_shards, e=8, d=4, f=6, c=3, seed=0):
    """Random send buffers routing every token to its expert's home."""
    rng = np.random.default_rng(seed)
    wg = rng.normal(size=(e, d, f)).astype(np.float32)
    wu = rng.normal(size=(e, d, f)).astype(np.float32)
    wd = rng.normal(size=(e, f, d)).astype(np.float32)
    e_local = e // n_shards
    x = rng.normal(size=(n_shards, n_shards, c, d)).astype(np.float32)
    eid = np.full((n_shards, n_shards, c), -1, np.int32)
    for s in range(n_shards):
        for dest in range(n_shards):
            n_live = int(rng.integers(0, c + 1))    # ragged + padding slots
            eid[s, dest, :n_live] = rng.integers(
                dest * e_local, (dest + 1) * e_local, n_live)
    return wg, wu, wd, x, eid


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >=2 devices (CI forces host devices)")
@pytest.mark.parametrize("n_shards", [2, 4])
def test_mesh_dispatch_matches_local_expert_ffn(n_shards):
    if jax.device_count() < n_shards:
        pytest.skip(f"needs {n_shards} devices")
    from repro.launch.mesh import make_expert_mesh
    from repro.launch.sharding import expert_dispatch_ffn
    from repro.models.moe import expert_group_ffn
    wg, wu, wd, x, eid = _dispatch_case(n_shards)
    mesh = make_expert_mesh(n_shards)
    out = np.asarray(expert_dispatch_ffn(mesh, wg, wu, wd, x, eid))
    assert out.shape == x.shape
    for s in range(n_shards):
        for dest in range(n_shards):
            for c_i in range(eid.shape[2]):
                e_id = int(eid[s, dest, c_i])
                if e_id < 0:
                    np.testing.assert_array_equal(out[s, dest, c_i], 0.0)
                    continue
                ref = np.asarray(expert_group_ffn(
                    wg[e_id], wu[e_id], wd[e_id], x[s, dest, c_i][None]))[0]
                np.testing.assert_allclose(out[s, dest, c_i], ref,
                                           rtol=1e-5, atol=1e-5)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >=2 devices (CI forces host devices)")
def test_expert_mesh_uses_device_slice():
    from repro.launch.mesh import make_expert_mesh
    mesh = make_expert_mesh(2)
    assert mesh.axis_names == ("expert",)
    assert mesh.devices.size == 2
    with pytest.raises(AssertionError):
        make_expert_mesh(jax.device_count() + 1)


def test_expert_group_ffn_matches_reference_kernel():
    """The factored single-expert FFN (the unit a peer computes) must
    match the slot-gather reference math for a 1-expert group."""
    from repro.kernels.ref import expert_ffn_ref
    import jax.numpy as jnp
    rng = np.random.default_rng(3)
    d, f, n = 4, 6, 5
    wg = rng.normal(size=(d, f)).astype(np.float32)
    wu = rng.normal(size=(d, f)).astype(np.float32)
    wd = rng.normal(size=(f, d)).astype(np.float32)
    xs = rng.normal(size=(n, d)).astype(np.float32)
    from repro.models.moe import expert_group_ffn
    ys = np.asarray(expert_group_ffn(jnp.asarray(wg), jnp.asarray(wu),
                                     jnp.asarray(wd), jnp.asarray(xs)))
    for i in range(n):
        ref = np.asarray(expert_ffn_ref(
            jnp.asarray(xs[i]), jnp.ones((1,), jnp.float32),
            jnp.asarray(wg)[None], jnp.asarray(wu)[None],
            jnp.asarray(wd)[None]))
        np.testing.assert_allclose(ys[i], ref, rtol=1e-5, atol=1e-6)
