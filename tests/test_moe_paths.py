"""MoE compute-path equivalences + newer features: gather vs dispatch,
horizon targets, cross-layer policy, HLO cost-model units."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import PredictorConfig
from repro.core.policies import CrossLayerPolicy, NoPrefetchPolicy
from repro.core.simulator import SimConfig, simulate
from repro.core.tracing import Trace
from repro.data.traces import PredictorDataset
from repro.models import moe as M


def _cfg_nodrop():
    cfg = get_reduced("deepseek-v2-lite")
    return cfg.replace(moe=dataclasses.replace(
        cfg.moe, capacity_factor=float(cfg.moe.num_experts)))


@pytest.mark.parametrize("b,t", [(1, 1), (2, 1), (1, 3)])
def test_gather_path_matches_dispatch(b, t):
    cfg = _cfg_nodrop()
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, cfg.d_model),
                          jnp.float32)
    w, idx, _ = M.route(p, cfg, x)
    y_dispatch, _, _ = M.moe_apply(p, cfg, x, decode=False)
    y_gather = M.moe_gather_apply(p, cfg, x, w, idx)
    np.testing.assert_allclose(np.asarray(y_dispatch), np.asarray(y_gather),
                               rtol=3e-5, atol=3e-6)


def test_capacity_dropping_drops_tokens():
    """With cf small and skewed routing, the dispatch path must drop."""
    cfg = get_reduced("deepseek-v2-lite")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=0.1))
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.broadcast_to(
        jax.random.normal(jax.random.PRNGKey(1), (1, 1, cfg.d_model)),
        (1, 64, cfg.d_model))   # identical tokens -> same expert -> overflow
    y, _, idx = M.moe_apply(p, cfg, x)
    cfg_full = _cfg_nodrop()
    y_full, _, _ = M.moe_apply(M.moe_init(jax.random.PRNGKey(0), cfg_full,
                                          jnp.float32), cfg_full, x)
    # outputs differ because some tokens were dropped (only shared-expert
    # contribution remains for them)
    assert not np.allclose(np.asarray(y), np.asarray(y_full), atol=1e-5)


def test_horizon_dataset_targets():
    rng = np.random.default_rng(0)
    t, L, k, E = 10, 3, 2, 8
    tr = Trace(rng.integers(0, 50, t).astype(np.int32),
               rng.normal(size=(t, 16)).astype(np.float32),
               rng.integers(0, E, (t, L, k)).astype(np.int32), 2)
    pc = PredictorConfig(token_emb_dim=16, num_model_layers=L, num_experts=E,
                         layer_emb_dim=8, d_model=32, num_layers=2,
                         num_heads=4, d_ff=64, max_seq=16, top_k=k, horizon=2)
    ds = PredictorDataset([tr], pc)
    emb, lids, mask, tgt = ds.example(0)        # layer 0 example
    assert tgt.shape[-1] == E * 2
    for tok in range(t):
        assert set(np.nonzero(tgt[tok, :E])[0]) == set(tr.experts[tok, 0])
        assert set(np.nonzero(tgt[tok, E:])[0]) == set(tr.experts[tok, 1])
    # last layer example has empty slot-1 targets
    _, _, _, tgt_last = ds.example(L - 1)
    assert tgt_last[:, E:].sum() == 0


def test_cross_layer_policy_learns_correlation():
    """Deterministic cross-layer rule: e_l = (e_{l-1} + 1) % E. The policy
    must exploit it and beat no-prefetch."""
    rng = np.random.default_rng(0)
    E, L, t = 8, 4, 30

    def mk(seed):
        r = np.random.default_rng(seed)
        ex = np.zeros((t, L, 1), np.int32)
        ex[:, 0, 0] = r.integers(0, E, t)
        for layer in range(1, L):
            ex[:, layer, 0] = (ex[:, layer - 1, 0] + 1) % E
        return Trace(np.arange(t, dtype=np.int32),
                     np.zeros((t, 4), np.float32), ex, 2)

    traces = [mk(s) for s in range(6)]
    pol = CrossLayerPolicy(traces[:4], L, E, width=1)
    sim = SimConfig(num_layers=L, num_experts=E, capacity_fraction=0.15,
                    warm_tokens=2)
    r_x = simulate(traces[4:], pol, sim)
    r_none = simulate(traces[4:], NoPrefetchPolicy(), sim)
    # layers 1.. are perfectly predictable from the previous layer
    assert r_x.prediction_hit_rate > 0.7
    assert r_x.cache_hit_rate > r_none.cache_hit_rate


def test_hlo_instr_bytes_model():
    from repro.launch.hlo_cost import _instr_bytes
    # plain dot: result + operands
    assert _instr_bytes("dot", 100, [200, 300]) == 600
    # scan-xs slice read: big operand capped at 2x result
    assert _instr_bytes("dynamic-slice", 10, [10_000, 4]) == 10 + 20 + 4
    # in-place cache update: 2x the small update, not the buffer
    assert _instr_bytes("fusion", 1000, [1000, 8]) == 16
    # elementwise fusion (all operands result-sized): full traffic
    assert _instr_bytes("fusion", 100, [100, 100]) == 300
