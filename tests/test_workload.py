"""Units for the open-loop traffic layer: workload generators, latency
summaries, and in-flight fetch coalescing on the modeled transfer
timeline."""
import pytest

from repro.core.metrics import (RequestLatency, latency_stats, percentile)
from repro.serving.offload import (TIER_DISK, TIER_HOST, HostExpertStore,
                                   OverlapTracker, make_offload_cache)
from repro.serving.workload import (SLO, PriorityClass, WorkloadRequest,
                                    poisson_workload, scale_rate,
                                    trace_workload)

# ---------------------------------------------------------------------------
# workload generators


CLASSES = (
    PriorityClass("interactive", priority=0, weight=1.0, prompt_len=(2, 6),
                  max_new=4, slo=SLO(ttft_s=0.1), temperature=0.0),
    PriorityClass("batch", priority=2, weight=3.0, prompt_len=16,
                  max_new=(8, 12), slo=None),
)


def test_poisson_workload_deterministic():
    a = poisson_workload(32, 5.0, CLASSES, vocab_size=64, seed=3)
    b = poisson_workload(32, 5.0, CLASSES, vocab_size=64, seed=3)
    assert a == b
    c = poisson_workload(32, 5.0, CLASSES, vocab_size=64, seed=4)
    assert a != c


def test_poisson_workload_shape():
    wl = poisson_workload(64, 10.0, CLASSES, vocab_size=64, seed=1)
    assert len(wl) == 64
    arrivals = [r.arrival_s for r in wl]
    assert arrivals == sorted(arrivals)
    assert all(r.arrival_s > 0 for r in wl)
    assert len({r.seed for r in wl}) == 64          # private per-request rng
    for r in wl:
        assert all(0 <= t < 64 for t in r.prompt)
        if r.cls == "interactive":
            assert r.priority == 0 and 2 <= len(r.prompt) <= 6
            assert r.max_new == 4 and r.slo == SLO(ttft_s=0.1)
        else:
            assert r.priority == 2 and len(r.prompt) == 16
            assert 8 <= r.max_new <= 12 and r.slo is None
    # with weight 1:3 both classes should actually appear
    names = {r.cls for r in wl}
    assert names == {"interactive", "batch"}


def test_poisson_workload_rate():
    wl = poisson_workload(400, 8.0, CLASSES, seed=0)
    mean_gap = wl[-1].arrival_s / len(wl)
    assert mean_gap == pytest.approx(1 / 8.0, rel=0.2)


def test_poisson_workload_validation():
    with pytest.raises(ValueError):
        poisson_workload(4, 0.0, CLASSES)
    with pytest.raises(ValueError):
        poisson_workload(4, 1.0, ())
    assert poisson_workload(0, 1.0, CLASSES) == []


def test_scale_rate():
    wl = poisson_workload(16, 2.0, CLASSES, seed=5)
    fast = scale_rate(wl, 4.0)
    assert [r.arrival_s for r in fast] == \
        pytest.approx([r.arrival_s / 4.0 for r in wl])
    # same requests, only the clock changes; originals untouched
    assert [(r.prompt, r.max_new, r.seed) for r in fast] == \
        [(r.prompt, r.max_new, r.seed) for r in wl]
    assert wl[0].arrival_s != fast[0].arrival_s
    with pytest.raises(ValueError):
        scale_rate(wl, 0.0)


def test_trace_workload_sorts_and_defaults():
    wl = trace_workload([
        {"arrival_s": 0.5, "prompt": [1, 2], "priority": 1},
        {"arrival_s": 0.1, "prompt": [3], "max_new": 2,
         "slo": {"ttft_s": 0.05}},
    ])
    assert [r.arrival_s for r in wl] == [0.1, 0.5]
    assert wl[0].slo == SLO(ttft_s=0.05) and wl[0].max_new == 2
    assert wl[1].priority == 1 and wl[1].max_new == 8    # default


# ---------------------------------------------------------------------------
# latency summaries


def test_percentile():
    xs = list(range(1, 101))
    assert percentile(xs, 50) == pytest.approx(50.5)
    assert percentile(xs, 99) == pytest.approx(99.01)
    assert percentile([], 99) == 0.0


def _rec(rid, arrival, first, finish, tokens, slo=None, rejected=False,
         priority=0, preemptions=0):
    return RequestLatency(rid=rid, priority=priority, arrival_s=arrival,
                          first_token_s=first, finish_s=finish,
                          tokens_out=tokens, preemptions=preemptions,
                          rejected=rejected,
                          slo_ttft_s=slo.ttft_s if slo else None,
                          slo_per_token_s=slo.per_token_s if slo else None)


def test_request_latency_slo():
    ok = _rec(0, 0.0, 0.05, 1.05, 11, slo=SLO(ttft_s=0.1, per_token_s=0.2))
    assert ok.ttft_s == pytest.approx(0.05)
    assert ok.tpot_s == pytest.approx(0.1)
    assert ok.slo_met
    late = _rec(1, 0.0, 0.5, 1.0, 6, slo=SLO(ttft_s=0.1))
    assert not late.slo_met                       # blew the TTFT budget
    slow = _rec(2, 0.0, 0.05, 3.05, 11, slo=SLO(per_token_s=0.2))
    assert not slow.slo_met                       # blew the per-token budget
    rej = _rec(3, 0.0, -1.0, 0.2, 0, slo=SLO(ttft_s=9.0), rejected=True)
    assert rej.ttft_s is None and not rej.slo_met
    free = _rec(4, 0.0, 5.0, 6.0, 2)              # no SLO declared
    assert not free.has_slo and free.slo_met


def test_latency_stats_summary():
    recs = [
        _rec(0, 0.0, 0.1, 1.0, 5, slo=SLO(ttft_s=0.2)),
        _rec(1, 0.0, 0.9, 2.0, 5, slo=SLO(ttft_s=0.2), preemptions=1),
        _rec(2, 0.0, -1.0, 0.5, 0, rejected=True),
    ]
    s = latency_stats(recs, elapsed_s=2.0)
    assert s.n == 3 and s.completed == 2 and s.rejected == 1
    assert s.preemptions == 1
    assert s.slo_requests == 2 and s.slo_met == 1
    assert s.slo_attainment == pytest.approx(0.5)
    assert s.throughput_rps == pytest.approx(1.0)
    assert s.goodput_rps == pytest.approx(0.5)    # one SLO-meeting request
    assert s.ttft_p50_s == pytest.approx(0.5)
    d = s.as_dict()
    assert d["goodput_rps"] == pytest.approx(0.5)
    empty = latency_stats([], elapsed_s=1.0)
    assert empty.n == 0 and empty.goodput_rps == 0.0


# ---------------------------------------------------------------------------
# in-flight fetch coalescing (the dedup bugfix)


K = (0, 7)


def test_tracker_coalesces_resubmit_onto_wire():
    tr = OverlapTracker(host_bw=1e9)
    assert tr.submit(K, int(1e9)) is False        # 1s transfer, lands at 1.0
    tr.advance(0.2)
    tr.drop(K)                                    # slot evicted mid-flight
    assert tr.submit(K, int(1e9)) is True         # rides the same bytes
    assert tr.fetches_deduped == 1
    assert tr.pending[K] == pytest.approx(1.0)    # original completion
    stall = tr.wait([K])
    assert stall == pytest.approx(0.8)            # 1.0 - clock(0.2)
    # a serial re-fetch would have queued behind the first: landing at 2.0
    assert tr.clock == pytest.approx(1.0)


def test_tracker_fresh_faster_fetch_wins():
    tr = OverlapTracker(host_bw=1e9)
    tr.submit(K, int(1e9), tier=TIER_DISK, duration=1.0)
    tr.drop(K)
    tr.advance(0.1)
    # the store can now serve from host DRAM: a fresh fetch lands at 0.15,
    # far earlier than the disk bytes at 1.0 — don't ride the slow wire
    assert tr.submit(K, int(1e9), tier=TIER_HOST, duration=0.05) is False
    assert tr.fetches_deduped == 0
    assert tr.pending[K] == pytest.approx(0.15)


def test_tracker_landed_transfer_not_coalesced():
    tr = OverlapTracker(host_bw=1e9)
    tr.submit(K, int(1e9), duration=0.1)
    tr.drop(K)
    tr.advance(0.5)                               # bytes landed long ago
    assert tr.submit(K, int(1e9), duration=0.1) is False
    assert tr.fetches_deduped == 0
    assert tr.pending[K] == pytest.approx(0.6)


def test_slot_buffer_dedups_thrashing_fetch(backbone):
    """Capacity-1 thrash: A, B, A again while A's first transfer is still
    on the wire — the re-fetch must ride it, charging no new bytes."""
    cfg, model, params, _ = backbone
    from repro.core.tracing import moe_layer_ids
    from repro.serving.engine import unstack_layers
    layers = unstack_layers(cfg, params)
    moe_layers = [layers[i]["moe"] for i in moe_layer_ids(cfg)]
    store = HostExpertStore(moe_layers)
    tr = OverlapTracker(host_bw=1e3)              # pathologically slow wire
    cache, buf = make_offload_cache(store, capacity=1, host_bw=1e3,
                                    tracker=tr)
    cache.access((0, 1))
    cache.access((0, 2))                          # evicts (0,1) mid-flight
    bytes_two = buf.fetch_bytes
    cache.access((0, 1))                          # back before it landed
    assert buf.fetches_deduped == 1
    assert tr.fetches_deduped == 1
    assert buf.fetch_count == 2                   # only two real transfers
    assert buf.fetch_bytes == bytes_two           # no new bytes charged
    # the blocking model stays the upper bound: all three charged
    assert buf.sim_fetch_s == pytest.approx(
        3 * store.bytes_per_expert / 1e3)


@pytest.fixture(scope="module")
def backbone():
    from helpers import tiny_backbone
    return tiny_backbone()
