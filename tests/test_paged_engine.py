"""Paged KV cache + chunked prefill: token-identical to the contiguous
batch-1 path on ragged prompt lengths, block-accurate pool accounting,
and per-position prefill/decode logit parity."""
import numpy as np
import pytest

from repro.core.policies import MoEInfinityPolicy, NoPrefetchPolicy
from repro.core.tracing import moe_layer_ids
from repro.serving.engine import OffloadEngine
from repro.serving.kvpool import BlockTable, KVBlockPool, blocks_for
from repro.serving.scheduler import BatchedOffloadEngine

from helpers import tiny_backbone

# deliberately ragged: 2..10-token prompts, so block tables end mid-block,
# span block boundaries, and retire at different steps
PROMPTS = [[3, 17, 5], [99, 255, 7, 42, 11, 4, 9, 250, 33, 2], [13, 5],
           [21, 8, 9, 77, 31, 6]]
MAX_NEW = 6
CACHE_LEN = 24


@pytest.fixture(scope="module")
def backbone():
    return tiny_backbone()


@pytest.fixture(scope="module")
def ref_streams(backbone):
    """Batch-1 contiguous-row streams: the parity reference."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    eng = OffloadEngine(model, params, None, n_total)
    return [eng.generate(p, MAX_NEW, CACHE_LEN) for p in PROMPTS]


def test_paged_chunked_matches_batch1_ragged(backbone, ref_streams):
    """The tentpole acceptance: paged decode + chunked prefill streams are
    identical to the contiguous batch-1 path across ragged lengths."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    eng = BatchedOffloadEngine(model, params, None, n_total, max_batch=4,
                               block_size=4)
    outs = eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    for i, (ref, got) in enumerate(zip(ref_streams, outs)):
        assert ref == got, f"request {i} diverged"
    # prompts were absorbed by prefill chunks, not token-by-token decode
    assert eng.stats.prefill_chunks > 0
    assert eng.stats.prefill_tokens == sum(len(p) - 1 for p in PROMPTS)
    assert eng.stats.fallback_prefill_tokens == 0    # nothing streamed
    # pool hygiene: every block came back, high-water < worst-case rows
    eng.pool.check_leaks()
    assert eng.pool.blocks_in_use == 0
    worst = eng.max_batch * blocks_for(CACHE_LEN, eng.block_size)
    assert 0 < eng.pool.stats.high_water < worst
    assert eng.kv_high_water_bytes > 0


def test_paged_block_boundary_sizes(backbone, ref_streams):
    """Parity must not depend on the block-size knob: prompts that end
    exactly on, one before, and one after a block boundary."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    for bs in (2, 3, 8):
        eng = BatchedOffloadEngine(model, params, None, n_total,
                                   max_batch=4, block_size=bs)
        outs = eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
        assert outs == ref_streams, f"diverged at block_size={bs}"


def test_block_granular_admission(backbone, ref_streams):
    """A pool smaller than max_batch×worst-case still serves every request
    (admission waits on block reservations), and streams stay identical."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    bs = 4
    # enough for the longest request plus one more small one, not for four
    kv_blocks = blocks_for(CACHE_LEN, bs) + blocks_for(9, bs) + 1
    eng = BatchedOffloadEngine(model, params, None, n_total, max_batch=4,
                               block_size=bs, kv_blocks=kv_blocks)
    outs = eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    assert outs == ref_streams
    assert eng.pool.stats.failed_reserves > 0    # admission really waited
    eng.pool.check_leaks()


def test_paged_with_policy_and_tight_capacity(backbone, ref_streams):
    """Chunk clamp (capacity // top_k) + per-request policy state + shared
    small ExpertCache: pinning discipline holds through prefill chunks."""
    cfg, model, params, _ = backbone
    e = cfg.moe.num_experts
    n_moe = len(moe_layer_ids(cfg))
    cap = max(2 * cfg.moe.top_k + 1, (n_moe * e) // 4)
    eng = BatchedOffloadEngine(
        model, params, lambda: MoEInfinityPolicy([], n_moe, e, width=4),
        cap, max_batch=2, block_size=4)
    assert eng.prefill_chunk <= cap // cfg.moe.top_k
    outs = eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    assert outs == ref_streams
    assert eng.stats.misses > 0


def test_prefill_logits_match_decode_per_position(backbone):
    """Each chunk position's logits equal the decode path's logits at the
    same position — the strongest form of prefill/decode equivalence."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    prompt = PROMPTS[1]

    ref = OffloadEngine(model, params, None, n_total)
    state = ref.init_state(CACHE_LEN)
    ref_logits = []
    for tok in prompt:
        lg, state, _ = ref.decode_token(state, int(tok))
        ref_logits.append(lg)

    eng = BatchedOffloadEngine(model, params, None, n_total, max_batch=2,
                               block_size=4, prefill_chunk=4)
    core = eng.core
    pool = KVBlockPool(16, 4)
    caches = core.alloc_paged_caches(16, 4)
    table = BlockTable(pool)
    got = []
    t0 = 0
    for chunk in (prompt[0:3], prompt[3:7], prompt[7:]):   # ragged chunks
        table.ensure(t0 + len(chunk) - 1)
        lg, caches, _ = core.prefill_chunk(caches, table.padded(6), t0,
                                           chunk, None, rid=0)
        got.extend(lg)
        t0 += len(chunk)
    for t, (a, b) in enumerate(zip(got, ref_logits)):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4,
                                   err_msg=f"position {t}")
    table.release()


def test_kernel_vs_gather_token_identical(backbone, ref_streams):
    """Tentpole acceptance: the flash-decode kernel route (default) and the
    PR-2 gather route (use_kernel=False) produce identical token streams —
    and both equal the contiguous batch-1 reference."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    gather = BatchedOffloadEngine(model, params, None, n_total, max_batch=4,
                                  block_size=4, use_kernel=False)
    assert gather.core.kernel is None
    outs_gather = gather.generate(PROMPTS, max_new=MAX_NEW,
                                  cache_len=CACHE_LEN)
    kernel = BatchedOffloadEngine(model, params, None, n_total, max_batch=4,
                                  block_size=4)
    assert kernel.core.kernel is not None    # flash-decode is the default
    outs_kernel = kernel.generate(PROMPTS, max_new=MAX_NEW,
                                  cache_len=CACHE_LEN)
    assert outs_kernel == outs_gather == ref_streams


def test_pallas_backend_through_engine(backbone, ref_streams):
    """The interpret-mode Pallas body serves the whole engine (decode steps
    AND prefill chunks) with streams identical to the reference — the CI
    pin that the kernel the TPU compiles is the one the engine runs."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    eng = BatchedOffloadEngine(model, params, None, n_total, max_batch=4,
                               block_size=4, kernel_backend="pallas")
    assert eng.core.kernel == "pallas"
    outs = eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    assert outs == ref_streams
    assert eng.stats.prefill_chunks > 0


def test_serve_config_bundles_knobs(backbone, ref_streams):
    """ServeConfig overrides the individual kwargs and reaches the core."""
    from repro.serving.config import ServeConfig
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    sc = ServeConfig(max_batch=2, block_size=4, prefill_chunk=4,
                     use_kernel=True, kernel_backend="jnp")
    eng = BatchedOffloadEngine(model, params, None, n_total,
                               max_batch=999, block_size=999, serve=sc)
    assert eng.max_batch == 2 and eng.block_size == 4
    assert eng.core.kernel == "jnp"
    assert sc.resolve_kernel() == "jnp"
    assert ServeConfig(use_kernel=False).resolve_kernel() is None
    outs = eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    assert outs == ref_streams


def test_contiguous_fallback_still_available(backbone, ref_streams):
    """paged=False keeps the PR-1 fixed-row engine as the fallback."""
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    eng = BatchedOffloadEngine(model, params, NoPrefetchPolicy(), n_total,
                               max_batch=4, paged=False)
    outs = eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    assert outs == ref_streams
    assert eng.stats.prefill_chunks == 0         # prompts streamed as decode
    # every prompt body token counted as a token-by-token fallback
    assert eng.stats.fallback_prefill_tokens == \
        sum(len(p) - 1 for p in PROMPTS)
    assert eng.pool is None


def test_mixed_attention_kinds_page_and_ring():
    """An arch mixing ring-buffer (chunked) and global attention: global
    layers page through block tables, ring layers keep bounded rows, and
    prompts fall back to token-by-token — streams still match batch-1."""
    import jax

    from repro.configs import get_reduced
    from repro.models import build_model

    cfg = get_reduced("llama4-scout-17b-a16e")
    assert set(cfg.layer_kinds()) == {"chunked", "global"}
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))   # untrained: parity only
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    prompts = [p[:4] for p in PROMPTS]
    ref = OffloadEngine(model, params, None, n_total)
    refs = [ref.generate(p, 5, 16) for p in prompts]
    eng = BatchedOffloadEngine(model, params, None, n_total, max_batch=4,
                               block_size=4)
    assert eng.paged and not eng.core.chunk_prefill_ok
    outs = eng.generate(prompts, max_new=5, cache_len=16)
    assert outs == refs
    assert eng.stats.prefill_chunks == 0         # token-by-token fallback
    # the ROADMAP gap is measurable: ring/recurrent prompts count their
    # bodies as fallback tokens (the final prompt token is decode on every
    # path, so it is excluded)
    assert eng.stats.fallback_prefill_tokens == \
        sum(len(p) - 1 for p in prompts)


def test_ttft_recorded(backbone):
    cfg, model, params, _ = backbone
    n_total = len(moe_layer_ids(cfg)) * cfg.moe.num_experts
    eng = BatchedOffloadEngine(model, params, None, n_total, max_batch=4,
                               block_size=4)
    eng.generate(PROMPTS, max_new=MAX_NEW, cache_len=CACHE_LEN)
    tt = eng.ttft()
    assert sorted(tt) == [0, 1, 2, 3]
    assert all(v > 0 for v in tt.values())
