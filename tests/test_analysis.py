"""Repo-contract linter + runtime sanitizers: each rule catches its
known-violation fixture (and stays quiet on the clean twin), suppressions
require an audited reason, the JSON artifact keeps its schema, the repo
itself lints clean, and the retrace guard pins "a warmed engine compiles
zero new XLA programs mid-run" on a real BatchedOffloadEngine.

Also the parity pins for the serving knobs the linter flagged as
untested: ``ServeConfig.default_priority`` / ``ServeConfig.default_slo``
(defaults must flow into submitted requests) and
``TierConfig.local_shard`` (which shard's home experts are tier-0 local).
"""
import json
import os
import textwrap

import numpy as np
import pytest

from repro.analysis import default_rules, run_lint
from repro.analysis.linter import BAD_SUPPRESSION
from repro.core.tracing import moe_layer_ids
from repro.serving.config import ServeConfig
from repro.serving.expertstore import TierConfig, TieredExpertStore
from repro.serving.offload import TIER_HOST, TIER_PEER
from repro.serving.scheduler import BatchedOffloadEngine
from repro.serving.workload import SLO, WorkloadRequest

from helpers import tiny_backbone

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CACHE_LEN = 64


# ---------------------------------------------------------------------------
# static half: the rule fixtures

def _lint(tmp_path, *sources, extra_files=None):
    """Write each source as src/mod<i>.py under a tmp project and lint."""
    src = tmp_path / "src"
    src.mkdir(exist_ok=True)
    for i, text in enumerate(sources):
        (src / f"mod{i}.py").write_text(textwrap.dedent(text))
    for rel, text in (extra_files or {}).items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return run_lint(str(tmp_path), ["src"], default_rules())


def _rules_hit(report):
    return {d.rule for d in report.findings}


def test_refcount_pairing_catches_unpaired_retain(tmp_path):
    report = _lint(tmp_path, """\
        def adopt(table, pool, bids):
            for bid in bids:
                pool.retain(bid)
                table.append(bid)
        """)
    assert _rules_hit(report) == {"refcount-pairing"}
    (d,) = report.findings
    assert "retain" in d.message and d.line == 3


def test_refcount_pairing_clean_when_drop_verb_present(tmp_path):
    report = _lint(tmp_path, """\
        def adopt(table, pool, bids):
            for bid in bids:
                pool.retain(bid)
                table.append(bid)

        def drop(table, pool):
            for bid in table:
                pool.free(bid)
        """)
    assert report.ok


def test_refcount_pairing_catches_discarded_try_reserve(tmp_path):
    report = _lint(tmp_path, """\
        def admit(pool, n):
            pool.try_reserve(n)

        def retire(pool, n):
            pool.unreserve(n)
        """)
    assert _rules_hit(report) == {"refcount-pairing"}
    assert any("discarded" in d.message for d in report.findings)


def test_tracer_purity_catches_branch_on_traced(tmp_path):
    report = _lint(tmp_path, """\
        import jax

        @jax.jit
        def step(x):
            if x > 0:
                return x
            return -x
        """)
    assert _rules_hit(report) == {"tracer-purity"}
    (d,) = report.findings
    assert "`if`" in d.message and "'x'" in d.message


def test_tracer_purity_catches_self_closure(tmp_path):
    report = _lint(tmp_path, """\
        import jax

        class Engine:
            def build(self):
                self._fn = jax.jit(lambda x: x * self.scale)
        """)
    assert _rules_hit(report) == {"tracer-purity"}
    assert "self.scale" in report.findings[0].message


def test_tracer_purity_clean_on_where_and_shape_metadata(tmp_path):
    report = _lint(tmp_path, """\
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(x):
            if x.ndim == 2:
                x = x[None]
            return jnp.where(x > 0, x, -x)

        @jax.jit
        def maybe(x, extra):
            if extra is None:
                return x
            return x + extra
        """)
    assert report.ok


def test_bucket_discipline_catches_raw_int_at_jit_call(tmp_path):
    report = _lint(tmp_path, """\
        import jax

        def _step(x, n):
            return x[:n]

        step = jax.jit(_step)

        def caller(x, tokens):
            return step(x, len(tokens))
        """)
    assert _rules_hit(report) == {"bucket-discipline"}
    assert "'n'" in report.findings[0].message


def test_bucket_discipline_clean_when_static_or_bucketed(tmp_path):
    report = _lint(tmp_path, """\
        import jax

        def bucket_size(n, cap):
            return min(cap, 1 << (n - 1).bit_length())

        def _step(x, n):
            return x[:n]

        step = jax.jit(_step, static_argnames=("n",))
        dyn = jax.jit(_step)

        def caller(x, tokens):
            step(x, len(tokens))
            n = bucket_size(len(tokens), 8)
            return dyn(x, n)
        """)
    assert report.ok


def test_stats_registration_catches_undocumented_unserialized(tmp_path):
    report = _lint(tmp_path, """\
        from dataclasses import dataclass

        @dataclass
        class CacheStats:
            '''Counters.

              * ``hits`` — resident at access time.
            '''
            hits: int = 0
            misses: int = 0
        """)
    assert _rules_hit(report) == {"stats-registration"}
    msgs = " | ".join(d.message for d in report.findings)
    assert "misses is not named in the class docstring" in msgs
    assert "never serialized" in msgs


def test_stats_registration_clean_with_docstring_and_blanket_dict(tmp_path):
    report = _lint(tmp_path, """\
        from dataclasses import asdict, dataclass

        @dataclass
        class CacheStats:
            '''Counters.

              * ``hits`` — resident at access time.
              * ``misses`` — not resident at access time.
            '''
            hits: int = 0
            misses: int = 0

            def as_dict(self):
                return asdict(self)
        """)
    assert report.ok


def test_parity_pin_catches_untested_knob(tmp_path):
    report = _lint(tmp_path, """\
        from dataclasses import dataclass

        @dataclass
        class ServeConfig:
            max_batch: int = 8
            exotic_knob: int = 3
        """, extra_files={
            "tests/test_x.py": """\
            def test_one():
                assert ServeConfig(max_batch=2).max_batch == 2
            """})
    assert _rules_hit(report) == {"parity-pin"}
    (d,) = report.findings
    assert "exotic_knob" in d.message


def test_parity_pin_silent_without_tests_dir(tmp_path):
    report = _lint(tmp_path, """\
        from dataclasses import dataclass

        @dataclass
        class ServeConfig:
            exotic_knob: int = 3
        """)
    assert report.ok


def test_metric_registration_catches_unregistered_literal(tmp_path):
    report = _lint(tmp_path, """\
        METRICS = {"cache.hit": "tier-0 hits"}
        """, """\
        def record(tel):
            tel.counter("cache.hit")
            tel.counter("cache.hitz")       # typo: not in the catalogue
            tel.gauge("kv.blocks", 3)       # never registered
            tel.histogram(samples, 10)      # non-literal arg: not checked
        """)
    assert _rules_hit(report) == {"metric-registration"}
    assert sorted(d.message.split("'")[1] for d in report.findings) == \
        ["cache.hitz", "kv.blocks"]


def test_metric_registration_clean_and_silent_without_catalogue(tmp_path):
    report = _lint(tmp_path, """\
        METRICS = {"cache.hit": "tier-0 hits", "stall.s": "stall seconds"}
        """, """\
        def record(tel, np):
            tel.counter("cache.hit", 2)
            tel.histogram("stall.s", 0.5)
            np.histogram([1, 2, 3], bins=2)   # first arg not a str literal
        """)
    assert report.ok
    # a project with no METRICS catalogue opts out of the rule entirely
    report = _lint(tmp_path, """\
        def record(tel):
            tel.counter("anything.goes")
        """)
    assert report.ok


# ---------------------------------------------------------------------------
# suppressions

_VIOLATION = """\
    def adopt(table, pool, bids):
        for bid in bids:
            pool.retain(bid){trailer}
"""


def test_suppression_with_reason_silences_and_records(tmp_path):
    trailer = ("  # lint: disable=refcount-pairing -- "
               "caller releases via table.release()")
    report = _lint(tmp_path, _VIOLATION.format(trailer=trailer))
    assert report.ok
    (d,) = report.suppressed
    assert d.rule == "refcount-pairing" and d.suppressed
    assert d.reason == "caller releases via table.release()"


def test_standalone_suppression_covers_next_line(tmp_path):
    report = _lint(tmp_path, """\
        def adopt(table, pool, bids):
            for bid in bids:
                # lint: disable=refcount-pairing -- released by the caller
                pool.retain(bid)
        """)
    assert report.ok and len(report.suppressed) == 1


def test_suppression_without_reason_is_its_own_finding(tmp_path):
    trailer = "  # lint: disable=refcount-pairing"
    report = _lint(tmp_path, _VIOLATION.format(trailer=trailer))
    assert _rules_hit(report) == {"refcount-pairing", BAD_SUPPRESSION}
    assert not report.suppressed          # reason-less comment covers nothing


def test_suppression_of_unknown_rule_is_a_finding(tmp_path):
    report = _lint(tmp_path, """\
        # lint: disable=no-such-rule -- because
        x = 1
        """)
    assert _rules_hit(report) == {BAD_SUPPRESSION}
    assert "unknown rule" in report.findings[0].message


def test_docstring_disable_example_is_not_a_suppression(tmp_path):
    report = _lint(tmp_path, '''\
        """Docs showing the syntax::

            # lint: disable=refcount-pairing -- example only
        """
        x = 1
        ''')
    assert report.ok and not report.suppressed


# ---------------------------------------------------------------------------
# artifact schema + the repo's own lint gate

def test_json_report_schema(tmp_path):
    trailer = "  # lint: disable=refcount-pairing -- audited"
    report = _lint(tmp_path, _VIOLATION.format(trailer=trailer))
    doc = json.loads(report.to_json())
    assert doc["version"] == 1
    assert set(doc) == {"version", "root", "files_scanned", "rules",
                        "findings", "suppressed", "summary"}
    assert doc["files_scanned"] == 1
    assert set(doc["summary"]) == {"findings", "suppressed", "by_rule"}
    assert doc["summary"]["suppressed"] == 1
    (s,) = doc["suppressed"]
    assert set(s) == {"file", "line", "rule", "message", "suppressed",
                      "reason"}


def test_repo_lints_clean():
    """The acceptance pin: zero unsuppressed findings over the shipped
    tree, and every suppression carries its audited reason."""
    report = run_lint(REPO, ["src", "benchmarks", "tools"], default_rules())
    assert report.ok, "\n".join(d.format() for d in report.findings)
    assert all(d.reason for d in report.suppressed)


# ---------------------------------------------------------------------------
# runtime half: retrace guard + leak sanitizer on a real engine

@pytest.fixture(scope="module")
def backbone():
    return tiny_backbone()


def _n_total(cfg):
    return len(moe_layer_ids(cfg)) * cfg.moe.num_experts


def _engine(backbone, **serve_kw):
    cfg, model, params, _ = backbone
    return BatchedOffloadEngine(model, params, None, _n_total(cfg),
                                serve=ServeConfig(**serve_kw))


def _warm(eng):
    """Compile every bucket the workload below can hit (prefill chunk
    widths 1/2/4/8, 1..max_batch decode lanes)."""
    probe = [[3, 1], [6, 2, 4], [8, 3, 6, 5, 2],
             [9, 4, 1, 7, 2, 8, 3, 6, 5]]
    eng.generate(probe[: eng.max_batch], max_new=2, cache_len=CACHE_LEN)
    for p in probe[eng.max_batch:]:
        eng.generate([p], max_new=2, cache_len=CACHE_LEN)


def test_retrace_guard_counts_and_flags_restore():
    import jax
    import jax.numpy as jnp
    from repro.analysis import RetraceError, RetraceGuard

    prev = bool(jax.config.jax_log_compiles)
    f = jax.jit(lambda x: x * 2 + 1)
    with RetraceGuard() as guard:
        f(jnp.ones((3,)))
        guard.self_check()                      # hook saw the compile
        with guard.frozen("cached shape"):
            f(jnp.ones((3,)))                   # cache hit: no event
        with pytest.raises(RetraceError, match="new XLA program"):
            with guard.frozen("fresh shape"):
                f(jnp.ones((5,)))               # new bucket mid-freeze
    assert bool(jax.config.jax_log_compiles) == prev


def test_warmed_engine_compiles_zero_new_programs(backbone):
    """The sanitizer invariant CI pins: after warmup covers the bucket
    family, a whole open-loop workload compiles nothing."""
    from repro.analysis import RetraceGuard

    eng = _engine(backbone, max_batch=2, block_size=8)
    with RetraceGuard() as guard:
        _warm(eng)
        guard.self_check()
        wl = [WorkloadRequest(0.0, [5, 9, 2], 4),
              WorkloadRequest(0.0, [7, 3], 4)]
        with guard.frozen("warmed BatchedOffloadEngine.run_workload"):
            res = eng.run_workload(wl, CACHE_LEN)
    assert len(res) == 2
    assert guard.total() > 0                    # warmup really compiled


def test_leak_sanitizer_checks_every_retire(backbone):
    from repro.analysis import sanitize_engine

    eng = _engine(backbone, max_batch=2, block_size=8)
    orig_retire = eng._retire
    san = sanitize_engine(eng)
    assert san is not None and eng._retire is not orig_retire
    wl = [WorkloadRequest(0.0, [5, 9, 2], 3),
          WorkloadRequest(0.0, [7, 3], 3),
          WorkloadRequest(0.0, [8, 2, 4, 1], 3)]
    res = eng.run_workload(wl, CACHE_LEN)
    assert len(res) == 3
    assert san.checks >= 3                      # one sweep per retire
    san.uninstall()
    assert eng._retire == orig_retire


# ---------------------------------------------------------------------------
# parity pins: the knobs the linter flagged as untested

def test_serve_defaults_flow_into_requests(backbone):
    eng = _engine(backbone, max_batch=2,
                  default_priority=7, default_slo=SLO(ttft_s=0.5))
    eng.submit([3, 1], 2)                       # takes both defaults
    eng.submit([6, 2], 2, priority=1, slo=SLO(ttft_s=9.0))
    by_rid = {req.rid: req for _, _, req in eng._queue}
    defaulted, explicit = (by_rid[r] for r in sorted(by_rid))
    assert defaulted.priority == 7
    assert defaulted.slo is not None and defaulted.slo.ttft_s == 0.5
    assert explicit.priority == 1 and explicit.slo.ttft_s == 9.0
    res = eng.run(CACHE_LEN)                    # defaults survive a drain
    assert len(res) == 2


def test_local_shard_selects_the_tier0_home():
    rng = np.random.default_rng(0)
    e, d, f = 8, 4, 6
    layers = [
        {"w_gate": rng.normal(size=(e, d, f)).astype(np.float32),
         "w_up": rng.normal(size=(e, d, f)).astype(np.float32),
         "w_down": rng.normal(size=(e, f, d)).astype(np.float32)}
        for _ in range(2)
    ]
    tc1 = TierConfig(num_shards=2, local_shard=1, cache_experts=0)
    store1 = TieredExpertStore(layers, tc1)
    key = next(k for k in sorted(store1.home_shard)
               if store1.home_shard[k] == 1)
    _, info = store1.fetch(key)
    assert info.tier == TIER_HOST               # home shard is local
    store0 = TieredExpertStore(
        layers, TierConfig(num_shards=2, local_shard=0, cache_experts=0))
    assert store0.home_shard[key] == 1          # placement ignores locality
    _, info0 = store0.fetch(key)
    assert info0.tier == TIER_PEER              # same key, now remote
