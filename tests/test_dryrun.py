"""Distribution layer tests.

The multi-device dry-run runs in a SUBPROCESS because dryrun.py sets
XLA_FLAGS=--xla_force_host_platform_device_count and jax locks the device
count at first init — the rest of the suite must keep seeing 1 CPU device.
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_dryrun(args):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--mini", *args],
        capture_output=True, text=True, env=env, timeout=500)


@pytest.mark.slow
def test_mini_dryrun_train_and_decode(tmp_path):
    out = str(tmp_path / "r.json")
    r = _run_dryrun(["--arch", "internlm2-1.8b", "--json", out])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    results = json.load(open(out))
    by_shape = {x["shape"]: x for x in results}
    assert by_shape["train_4k"]["status"] == "ok"
    assert by_shape["decode_32k"]["status"] == "ok"
    assert by_shape["prefill_32k"]["status"] == "ok"
    assert by_shape["long_500k"]["status"] == "skip"
    tr = by_shape["train_4k"]
    # roofline terms present and positive
    assert all(v > 0 for v in tr["terms_s"].values())
    assert tr["dominant"] in ("compute_s", "memory_s", "collective_s")
    # HLO flops within sane range of the 6ND model estimate (remat +
    # attention push it above; sharding inefficiency below)
    assert 0.2 < tr["useful_ratio"] < 3.0
    assert tr["collective_total"] > 0  # sharded -> must communicate


@pytest.mark.slow
def test_mini_dryrun_multipod_moe(tmp_path):
    out = str(tmp_path / "r.json")
    r = _run_dryrun(["--arch", "llama4-scout-17b-a16e", "--shape",
                     "decode_32k", "--multi-pod", "--json", out])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    results = json.load(open(out))
    assert results[0]["status"] == "ok"
    assert results[0]["chips"] == 8


def test_hlo_cost_parser_scan():
    """Loop-trip-aware flop accounting on this process's single device."""
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_cost import analyze

    def body(c, x):
        return c @ x, None

    init = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    xs = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
    comp = jax.jit(
        lambda i, x: jax.lax.scan(body, i, x)).lower(init, xs).compile()
    r = analyze(comp.as_text())
    assert r["flops"] == pytest.approx(7 * 2 * 128 ** 3, rel=0.01)


def test_param_shardings_divisible():
    """Every parameter of every full-size arch gets a spec whose axes divide
    the dim sizes (guards the auto-sharder against new configs)."""
    import jax
    import numpy as np

    from repro.configs import ASSIGNED_ARCHS, get_config
    from repro.launch.sharding import param_spec
    from repro.models import build_model

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    def axis_size(ax):
        if isinstance(ax, tuple):
            return int(np.prod([axis_size(a) for a in ax]))
        return {"data": 16, "model": 16}[ax]

    mesh = FakeMesh()
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        model = build_model(cfg)
        abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        flat = jax.tree_util.tree_flatten_with_path(abs_params)[0]
        for path, leaf in flat:
            pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
            spec = param_spec(pstr, leaf.shape, cfg, mesh)
            for i, ax in enumerate(spec):
                if ax is not None:
                    assert leaf.shape[i] % axis_size(ax) == 0, \
                        (arch, pstr, leaf.shape, spec)
