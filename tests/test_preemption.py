"""SLO-aware preemptive scheduling: a preempted-and-resumed request's
output stream must be token-identical to a never-preempted run (the
teacher-forced resume replays the folded prompt, so sampling never sees
the eviction), the pool's refcount ledger must balance after preemption
storms, and admission must respect priority order."""
import time

import pytest

from repro.core.tracing import moe_layer_ids
from repro.serving.config import ServeConfig
from repro.serving.scheduler import BatchedOffloadEngine
from repro.serving.workload import SLO, WorkloadRequest

from helpers import tiny_backbone

LONG = [7, 3, 99, 42, 11, 250, 5, 17, 33, 2, 81, 64]
SHORT = [5, 9, 2]
MAX_NEW_LONG = 40
MAX_NEW_SHORT = 4
CACHE_LEN = 64


@pytest.fixture(scope="module")
def backbone():
    return tiny_backbone()


def _n_total(cfg):
    return len(moe_layer_ids(cfg)) * cfg.moe.num_experts


def _engine(backbone, **serve_kw):
    cfg, model, params, _ = backbone
    serve = ServeConfig(**serve_kw)
    return BatchedOffloadEngine(model, params, None, _n_total(cfg),
                                serve=serve)


@pytest.fixture(scope="module")
def ref_streams(backbone):
    """Never-preempted reference streams (plain closed-loop engine)."""
    eng = _engine(backbone, max_batch=2, block_size=8)
    long = eng.generate([LONG], max_new=MAX_NEW_LONG,
                        cache_len=CACHE_LEN)[0]
    short = eng.generate([SHORT], max_new=MAX_NEW_SHORT,
                         cache_len=CACHE_LEN)[0]
    return long, short


def _warm(eng):
    """Compile every bucket a preempting run can hit — prefill chunk
    widths 1/2/4/8 (a resume's re-prefill tail lands on any of them) and
    1..max_batch decode lanes — so arrival offsets measured from a solo
    run aren't skewed by compile time landing mid-measurement."""
    probe = [[3, 1], [6, 2, 4], [8, 3, 6, 5, 2],
             [9, 4, 1, 7, 2, 8, 3, 6, 5]]
    eng.generate(probe[: eng.max_batch], max_new=2, cache_len=CACHE_LEN)
    for p in probe[eng.max_batch:]:
        eng.generate([p], max_new=2, cache_len=CACHE_LEN)


def _preempting_run(eng, temperature=0.0, long_seed=0, short_seed=0):
    """Open-loop run engineered to preempt: a background-priority long
    request starts alone, then an urgent request arrives mid-decode while
    every lane is taken. The arrival offset is derived from a measured
    solo run of the same work on the same (warmed) engine, so the long
    request is reliably still decoding when the urgent one lands."""
    _warm(eng)
    t0 = time.perf_counter()
    eng.generate([LONG], max_new=MAX_NEW_LONG, cache_len=CACHE_LEN,
                 temperature=temperature, seeds=[long_seed])
    solo_s = time.perf_counter() - t0
    wl = [
        WorkloadRequest(0.0, LONG, MAX_NEW_LONG, priority=2,
                        temperature=temperature, seed=long_seed),
        WorkloadRequest(0.2 * solo_s, SHORT, MAX_NEW_SHORT, priority=0,
                        slo=SLO(ttft_s=solo_s), temperature=temperature,
                        seed=short_seed),
    ]
    res = eng.run_workload(wl, CACHE_LEN)
    rid_long, rid_short = sorted(res)             # rids in arrival order
    return res[rid_long], res[rid_short]


@pytest.mark.parametrize("block_size,prefix", [(2, False), (8, False),
                                               (4, True), (8, True)])
def test_preempt_resume_token_identical(backbone, ref_streams,
                                        block_size, prefix):
    """The acceptance pin: eviction + re-admission (with or without the
    prefix index making the resume a cache hit) never changes a stream."""
    eng = _engine(backbone, max_batch=1, block_size=block_size,
                  prefix_cache=prefix, preemption=True)
    long, short = _preempting_run(eng)
    assert eng.stats.preemptions >= 1, "urgent arrival never preempted"
    ref_long, ref_short = ref_streams
    assert long == ref_long, "preempted stream diverged"
    assert short == ref_short, "preempting stream diverged"
    assert eng.pool.stats.preempt_ref_drops > 0
    # run_workload's own check_leaks already ran at retire; re-assert with
    # the prefix cache's retained blocks as the only legitimate residue
    eng.pool.check_leaks(expected_in_use=(
        eng.prefix.cached_blocks if eng.prefix is not None else 0))
    rec = eng.records()[sorted(eng.records())[0]]
    assert rec.preemptions == eng.stats.preemptions
    assert eng.stats.latency is not None
    assert eng.stats.latency.preemptions == eng.stats.preemptions


def test_preempt_resume_sampled_stream(backbone):
    """Temperature > 0: teacher-forced resume positions never consume the
    request RNG, so even sampled streams survive preemption bit-exactly."""
    ref = _engine(backbone, max_batch=2, block_size=4)
    ref_long = ref.generate([LONG], max_new=MAX_NEW_LONG,
                            cache_len=CACHE_LEN, temperature=0.8,
                            seeds=[11])[0]
    eng = _engine(backbone, max_batch=1, block_size=4, prefix_cache=True,
                  preemption=True)
    long, _ = _preempting_run(eng, temperature=0.8, long_seed=11,
                              short_seed=13)
    assert eng.stats.preemptions >= 1
    assert long == ref_long


def test_preemption_storm_leak_free(backbone):
    """Several urgent arrivals against saturated lanes: every stream still
    matches its uncontended reference and the block ledger balances."""
    cfg, model, params, _ = backbone
    reqs = [
        (LONG, MAX_NEW_LONG, 2, 0),
        (list(reversed(LONG)), MAX_NEW_LONG, 2, 1),
        (SHORT, MAX_NEW_SHORT, 0, 2),
        ([44, 8, 1, 9], 3, 1, 3),
        ([250, 33], MAX_NEW_SHORT, 0, 4),
    ]
    ref = _engine(backbone, max_batch=2, block_size=4)
    refs = [ref.generate([p], max_new=m, cache_len=CACHE_LEN,
                         seeds=[s])[0] for p, m, _, s in reqs]

    eng = _engine(backbone, max_batch=2, block_size=4, prefix_cache=True,
                  preemption=True)
    _warm(eng)
    t0 = time.perf_counter()
    eng.generate([LONG], max_new=MAX_NEW_LONG, cache_len=CACHE_LEN)
    solo_s = time.perf_counter() - t0
    # both lanes fill with background work, then urgent/medium requests
    # land mid-decode at staggered offsets
    offsets = [0.0, 0.0, 0.15 * solo_s, 0.3 * solo_s, 0.45 * solo_s]
    wl = [WorkloadRequest(offsets[i], p, m, priority=pr, seed=s)
          for i, (p, m, pr, s) in enumerate(reqs)]
    res = eng.run_workload(wl, CACHE_LEN)
    assert eng.stats.preemptions >= 1
    for rid, want in zip(sorted(res), refs):
        assert res[rid] == want, f"request {rid} diverged under the storm"
    eng.pool.check_leaks(expected_in_use=(
        eng.prefix.cached_blocks if eng.prefix is not None else 0))
    lat = eng.stats.latency
    assert lat.completed == len(reqs) and lat.rejected == 0


def test_priority_admission_order(backbone):
    """Closed loop, one lane: the heap admits strictly by (priority, FIFO)
    regardless of submission order, with no preemption needed."""
    eng = _engine(backbone, max_batch=1, block_size=8, preemption=True)
    rid_low = eng.submit(SHORT, 2, priority=2)
    rid_hi = eng.submit([9, 9], 2, priority=0)
    rid_mid = eng.submit([4, 4], 2, priority=1)
    res = eng.run(CACHE_LEN)
    assert set(res) == {rid_low, rid_hi, rid_mid}
    assert eng.stats.preemptions == 0
    recs = eng.records()
    assert (recs[rid_hi].finish_s < recs[rid_mid].finish_s
            < recs[rid_low].finish_s)


def test_run_workload_latency_summary(backbone):
    """Open-loop smoke: stats.latency is populated with sane SLO fields."""
    eng = _engine(backbone, max_batch=2, block_size=8, preemption=True)
    eng.generate([[3, 1, 4]], max_new=2, cache_len=CACHE_LEN)    # warm jit
    wl = [WorkloadRequest(0.0, SHORT, 3, priority=0,
                          slo=SLO(ttft_s=60.0)),
          WorkloadRequest(0.01, [4, 4, 4], 3, priority=1)]
    res = eng.run_workload(wl, CACHE_LEN)
    # every engine generates max_new + 1 tokens (known off-by-one, pinned
    # mutually identical across engines — see ROADMAP)
    assert sorted(len(v) for v in res.values()) == [4, 4]
    lat = eng.stats.latency
    assert lat.n == 2 and lat.completed == 2
    assert lat.slo_requests == 1 and lat.slo_met == 1
    assert lat.ttft_p99_s > 0 and lat.goodput_rps > 0
    assert lat.elapsed_s > 0
    for rec in eng.records().values():
        assert rec.ttft_s is not None and rec.ttft_s >= 0
