"""Pallas kernel validation: interpret-mode kernel body vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("t,e,k", [
    (7, 16, 2), (64, 64, 6), (33, 160, 6), (256, 128, 1), (4, 8, 8),
    (130, 100, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_gating(t, e, k, dtype):
    logits = jnp.asarray(RNG.normal(size=(t, e)) * 2, dtype)
    wr, ir = ref.topk_gating_ref(logits, k)
    wp, ip = ops.topk_gating(logits, k, backend="pallas")
    np.testing.assert_allclose(np.sort(np.asarray(wr)), np.sort(np.asarray(wp)),
                               rtol=2e-3, atol=1e-5)
    for row in range(t):
        assert set(np.asarray(ir)[row].tolist()) == \
            set(np.asarray(ip)[row].tolist()), row
    # weights sum to 1 after renormalisation
    np.testing.assert_allclose(np.asarray(wp).sum(-1), 1.0, atol=1e-3)


@pytest.mark.parametrize("k,d,f", [
    (1, 128, 128), (2, 128, 256), (6, 256, 512), (4, 512, 1024),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_ffn(k, d, f, dtype):
    x = jnp.asarray(RNG.normal(size=(d,)), dtype)
    w = jnp.asarray(RNG.random(k) + 0.1, jnp.float32)
    wg = jnp.asarray(RNG.normal(size=(k, d, f)) * 0.05, dtype)
    wu = jnp.asarray(RNG.normal(size=(k, d, f)) * 0.05, dtype)
    wd = jnp.asarray(RNG.normal(size=(k, f, d)) * 0.05, dtype)
    yr = np.asarray(ref.expert_ffn_ref(x, w, wg, wu, wd), np.float32)
    yp = np.asarray(ops.expert_ffn(x, w, wg, wu, wd, backend="pallas"),
                    np.float32)
    tol = 5e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(yr, yp, rtol=tol, atol=tol)


@pytest.mark.parametrize("s,kvh,g,hd,vl", [
    (128, 2, 4, 64, 100), (1024, 1, 16, 128, 1024), (96, 4, 1, 32, 50),
    (2048, 8, 2, 64, 1500), (512, 1, 1, 128, 1),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(s, kvh, g, hd, vl, dtype):
    h = kvh * g
    q = jnp.asarray(RNG.normal(size=(h, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(s, kvh, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(s, kvh, hd)), dtype)
    yr = np.asarray(ref.flash_decode_ref(q, k, v, vl), np.float32)
    yp = np.asarray(ops.flash_decode(q, k, v, vl, backend="pallas"),
                    np.float32)
    tol = 5e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(yr, yp, rtol=tol, atol=tol)


def test_flash_decode_matches_model_attention():
    """The kernel must agree with the model's decode attention math."""
    from repro.configs import get_reduced
    cfg = get_reduced("yi-6b")
    s, kvh, hd, h = 64, cfg.num_kv_heads, cfg.hd, cfg.num_heads
    q = jnp.asarray(RNG.normal(size=(h, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(s, kvh, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(s, kvh, hd)), jnp.float32)
    out = ops.flash_decode(q, k, v, 40, backend="pallas")
    ref_out = ref.flash_decode_ref(q, k, v, 40)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Paged flash-decode: block-table attention against the pool layout


def _paged_case(bs, kvh, g, hd, w, n, seed, dtype=jnp.float32, pad_w=0):
    """Random pool + per-lane tables/positions. Lane tables draw distinct
    blocks (plus ``pad_w`` scratch-padded tail entries); positions are
    ragged and include a partially-filled last block."""
    rng = np.random.default_rng(seed)
    nb = n * w + 3                       # spare blocks stay unreferenced
    q = jnp.asarray(rng.normal(size=(n, kvh, g, hd)), dtype)
    kp = jnp.asarray(rng.normal(size=(nb, bs, kvh, hd)), dtype)
    vp = jnp.asarray(rng.normal(size=(nb, bs, kvh, hd)), dtype)
    perm = rng.permutation(nb - 1)[: n * w] + 1          # never scratch
    tables = np.zeros((n, w + pad_w), np.int32)
    tables[:, :w] = perm.reshape(n, w)
    # ragged: lane 0 ends mid-block, last lane uses the full table
    pos = rng.integers(0, w * bs, size=n)
    pos[0] = (w - 1) * bs + bs // 2 - 1 if w * bs > 1 else 0
    pos[-1] = w * bs - 1
    return q, kp, vp, jnp.asarray(tables), jnp.asarray(pos, jnp.int32)


@pytest.mark.parametrize("bs", [8, 16, 64])
@pytest.mark.parametrize("kvh,g", [(1, 4), (2, 2), (4, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_decode(bs, kvh, g, dtype):
    """Kernel body (interpret) and its lax.scan twin vs the dense oracle,
    across block sizes, GQA group sizes, ragged pos, and a scratch-padded
    table tail."""
    q, kp, vp, tables, pos = _paged_case(bs, kvh, g, hd=32, w=3, n=3,
                                         seed=bs * 10 + kvh, dtype=dtype,
                                         pad_w=2)
    yr = np.asarray(ref.paged_flash_decode_ref(q, kp, vp, tables, pos),
                    np.float32)
    tol = 5e-4 if dtype == jnp.float32 else 3e-2
    for backend in ("pallas", "jnp"):
        yp = np.asarray(ops.paged_flash_decode(q, kp, vp, tables, pos,
                                               backend=backend), np.float32)
        np.testing.assert_allclose(yr, yp, rtol=tol, atol=tol,
                                   err_msg=backend)


def test_paged_flash_decode_mla_layout():
    """The MLA latent layout (``v_pool=None``): one kv head, K = the whole
    latent page, V = its first ``dv`` features sliced from the same fetch,
    custom scale — and it must equal passing the pool explicitly twice."""
    q, kp, _, tables, pos = _paged_case(bs=8, kvh=1, g=4, hd=48, w=4, n=2,
                                        seed=7)
    scale, dv = 0.125, 32
    yr = np.asarray(ref.paged_flash_decode_ref(q, kp, None, tables, pos,
                                               scale=scale, dv=dv),
                    np.float32)
    assert yr.shape == (2, 1, 4, dv)
    y2 = np.asarray(ref.paged_flash_decode_ref(q, kp, kp, tables, pos,
                                               scale=scale, dv=dv),
                    np.float32)
    np.testing.assert_array_equal(yr, y2)    # shared == explicit two-pool
    for backend in ("pallas", "jnp"):
        yp = np.asarray(ops.paged_flash_decode(q, kp, None, tables, pos,
                                               scale=scale, dv=dv,
                                               backend=backend), np.float32)
        np.testing.assert_allclose(yr, yp, rtol=5e-4, atol=5e-4,
                                   err_msg=backend)


def test_paged_flash_decode_jnp_tiling_invariant():
    """The scan twin's tile size is a perf knob, not a semantics knob."""
    from repro.kernels.paged_attention import paged_flash_decode_jnp
    q, kp, vp, tables, pos = _paged_case(bs=8, kvh=2, g=2, hd=32, w=5, n=2,
                                         seed=3)
    base = np.asarray(paged_flash_decode_jnp(q, kp, vp, tables, pos,
                                             tile_blocks=1), np.float32)
    for tile in (2, 3, 5, 128):
        got = np.asarray(paged_flash_decode_jnp(q, kp, vp, tables, pos,
                                                tile_blocks=tile),
                         np.float32)
        np.testing.assert_allclose(base, got, rtol=1e-5, atol=1e-6,
                                   err_msg=f"tile={tile}")


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_paged_flash_decode_scratch_invariance(backend):
    """Output is invariant to the contents of the scratch block and of pool
    blocks no table references below ``pos`` — masked positions contribute
    exactly zero (the hypothesis sweep in test_properties.py randomises
    this; here one deterministic case pins both backends)."""
    rng = np.random.default_rng(0)
    bs, kvh, g, hd, nb = 8, 2, 2, 16, 8
    q = jnp.asarray(rng.normal(size=(2, kvh, g, hd)), jnp.float32)
    kp = np.asarray(rng.normal(size=(nb, bs, kvh, hd)), np.float32)
    vp = np.asarray(rng.normal(size=(nb, bs, kvh, hd)), np.float32)
    tables = jnp.asarray([[3, 5], [6, 0]], jnp.int32)   # lane 1: scratch tail
    pos = jnp.asarray([15, 4], jnp.int32)
    kp2, vp2 = kp.copy(), vp.copy()
    for b in (0, 1, 2, 4, 7):                 # scratch + unreferenced
        kp2[b] = 99.0
        vp2[b] = -99.0
    out1 = np.asarray(ops.paged_flash_decode(
        q, jnp.asarray(kp), jnp.asarray(vp), tables, pos, backend=backend))
    out2 = np.asarray(ops.paged_flash_decode(
        q, jnp.asarray(kp2), jnp.asarray(vp2), tables, pos,
        backend=backend))
    np.testing.assert_array_equal(out1, out2)


@pytest.mark.parametrize("kernel", ["pallas", "jnp"])
def test_paged_attn_decode_kernel_vs_gather(kernel):
    """Model-level GQA pin: ``paged_attn_decode`` through the kernel route
    equals the gather + dense-attend reference route (yi-6b reduced:
    4 heads over 2 kv heads)."""
    from repro.configs import get_reduced
    from repro.models import attention as attn
    cfg = get_reduced("yi-6b")
    key = jax.random.PRNGKey(0)
    p = attn.attn_init(key, cfg, jnp.float32)
    bs, w, n = 8, 3, 3
    cache = attn.paged_init_cache(cfg, n * w + 1, bs, jnp.float32)
    cache = {k: jax.random.normal(jax.random.PRNGKey(1), v.shape, v.dtype)
             for k, v in cache.items()}
    x = jax.random.normal(jax.random.PRNGKey(2), (n, 1, cfg.d_model))
    tables = jnp.asarray(1 + np.arange(n * w).reshape(n, w), jnp.int32)
    pos = jnp.asarray([5, 17, 23], jnp.int32)
    y_ref, c_ref = attn.paged_attn_decode(p, cfg, x, cache, tables, pos)
    y_ker, c_ker = attn.paged_attn_decode(p, cfg, x, cache, tables, pos,
                                          kernel=kernel)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ker),
                               rtol=2e-5, atol=2e-5)
    for k in c_ref:                      # scatter identical on both routes
        np.testing.assert_array_equal(np.asarray(c_ref[k]),
                                      np.asarray(c_ker[k]))


@pytest.mark.parametrize("kernel", ["pallas", "jnp"])
def test_mla_paged_kernel_vs_attend(kernel):
    """MLA pin: absorbed paged decode through the kernel equals the
    ``_mla_attend`` gather reference (deepseek-v2-lite reduced latents)."""
    from repro.configs import get_reduced
    from repro.models import mla
    cfg = get_reduced("deepseek-v2-lite")
    p = mla.mla_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    bs, w, n = 8, 3, 2
    cache = mla.mla_paged_init_cache(cfg, n * w + 1, bs, jnp.float32)
    cache = {k: jax.random.normal(jax.random.PRNGKey(1), v.shape, v.dtype)
             for k, v in cache.items()}
    x = jax.random.normal(jax.random.PRNGKey(2), (n, 1, cfg.d_model))
    tables = jnp.asarray(1 + np.arange(n * w).reshape(n, w), jnp.int32)
    pos = jnp.asarray([11, 23], jnp.int32)
    y_ref, c_ref = mla.mla_paged_decode(p, cfg, x, cache, tables, pos)
    y_ker, c_ker = mla.mla_paged_decode(p, cfg, x, cache, tables, pos,
                                        kernel=kernel)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ker),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(c_ref["lat"]),
                                  np.asarray(c_ker["lat"]))


@pytest.mark.parametrize("g,h,l,n,p", [
    (4, 3, 32, 16, 64), (2, 8, 128, 128, 64), (6, 1, 64, 32, 32),
    (1, 24, 128, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunk(g, h, l, n, p, dtype):
    """Mamba-2 SSD within-chunk kernel vs its oracle (and transitively the
    model's y_diag einsum, which the oracle mirrors)."""
    from repro.kernels.ssd_chunk import ssd_chunk, ssd_chunk_ref
    c = jnp.asarray(RNG.normal(size=(g, l, n)) * 0.3, dtype)
    b = jnp.asarray(RNG.normal(size=(g, l, n)) * 0.3, dtype)
    x = jnp.asarray(RNG.normal(size=(g, h, l, p)) * 0.5, dtype)
    a = jnp.asarray(-np.abs(RNG.normal(size=(g, h, l))).cumsum(-1) * 0.1,
                    jnp.float32)
    yr = np.asarray(ssd_chunk_ref(c, b, x, a), np.float32)
    yp = np.asarray(ssd_chunk(c, b, x, a), np.float32)
    tol = 5e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(yr, yp, rtol=tol, atol=tol)
