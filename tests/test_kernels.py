"""Pallas kernel validation: interpret-mode kernel body vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("t,e,k", [
    (7, 16, 2), (64, 64, 6), (33, 160, 6), (256, 128, 1), (4, 8, 8),
    (130, 100, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_gating(t, e, k, dtype):
    logits = jnp.asarray(RNG.normal(size=(t, e)) * 2, dtype)
    wr, ir = ref.topk_gating_ref(logits, k)
    wp, ip = ops.topk_gating(logits, k, backend="pallas")
    np.testing.assert_allclose(np.sort(np.asarray(wr)), np.sort(np.asarray(wp)),
                               rtol=2e-3, atol=1e-5)
    for row in range(t):
        assert set(np.asarray(ir)[row].tolist()) == \
            set(np.asarray(ip)[row].tolist()), row
    # weights sum to 1 after renormalisation
    np.testing.assert_allclose(np.asarray(wp).sum(-1), 1.0, atol=1e-3)


@pytest.mark.parametrize("k,d,f", [
    (1, 128, 128), (2, 128, 256), (6, 256, 512), (4, 512, 1024),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_ffn(k, d, f, dtype):
    x = jnp.asarray(RNG.normal(size=(d,)), dtype)
    w = jnp.asarray(RNG.random(k) + 0.1, jnp.float32)
    wg = jnp.asarray(RNG.normal(size=(k, d, f)) * 0.05, dtype)
    wu = jnp.asarray(RNG.normal(size=(k, d, f)) * 0.05, dtype)
    wd = jnp.asarray(RNG.normal(size=(k, f, d)) * 0.05, dtype)
    yr = np.asarray(ref.expert_ffn_ref(x, w, wg, wu, wd), np.float32)
    yp = np.asarray(ops.expert_ffn(x, w, wg, wu, wd, backend="pallas"),
                    np.float32)
    tol = 5e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(yr, yp, rtol=tol, atol=tol)


@pytest.mark.parametrize("s,kvh,g,hd,vl", [
    (128, 2, 4, 64, 100), (1024, 1, 16, 128, 1024), (96, 4, 1, 32, 50),
    (2048, 8, 2, 64, 1500), (512, 1, 1, 128, 1),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(s, kvh, g, hd, vl, dtype):
    h = kvh * g
    q = jnp.asarray(RNG.normal(size=(h, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(s, kvh, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(s, kvh, hd)), dtype)
    yr = np.asarray(ref.flash_decode_ref(q, k, v, vl), np.float32)
    yp = np.asarray(ops.flash_decode(q, k, v, vl, backend="pallas"),
                    np.float32)
    tol = 5e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(yr, yp, rtol=tol, atol=tol)


def test_flash_decode_matches_model_attention():
    """The kernel must agree with the model's decode attention math."""
    from repro.configs import get_reduced
    cfg = get_reduced("yi-6b")
    s, kvh, hd, h = 64, cfg.num_kv_heads, cfg.hd, cfg.num_heads
    q = jnp.asarray(RNG.normal(size=(h, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(s, kvh, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(s, kvh, hd)), jnp.float32)
    out = ops.flash_decode(q, k, v, 40, backend="pallas")
    ref_out = ref.flash_decode_ref(q, k, v, 40)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("g,h,l,n,p", [
    (4, 3, 32, 16, 64), (2, 8, 128, 128, 64), (6, 1, 64, 32, 32),
    (1, 24, 128, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunk(g, h, l, n, p, dtype):
    """Mamba-2 SSD within-chunk kernel vs its oracle (and transitively the
    model's y_diag einsum, which the oracle mirrors)."""
    from repro.kernels.ssd_chunk import ssd_chunk, ssd_chunk_ref
    c = jnp.asarray(RNG.normal(size=(g, l, n)) * 0.3, dtype)
    b = jnp.asarray(RNG.normal(size=(g, l, n)) * 0.3, dtype)
    x = jnp.asarray(RNG.normal(size=(g, h, l, p)) * 0.5, dtype)
    a = jnp.asarray(-np.abs(RNG.normal(size=(g, h, l))).cumsum(-1) * 0.1,
                    jnp.float32)
    yr = np.asarray(ssd_chunk_ref(c, b, x, a), np.float32)
    yp = np.asarray(ssd_chunk(c, b, x, a), np.float32)
    tol = 5e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(yr, yp, rtol=tol, atol=tol)
