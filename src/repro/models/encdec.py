"""Encoder-decoder model (seamless-m4t): bidirectional encoder over stubbed
audio-frame embeddings + causal decoder with cross-attention.

The mel-spectrogram / conformer frontend is a ShapeDtypeStruct stub per the
assignment carve-out — the encoder consumes precomputed frame embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch import shardctx
from repro.models import attention as attn
from repro.models.common import (dense_init, dtype_of, ffn_apply, ffn_init,
                                 rms_norm, rms_norm_init)


# ---------------------------------------------------------------------------
# Encoder

def _enc_cfg(cfg):
    e = cfg.encdec
    return cfg.replace(num_heads=e.enc_heads, num_kv_heads=e.enc_heads,
                       d_ff=e.enc_d_ff)


def encoder_init(key, cfg, dtype):
    ecfg = _enc_cfg(cfg)
    e = cfg.encdec
    keys = jax.random.split(key, e.enc_layers + 1)
    layers = []
    for i in range(e.enc_layers):
        k1, k2 = jax.random.split(keys[i])
        layers.append({
            "ln1": rms_norm_init(cfg.d_model, dtype),
            "attn": attn.attn_init(k1, ecfg, dtype),
            "ln2": rms_norm_init(cfg.d_model, dtype),
            "ffn": ffn_init(k2, cfg.d_model, e.enc_d_ff, dtype),
        })
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "in_proj": dense_init(keys[-1], cfg.frontend_dim, cfg.d_model, dtype),
        "layers": stacked,
        "final_ln": rms_norm_init(cfg.d_model, dtype),
    }


def encoder_apply(params, cfg, frames):
    """frames: (B, S_frames, frontend_dim) -> (B, S_frames, d_model)."""
    ecfg = _enc_cfg(cfg)
    x = jnp.einsum("bsf,fd->bsd", frames.astype(dtype_of(cfg)),
                   params["in_proj"])
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    def body(xc, lp):
        h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
        o, _ = attn.attn_apply(lp["attn"], ecfg, "global", h, positions,
                               "full", causal=False)
        xc = xc + o
        h = rms_norm(xc, lp["ln2"], cfg.norm_eps)
        xc = xc + ffn_apply(lp["ffn"], h, cfg.ffn_kind)
        return shardctx.constrain_act(xc), None

    if shardctx.current_remat():
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return rms_norm(x, params["final_ln"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder with cross-attention (scan over layers)

def decoder_init(key, cfg, dtype):
    keys = jax.random.split(key, cfg.num_layers)
    layers = []
    for i in range(cfg.num_layers):
        k1, k2, k3 = jax.random.split(keys[i], 3)
        layers.append({
            "ln1": rms_norm_init(cfg.d_model, dtype),
            "self_attn": attn.attn_init(k1, cfg, dtype),
            "ln_x": rms_norm_init(cfg.d_model, dtype),
            "cross_attn": attn.cross_attn_init(k2, cfg, dtype),
            "ln2": rms_norm_init(cfg.d_model, dtype),
            "ffn": ffn_init(k3, cfg.d_model, cfg.d_ff, dtype),
        })
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def cross_memory(params, cfg, enc_out):
    """Precompute per-layer cross-attention K/V (stacked leading L)."""
    def per_layer(lp):
        return attn.cross_attn_memory(lp["cross_attn"], enc_out)
    return jax.vmap(per_layer)(params)  # maps over stacked layer dim


def decoder_apply(params, cfg, x, positions, memory, mode,
                  caches=None, pos=None, cache_len: int = 0):
    """memory: stacked per-layer {"k","v"}; caches: stacked self-attn caches."""
    use_cache = mode == "decode"        # prefill builds caches, reads none

    def body(carry, xs):
        xc = carry
        lp, mem, cc = xs
        h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
        o, nc = attn.attn_apply(lp["self_attn"], cfg, "global", h, positions,
                                mode, cc if use_cache else None, pos,
                                cache_len)
        xc = xc + o
        h = rms_norm(xc, lp["ln_x"], cfg.norm_eps)
        xc = xc + attn.cross_attn_apply(lp["cross_attn"], cfg, h, mem)
        h = rms_norm(xc, lp["ln2"], cfg.norm_eps)
        xc = xc + ffn_apply(lp["ffn"], h, cfg.ffn_kind)
        return shardctx.constrain_act(xc), (nc if nc is not None else {})

    if mode == "full" and shardctx.current_remat():
        body = jax.checkpoint(body, prevent_cse=False)
    if use_cache:
        cc_in = caches
    else:  # leafless pytree with the right scan length
        cc_in = {"_": jnp.zeros((cfg.num_layers, 1), jnp.int8)}
    x, new_caches = jax.lax.scan(body, x, (params, memory, cc_in))
    return x, (new_caches if mode != "full" else None)


def decoder_cache_init(cfg, batch, cache_len, dtype):
    per = attn.init_cache(cfg, "global", batch, cache_len, dtype)
    return jax.tree.map(
        lambda leaf: jnp.zeros((cfg.num_layers,) + leaf.shape, leaf.dtype),
        per)
