"""Decoder assembly: blocks -> scan-over-layer-groups -> LM.

Layer layout (keeps HLO size ~O(pattern length), not O(num_layers)):
  head blocks   — ``moe.first_dense_layers`` unrolled layers (dense FFN)
  scan blocks   — ``G`` repetitions of ``block_pattern``; params/caches are
                  stacked with leading dim G and driven by ``jax.lax.scan``
  tail blocks   — ``num_layers`` remainder, unrolled

Every apply returns ``extras`` carrying routed-expert ids for MoE layers —
the raw material for the paper's activation traces.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.launch import shardctx
from repro.models import attention as attn
from repro.models import mla, moe, rglru, ssd
from repro.models.common import (dense_init, dtype_of, ffn_apply, ffn_init,
                                 rms_norm, rms_norm_init)

Params = Dict[str, Any]


def _layer_split(cfg):
    n_head = cfg.moe.first_dense_layers if cfg.moe else 0
    pat = len(cfg.block_pattern)
    rem = cfg.num_layers - n_head
    return n_head, rem // pat, rem % pat


def _layer_is_moe(cfg, layer_idx: int) -> bool:
    if cfg.moe is None:
        return False
    if cfg.layer_kinds()[layer_idx] == "ssd":
        return False
    return layer_idx >= cfg.moe.first_dense_layers


# ---------------------------------------------------------------------------
# Single block

def block_init(key, cfg, kind: str, is_moe: bool, dtype):
    ks = jax.random.split(key, 3)
    p: Params = {"ln1": rms_norm_init(cfg.d_model, dtype)}
    if kind == "mla":
        p["attn"] = mla.mla_init(ks[0], cfg, dtype)
    elif kind in ("global", "local", "chunked"):
        p["attn"] = attn.attn_init(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["rec"] = rglru.rglru_init(ks[0], cfg, dtype)
    elif kind == "ssd":
        p["ssd"] = ssd.ssd_init(ks[0], cfg, dtype)
        return p                                     # mamba block: no FFN
    p["ln2"] = rms_norm_init(cfg.d_model, dtype)
    if is_moe:
        p["moe"] = moe.moe_init(ks[1], cfg, dtype)
    else:
        dff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.d_ff_dense:
            dff = cfg.moe.d_ff_dense
        p["ffn"] = ffn_init(ks[1], cfg.d_model, dff, dtype)
    return p


def block_cache_init(cfg, kind: str, batch: int, cache_len: int, dtype):
    if kind == "mla":
        return mla.mla_init_cache(cfg, batch, cache_len, dtype)
    if kind in ("global", "local", "chunked"):
        return attn.init_cache(cfg, kind, batch, cache_len, dtype)
    if kind == "rglru":
        return rglru.rglru_init_state(cfg, batch, dtype)
    if kind == "ssd":
        return ssd.ssd_init_state(cfg, batch, dtype)
    raise ValueError(kind)


# Attention kinds whose decode KV grows with the sequence — these page
# through block tables in the paged serving engine. Ring-buffer kinds
# (local/chunked) and recurrent kinds keep bounded per-request rows.
PAGED_KINDS = ("global", "mla")


def block_paged_cache_init(cfg, kind: str, num_blocks: int, block_size: int,
                           row_batch: int, dtype):
    """Per-layer decode cache for the block-paged serving engine.

    Paged kinds get a (num_blocks, block_size, ...) pool sharing one block-id
    space across layers (serving/kvpool.py); bounded kinds keep ``row_batch``
    contiguous rows exactly like ``block_cache_init`` (the scratch row
    included).
    """
    if kind == "mla":
        return mla.mla_paged_init_cache(cfg, num_blocks, block_size, dtype)
    if kind == "global":
        return attn.paged_init_cache(cfg, num_blocks, block_size, dtype)
    if kind in ("local", "chunked"):
        return attn.init_cache(cfg, kind, row_batch, 0, dtype)  # ring-sized
    # recurrent kinds have no DecodeCore decode path at all, so the paged
    # engine's paged_ok gate rejects them before reaching here
    raise ValueError(f"no paged decode cache for layer kind {kind!r}")


def block_paged_decode(p, cfg, kind: str, x, cache, tables, pos,
                       kernel=None):
    """Attention half of one paged decode step (ln1 + attend + residual).

    x: (N,1,D); tables: (N,W); pos: (N,). ``kernel`` selects the paged
    flash-decode backend (kernels/paged_attention.py) for every paged kind;
    None keeps each family's gather + dense-attend parity reference.
    Returns (x, new_cache).
    """
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "mla":
        o, nc = mla.mla_paged_decode(p["attn"], cfg, h, cache, tables, pos,
                                     kernel=kernel)
    elif kind == "global":
        o, nc = attn.paged_attn_decode(p["attn"], cfg, h, cache, tables,
                                       pos, kernel=kernel)
    else:
        raise ValueError(f"layer kind {kind!r} does not page")
    return x + o, nc


def block_paged_prefill(p, cfg, kind: str, x, cache, table, t0, n_valid,
                        kernel=None):
    """Attention half of one paged prefill chunk for a single request.

    x: (1,C,D); table: (W,); t0/n_valid scalars. Same kernel selection as
    ``block_paged_decode`` — chunk tokens become kernel lanes, keeping the
    chunked-prefill stream token-identical to decode. Returns (x, new_cache).
    """
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "mla":
        o, nc = mla.mla_paged_prefill(p["attn"], cfg, h, cache, table, t0,
                                      n_valid, kernel=kernel)
    elif kind == "global":
        o, nc = attn.paged_attn_prefill(p["attn"], cfg, h, cache, table, t0,
                                        n_valid, kernel=kernel)
    else:
        raise ValueError(f"layer kind {kind!r} does not page")
    return x + o, nc


def block_paged_copy(cfg, kind: str, cache, src, dst):
    """Copy pool page ``src -> dst`` for one paged layer — the device side
    of copy-on-write when a request must write into a block it shares with
    siblings (prefix cache). Bounded (ring/recurrent) kinds have no pages
    and never share, so only paged kinds dispatch here."""
    if kind == "mla":
        return mla.mla_paged_copy_block(cache, src, dst)
    if kind == "global":
        return attn.paged_copy_block(cache, src, dst)
    raise ValueError(f"layer kind {kind!r} does not page")


def block_apply(p, cfg, kind: str, x, positions, mode: str,
                cache=None, pos=None, cache_len: int = 0):
    """Returns (x, new_cache, extras)."""
    extras: Params = {}
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "mla":
        out, new_cache = mla.mla_apply(p["attn"], cfg, h, positions, mode,
                                       cache, pos, cache_len)
    elif kind in ("global", "local", "chunked"):
        out, new_cache = attn.attn_apply(p["attn"], cfg, kind, h, positions,
                                         mode, cache, pos, cache_len)
    elif kind == "rglru":
        if mode == "decode":
            out, new_cache = rglru.rglru_step(p["rec"], cfg, h, cache)
        elif mode == "prefill":
            out, new_cache = rglru.rglru_apply_full(p["rec"], cfg, h,
                                                    return_state=True)
        else:
            out, new_cache = rglru.rglru_apply_full(p["rec"], cfg, h), None
    elif kind == "ssd":
        if mode == "decode":
            out, new_cache = ssd.ssd_step(p["ssd"], cfg, h, cache)
        elif mode == "prefill":
            out, new_cache = ssd.ssd_apply_full(p["ssd"], cfg, h,
                                                return_state=True)
        else:
            out, new_cache = ssd.ssd_apply_full(p["ssd"], cfg, h), None
        return x + out, new_cache, extras            # no FFN sub-block
    else:
        raise ValueError(kind)
    x = x + out

    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, aux, idx = moe.moe_apply(p["moe"], cfg, h,
                                    decode=(mode == "decode"))
        extras["moe_aux"] = aux
        extras["experts"] = idx
    else:
        y = ffn_apply(p["ffn"], h, cfg.ffn_kind)
    return x + y, new_cache, extras


# ---------------------------------------------------------------------------
# Full decoder stack

def stack_init(key, cfg, dtype) -> Params:
    kinds = cfg.layer_kinds()
    n_head, n_groups, n_tail = _layer_split(cfg)
    pat = len(cfg.block_pattern)
    keys = jax.random.split(key, cfg.num_layers)

    head = [block_init(keys[i], cfg, kinds[i], _layer_is_moe(cfg, i), dtype)
            for i in range(n_head)]

    scan_params = []
    for j in range(pat):
        per_group = []
        for g in range(n_groups):
            li = n_head + g * pat + j
            per_group.append(block_init(keys[li], cfg, kinds[li],
                                        _layer_is_moe(cfg, li), dtype))
        scan_params.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
                           if n_groups else {})

    tail_base = n_head + n_groups * pat
    tail = [block_init(keys[tail_base + i], cfg, kinds[tail_base + i],
                       _layer_is_moe(cfg, tail_base + i), dtype)
            for i in range(n_tail)]
    return {"head": head, "scan": tuple(scan_params), "tail": tail}


def stack_cache_init(cfg, batch: int, cache_len: int, dtype) -> Params:
    kinds = cfg.layer_kinds()
    n_head, n_groups, n_tail = _layer_split(cfg)
    pat = len(cfg.block_pattern)

    def mk(i):
        return block_cache_init(cfg, kinds[i], batch, cache_len, dtype)

    head = [mk(i) for i in range(n_head)]
    scan = []
    for j in range(pat):
        per = [mk(n_head + g * pat + j) for g in range(n_groups)]
        scan.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per)
                    if n_groups else {})
    tail_base = n_head + n_groups * pat
    tail = [mk(tail_base + i) for i in range(n_tail)]
    return {"head": head, "scan": tuple(scan), "tail": tail}


def stack_apply(params, cfg, x, positions, mode: str,
                caches: Optional[Params] = None, pos=None, cache_len: int = 0):
    """Run all layers. Returns (x, new_caches, extras_list).

    extras_list: per-layer dicts for head/tail; for scanned groups the
    entries are stacked with leading dim G (one entry per pattern position).
    """
    kinds = cfg.layer_kinds()
    n_head, n_groups, n_tail = _layer_split(cfg)
    pat = len(cfg.block_pattern)
    use_cache = mode == "decode"        # prefill BUILDS caches, reads none
    new_caches: Params = {"head": [], "scan": None, "tail": []}
    extras_out = {"head": [], "scan": None, "tail": []}

    for i in range(n_head):
        c = caches["head"][i] if use_cache else None
        x, nc, ex = block_apply(params["head"][i], cfg, kinds[i], x,
                                positions, mode, c, pos, cache_len)
        new_caches["head"].append(nc)
        extras_out["head"].append(ex)

    if n_groups:
        scan_kinds = [kinds[n_head + j] for j in range(pat)]

        def body(carry, xs):
            xc = carry
            pp, cc = xs
            ncs, exs = [], []
            for j in range(pat):
                c = cc[j] if use_cache else None
                xc, nc, ex = block_apply(pp[j], cfg, scan_kinds[j], xc,
                                         positions, mode, c, pos, cache_len)
                ncs.append(nc if nc is not None else {})
                exs.append(ex)
            xc = shardctx.constrain_act(xc)
            return xc, (tuple(ncs), tuple(exs))

        if mode == "full" and shardctx.current_remat():
            body = jax.checkpoint(body, prevent_cse=False)
        cc_in = caches["scan"] if use_cache else tuple({} for _ in range(pat))
        x, (scan_caches, scan_extras) = jax.lax.scan(
            body, x, (params["scan"], cc_in))
        new_caches["scan"] = scan_caches
        extras_out["scan"] = scan_extras
    else:
        new_caches["scan"] = tuple({} for _ in range(pat))
        extras_out["scan"] = tuple({} for _ in range(pat))

    tail_base = n_head + n_groups * pat
    for i in range(n_tail):
        c = caches["tail"][i] if use_cache else None
        x, nc, ex = block_apply(params["tail"][i], cfg, kinds[tail_base + i],
                                x, positions, mode, c, pos, cache_len)
        new_caches["tail"].append(nc)
        extras_out["tail"].append(ex)

    if mode == "full":
        new_caches = None
    return x, new_caches, extras_out


# ---------------------------------------------------------------------------
# LM wrapper (embeddings + stack + head), incl. stubbed modality frontends

def lm_init(key, cfg) -> Params:
    dtype = dtype_of(cfg)
    k_emb, k_stack, k_head, k_fe = jax.random.split(key, 4)
    p: Params = {
        "tok_emb": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dtype),
        "final_ln": rms_norm_init(cfg.d_model, dtype),
        "stack": stack_init(k_stack, cfg, dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)
    if cfg.frontend is not None:
        p["frontend_proj"] = dense_init(k_fe, cfg.frontend_dim, cfg.d_model,
                                        dtype)
    return p


def embed(params, cfg, tokens, modality=None):
    """tokens: (B, S_text) int32; modality: (B, S_m, frontend_dim) or None.

    VLM early fusion: projected patch embeddings are prepended to the token
    embeddings (the frontend itself is stubbed per the assignment).
    """
    x = jnp.take(params["tok_emb"], tokens, axis=0)
    n_prefix = 0
    if modality is not None and cfg.frontend == "vision":
        m = jnp.einsum("bsf,fd->bsd", modality.astype(x.dtype),
                       params["frontend_proj"])
        x = jnp.concatenate([m, x], axis=1)
        n_prefix = modality.shape[1]
    return x, n_prefix


def unembed(params, cfg, x):
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    w = params["tok_emb"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("btd,dv->btv", x, w).astype(jnp.float32)


def lm_apply(params, cfg, tokens, modality=None, mode: str = "full",
             caches=None, pos=None, cache_len: int = 0):
    x, n_prefix = embed(params, cfg, tokens, modality)
    b, t, _ = x.shape
    if mode == "decode":
        positions = jnp.full((b, 1), pos, jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    x, new_caches, extras = stack_apply(params["stack"], cfg, x, positions,
                                        mode, caches, pos, cache_len)
    logits = unembed(params, cfg, x)
    return logits, new_caches, extras, n_prefix


def collect_moe_aux(cfg, extras) -> jnp.ndarray:
    """Mean MoE load-balance loss across layers (0 if no MoE)."""
    losses = []
    for ex in extras["head"] + list(extras["tail"]):
        if "moe_aux" in ex:
            losses.append(ex["moe_aux"])
    for ex in extras["scan"]:
        if isinstance(ex, dict) and "moe_aux" in ex:
            losses.append(jnp.mean(ex["moe_aux"]))
    if not losses:
        return jnp.zeros((), jnp.float32)
    return jnp.mean(jnp.stack(losses))
