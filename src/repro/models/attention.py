"""GQA attention with global / sliding-window(local) / chunked masking.

Three entry points:
  * ``attn_apply(..., mode="full")``    — train / no-cache forward.
  * ``attn_apply(..., mode="prefill")`` — forward + build a decode cache.
  * ``attn_apply(..., mode="decode")``  — one token against the cache.

Prefill/train attention is q-chunked (``lax.scan`` over query blocks) so the
(T, S) score tensor is never materialised for long sequences — this is what
keeps the 32k prefill dry-run inside HBM. Decode caches:
  global  -> full-length buffer, write at ``pos``
  local   -> ring buffer of ``window`` slots, write at ``pos % window``
  chunked -> ring buffer of ``chunk`` slots; only slots from the current
             attention chunk are valid (llama4 iRoPE semantics)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch import shardctx
from repro.models.common import apply_rope, dense_init

Q_CHUNK = 1024
NEG_INF = -1e30


def attn_init(key, cfg, dtype):
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d, h * hd, dtype).reshape(d, h, hd),
        "wk": dense_init(k2, d, kvh * hd, dtype).reshape(d, kvh, hd),
        "wv": dense_init(k3, d, kvh * hd, dtype).reshape(d, kvh, hd),
        "wo": dense_init(k4, h * hd, d, dtype).reshape(h, hd, d),
    }


def _mask(qpos, kpos, kind: str, cfg, causal: bool):
    """(Tq, Sk) boolean validity mask from absolute positions."""
    q = qpos[:, None]
    k = kpos[None, :]
    if not causal:
        return jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    m = k <= q
    if kind == "local":
        m &= k > q - cfg.window
    elif kind == "chunked":
        m &= (k // cfg.chunk) == (q // cfg.chunk)
    return m


def _sdpa(q, k, v, mask):
    """q: (B,Tq,KVH,G,hd)  k,v: (B,S,KVH,hd)  mask: (Tq,S) -> (B,Tq,KVH,G,hd)."""
    hd = q.shape[-1]
    scores = jnp.einsum("btngd,bsnd->bngts", q, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bngts,bsnd->btngd", probs, v)


def sdpa_any(q, k, v, qpos, kpos, kind, cfg, causal=True):
    """Full attention, q-chunked when the sequence is long.

    q: (B,T,H,hd) grouped internally for GQA; k,v: (B,S,KVH,hd).
    qpos: (T,), kpos: (S,) absolute positions.
    """
    b, t, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    vd = v.shape[-1]                     # may differ from hd (MLA)
    qg = q.reshape(b, t, kvh, g, hd)
    if t < 2 * Q_CHUNK or t % Q_CHUNK != 0:
        out = _sdpa(qg, k, v, _mask(qpos, kpos, kind, cfg, causal))
        return out.reshape(b, t, h, vd)

    n = t // Q_CHUNK
    qc = qg.reshape(b, n, Q_CHUNK, kvh, g, hd)
    pc = qpos.reshape(n, Q_CHUNK)

    def body(_, xs):
        qi, pi = xs
        oi = _sdpa(qi, k, v, _mask(pi, kpos, kind, cfg, causal))
        return None, oi

    _, out = jax.lax.scan(body, None, (jnp.moveaxis(qc, 1, 0), pc))
    out = jnp.moveaxis(out, 0, 1).reshape(b, t, h, vd)
    return out


def _ring_len(kind: str, cfg) -> int:
    return {"local": cfg.window, "chunked": cfg.chunk}.get(kind, 0)


def init_cache(cfg, kind, batch, cache_len, dtype):
    ring = _ring_len(kind, cfg)
    s = ring if ring else cache_len
    kvh, hd = cfg.num_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, s, kvh, hd), dtype),
        "v": jnp.zeros((batch, s, kvh, hd), dtype),
    }


def _fill_cache(cfg, kind, k, v, t, cache_len):
    """Convert prefill k/v (already rope'd) into the decode cache layout."""
    ring = _ring_len(kind, cfg)
    if not ring:
        pad = cache_len - t
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return {"k": k, "v": v}
    # ring slot s holds the latest position p <= t-1 with p % ring == s
    s = jnp.arange(ring)
    src = (t - 1) - ((t - 1 - s) % ring)           # may be < 0 when t < ring
    src_c = jnp.clip(src, 0, t - 1)
    return {"k": k[:, src_c], "v": v[:, src_c]}


def _decode_valid(kind: str, cfg, slots, pos):
    """Validity of each cache slot when decoding token at absolute ``pos``."""
    if kind == "global":
        return slots <= pos
    ring = _ring_len(kind, cfg)
    w = pos % ring
    slot_pos = pos - ((w - slots) % ring)          # abs position held by slot
    if kind == "local":
        return slot_pos >= 0
    return (slots <= w) & (slot_pos >= 0)          # chunked: current chunk only


def attn_apply(p, cfg, kind, x, positions, mode, cache=None, pos=None,
               cache_len=0, causal=True):
    """Returns (out, new_cache). new_cache is None in full mode."""
    b, t, _ = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dnk->btnk", x, p["wk"])
    v = jnp.einsum("btd,dnk->btnk", x, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if mode in ("full", "prefill"):
        # head-shard inside attention (one seq->head reshard per layer
        # instead of per-q-chunk K/V gathers; see shardctx docstring)
        q, k, v = (shardctx.constrain_qkv(z) for z in (q, k, v))

    if mode in ("full", "prefill"):
        qpos = positions[0] if positions.ndim == 2 else positions
        out = sdpa_any(q, k, v, qpos, qpos, kind, cfg, causal)
        new_cache = None
        if mode == "prefill":
            new_cache = _fill_cache(cfg, kind, k, v, t, cache_len)
    else:  # decode: t == 1
        ring = _ring_len(kind, cfg)
        idx = pos % ring if ring else pos
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, idx, 0, 0))
        slots = jnp.arange(ck.shape[1])
        valid = _decode_valid(kind, cfg, slots, pos)
        kvh, hd = ck.shape[2], ck.shape[3]
        g = cfg.num_heads // kvh
        qg = q.reshape(b, 1, kvh, g, hd)
        scores = jnp.einsum("btngd,bsnd->bngts", qg, ck).astype(jnp.float32)
        scores = scores * (hd ** -0.5)
        scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bngts,bsnd->btngd", probs, cv)
        out = out.reshape(b, 1, cfg.num_heads, hd)
        new_cache = {"k": ck, "v": cv}

    y = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec decoder): static k/v memory, no cache update.

def cross_attn_init(key, cfg, dtype):
    return attn_init(key, cfg, dtype)


def cross_attn_apply(p, cfg, x, memory_kv):
    """x: (B,T,D); memory_kv: {"k","v"} (B,S,KVH,hd) precomputed."""
    b, t, _ = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k, v = memory_kv["k"], memory_kv["v"]
    kvh, hd = k.shape[2], k.shape[3]
    g = cfg.num_heads // kvh
    qg = q.reshape(b, t, kvh, g, hd)
    mask = jnp.ones((t, k.shape[1]), bool)
    out = _sdpa(qg, k, v, mask).reshape(b, t, cfg.num_heads, hd)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"])


def cross_attn_memory(p, x_enc):
    """Precompute cross-attention k/v from encoder output."""
    k = jnp.einsum("bsd,dnk->bsnk", x_enc, p["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", x_enc, p["wv"])
    return {"k": k, "v": v}
