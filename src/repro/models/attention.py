"""GQA attention with global / sliding-window(local) / chunked masking.

Three entry points:
  * ``attn_apply(..., mode="full")``    — train / no-cache forward.
  * ``attn_apply(..., mode="prefill")`` — forward + build a decode cache.
  * ``attn_apply(..., mode="decode")``  — one token against the cache.

Prefill/train attention is q-chunked (``lax.scan`` over query blocks) so the
(T, S) score tensor is never materialised for long sequences — this is what
keeps the 32k prefill dry-run inside HBM. Decode caches:
  global  -> full-length buffer, write at ``pos``
  local   -> ring buffer of ``window`` slots, write at ``pos % window``
  chunked -> ring buffer of ``chunk`` slots; only slots from the current
             attention chunk are valid (llama4 iRoPE semantics)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch import shardctx
from repro.models.common import apply_rope, dense_init

Q_CHUNK = 1024
NEG_INF = -1e30


def attn_init(key, cfg, dtype):
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d, h * hd, dtype).reshape(d, h, hd),
        "wk": dense_init(k2, d, kvh * hd, dtype).reshape(d, kvh, hd),
        "wv": dense_init(k3, d, kvh * hd, dtype).reshape(d, kvh, hd),
        "wo": dense_init(k4, h * hd, d, dtype).reshape(h, hd, d),
    }


def _mask(qpos, kpos, kind: str, cfg, causal: bool):
    """(Tq, Sk) boolean validity mask from absolute positions."""
    q = qpos[:, None]
    k = kpos[None, :]
    if not causal:
        return jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    m = k <= q
    if kind == "local":
        m &= k > q - cfg.window
    elif kind == "chunked":
        m &= (k // cfg.chunk) == (q // cfg.chunk)
    return m


def _sdpa(q, k, v, mask):
    """q: (B,Tq,KVH,G,hd)  k,v: (B,S,KVH,hd)  mask: (Tq,S) -> (B,Tq,KVH,G,hd)."""
    hd = q.shape[-1]
    scores = jnp.einsum("btngd,bsnd->bngts", q, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bngts,bsnd->btngd", probs, v)


def sdpa_any(q, k, v, qpos, kpos, kind, cfg, causal=True):
    """Full attention, q-chunked when the sequence is long.

    q: (B,T,H,hd) grouped internally for GQA; k,v: (B,S,KVH,hd).
    qpos: (T,), kpos: (S,) absolute positions.
    """
    b, t, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    vd = v.shape[-1]                     # may differ from hd (MLA)
    qg = q.reshape(b, t, kvh, g, hd)
    if t < 2 * Q_CHUNK or t % Q_CHUNK != 0:
        out = _sdpa(qg, k, v, _mask(qpos, kpos, kind, cfg, causal))
        return out.reshape(b, t, h, vd)

    n = t // Q_CHUNK
    qc = qg.reshape(b, n, Q_CHUNK, kvh, g, hd)
    pc = qpos.reshape(n, Q_CHUNK)

    def body(_, xs):
        qi, pi = xs
        oi = _sdpa(qi, k, v, _mask(pi, kpos, kind, cfg, causal))
        return None, oi

    _, out = jax.lax.scan(body, None, (jnp.moveaxis(qc, 1, 0), pc))
    out = jnp.moveaxis(out, 0, 1).reshape(b, t, h, vd)
    return out


def _ring_len(kind: str, cfg) -> int:
    return {"local": cfg.window, "chunked": cfg.chunk}.get(kind, 0)


def init_cache(cfg, kind, batch, cache_len, dtype):
    ring = _ring_len(kind, cfg)
    s = ring if ring else cache_len
    kvh, hd = cfg.num_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, s, kvh, hd), dtype),
        "v": jnp.zeros((batch, s, kvh, hd), dtype),
    }


def _fill_cache(cfg, kind, k, v, t, cache_len):
    """Convert prefill k/v (already rope'd) into the decode cache layout."""
    ring = _ring_len(kind, cfg)
    if not ring:
        pad = cache_len - t
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return {"k": k, "v": v}
    # ring slot s holds the latest position p <= t-1 with p % ring == s
    s = jnp.arange(ring)
    src = (t - 1) - ((t - 1 - s) % ring)           # may be < 0 when t < ring
    src_c = jnp.clip(src, 0, t - 1)
    return {"k": k[:, src_c], "v": v[:, src_c]}


def _gqa_attend(q, ck, cv, valid, out_dtype):
    """Grouped-query decode attention shared by the contiguous decode
    branch and the paged paths — one implementation so the paged engine's
    token-identity to contiguous decode can't drift.

    q: (B,T,H,hd); ck/cv: (B,S,KVH,hd); valid broadcastable to (B,T,S).
    Returns (B,T,H,hd).
    """
    b, t, h, hd = q.shape
    kvh = ck.shape[2]
    g = h // kvh
    qg = q.reshape(b, t, kvh, g, hd)
    scores = jnp.einsum("btngd,bsnd->bngts", qg, ck).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    valid = jnp.broadcast_to(valid, (b, t, ck.shape[1]))
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(out_dtype)
    out = jnp.einsum("bngts,bsnd->btngd", probs, cv)
    return out.reshape(b, t, h, hd)


def _decode_valid(kind: str, cfg, slots, pos):
    """Validity of each cache slot when decoding token at absolute ``pos``."""
    if kind == "global":
        return slots <= pos
    ring = _ring_len(kind, cfg)
    w = pos % ring
    slot_pos = pos - ((w - slots) % ring)          # abs position held by slot
    if kind == "local":
        return slot_pos >= 0
    return (slots <= w) & (slot_pos >= 0)          # chunked: current chunk only


def attn_apply(p, cfg, kind, x, positions, mode, cache=None, pos=None,
               cache_len=0, causal=True):
    """Returns (out, new_cache). new_cache is None in full mode."""
    b, t, _ = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dnk->btnk", x, p["wk"])
    v = jnp.einsum("btd,dnk->btnk", x, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if mode in ("full", "prefill"):
        # head-shard inside attention (one seq->head reshard per layer
        # instead of per-q-chunk K/V gathers; see shardctx docstring)
        q, k, v = (shardctx.constrain_qkv(z) for z in (q, k, v))

    if mode in ("full", "prefill"):
        qpos = positions[0] if positions.ndim == 2 else positions
        out = sdpa_any(q, k, v, qpos, qpos, kind, cfg, causal)
        new_cache = None
        if mode == "prefill":
            new_cache = _fill_cache(cfg, kind, k, v, t, cache_len)
    else:  # decode: t == 1
        ring = _ring_len(kind, cfg)
        idx = pos % ring if ring else pos
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, idx, 0, 0))
        slots = jnp.arange(ck.shape[1])
        valid = _decode_valid(kind, cfg, slots, pos)
        out = _gqa_attend(q, ck, cv, valid[None, None, :], x.dtype)
        new_cache = {"k": ck, "v": cv}

    y = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# Paged KV cache (block-table) decode path — serving/kvpool.py owns the block
# id space; here blocks are just the leading axis of the pool tensors. The
# contiguous row cache above remains the fallback (batch-1 engine, training).
#
# The *read* side has two routes: ``kernel=None`` gathers every lane's pages
# into a contiguous (N, W*block_size, ...) copy and attends densely (the
# parity reference), while ``kernel`` in {"jnp", "pallas", "tpu"} runs the
# paged flash-decode kernel (kernels/paged_attention.py), which walks the
# block table in place — no materialised copy on the hot path.

def paged_init_cache(cfg, num_blocks: int, block_size: int, dtype):
    """Block-paged pool for a *global* attention layer: block b, slot s holds
    K/V for absolute position ``table.index(b) * block_size + s``."""
    kvh, hd = cfg.num_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((num_blocks, block_size, kvh, hd), dtype),
        "v": jnp.zeros((num_blocks, block_size, kvh, hd), dtype),
    }


def _paged_scatter(cache, k_new, v_new, bids, slots):
    """Write one K/V entry per request: k_new/v_new (N, KVH, hd),
    bids/slots (N,). Distinct requests own distinct blocks so the batched
    scatter is race-free; padding lanes all target the scratch block."""
    return {
        "k": cache["k"].at[bids, slots].set(k_new),
        "v": cache["v"].at[bids, slots].set(v_new),
    }


def paged_copy_block(cache, src, dst):
    """Copy one pool page ``src -> dst`` (both K and V planes) — the device
    half of copy-on-write: a request about to write into a block it shares
    with siblings first duplicates the page into its private block."""
    return {
        "k": cache["k"].at[dst].set(cache["k"][src]),
        "v": cache["v"].at[dst].set(cache["v"][src]),
    }


def _paged_gather(cache, tables):
    """tables: (N, W) int32 -> K/V (N, W*block_size, KVH, hd) in absolute
    position order (logical block i of the table covers positions
    [i*bs, (i+1)*bs))."""
    n, w = tables.shape
    bs = cache["k"].shape[1]
    k = jnp.take(cache["k"], tables.reshape(-1), axis=0)
    v = jnp.take(cache["v"], tables.reshape(-1), axis=0)
    shp = (n, w * bs) + cache["k"].shape[2:]
    return k.reshape(shp), v.reshape(shp)


def _paged_qkv(p, cfg, x, positions):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dnk->btnk", x, p["wk"])
    v = jnp.einsum("btd,dnk->btnk", x, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _paged_kernel_attend(q, cache, tables, pos, kernel: str):
    """Flash-decode the lanes in ``q`` through the block pool.

    q: (L,H,hd) — one query token per lane; tables: (L,W); pos: (L,).
    Returns (L,H,hd). The kernel masks positions > pos per lane, which
    covers causality, the partially-filled last block, scratch-padded
    table entries, and pad lanes alike.
    """
    from repro.kernels import ops
    l, h, hd = q.shape
    kvh = cache["k"].shape[2]
    qg = q.reshape(l, kvh, h // kvh, hd)
    out = ops.paged_flash_decode(qg, cache["k"], cache["v"], tables, pos,
                                 backend=kernel)
    return out.reshape(l, h, hd)


def paged_attn_decode(p, cfg, x, cache, tables, pos, kernel=None):
    """One decode token per lane through the paged cache.

    x: (N,1,D); tables: (N,W) int32 block tables; pos: (N,) positions.
    Returns (y (N,1,D), new cache). Global attention only — ring-buffer
    kinds keep their bounded per-row caches. ``kernel`` selects the paged
    flash-decode backend; None keeps the gather + dense-attend reference.
    """
    bs = cache["k"].shape[1]
    q, k, v = _paged_qkv(p, cfg, x, pos[:, None])
    bids = jnp.take_along_axis(tables, (pos // bs)[:, None], axis=1)[:, 0]
    cache = _paged_scatter(cache, k[:, 0], v[:, 0], bids, pos % bs)
    if kernel is None:
        ck, cv = _paged_gather(cache, tables)
        valid = (jnp.arange(ck.shape[1])[None, None, :]
                 <= pos[:, None, None])                    # (N,1,S)
        out = _gqa_attend(q, ck, cv, valid, x.dtype)
    else:
        out = _paged_kernel_attend(q[:, 0], cache, tables, pos,
                                   kernel)[:, None]
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    return y, cache


def paged_attn_prefill(p, cfg, x, cache, table, t0, n_valid, kernel=None):
    """One prompt chunk of a single request through the paged cache.

    x: (1,C,D) — C is the (padded) chunk bucket, the first ``n_valid``
    tokens are real and sit at absolute positions t0..t0+n_valid-1; pad
    tokens scatter to the scratch block. Per-token math is identical to
    feeding the chunk token-by-token through ``paged_attn_decode`` — on the
    kernel route each chunk token literally becomes one kernel lane sharing
    the request's table — so the chunked-prefill stream stays
    token-identical to the decode path.
    """
    c = x.shape[1]
    bs = cache["k"].shape[1]
    idx = jnp.arange(c)
    positions = t0 + idx[None, :]                          # (1,C)
    q, k, v = _paged_qkv(p, cfg, x, positions)
    real = idx < n_valid
    p_abs = t0 + idx
    lb = jnp.clip(p_abs // bs, 0, table.shape[0] - 1)
    bids = jnp.where(real, jnp.take(table, lb), 0)
    slots = jnp.where(real, p_abs % bs, 0)
    cache = _paged_scatter(cache, k[0], v[0], bids, slots)
    if kernel is None:
        ck, cv = _paged_gather(cache, table[None, :])      # (1,S,KVH,hd)
        valid = (jnp.arange(ck.shape[1])[None, None, :]
                 <= positions[:, :, None])                 # (1,C,S)
        out = _gqa_attend(q, ck, cv, valid, x.dtype)
    else:
        lane_tables = jnp.broadcast_to(table[None, :], (c, table.shape[0]))
        out = _paged_kernel_attend(q[0], cache, lane_tables,
                                   positions[0], kernel)[None]
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    return y, cache


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec decoder): static k/v memory, no cache update.

def cross_attn_init(key, cfg, dtype):
    return attn_init(key, cfg, dtype)


def cross_attn_apply(p, cfg, x, memory_kv):
    """x: (B,T,D); memory_kv: {"k","v"} (B,S,KVH,hd) precomputed."""
    b, t, _ = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k, v = memory_kv["k"], memory_kv["v"]
    kvh, hd = k.shape[2], k.shape[3]
    g = cfg.num_heads // kvh
    qg = q.reshape(b, t, kvh, g, hd)
    mask = jnp.ones((t, k.shape[1]), bool)
    out = _sdpa(qg, k, v, mask).reshape(b, t, cfg.num_heads, hd)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"])


def cross_attn_memory(p, x_enc):
    """Precompute cross-attention k/v from encoder output."""
    k = jnp.einsum("bsd,dnk->bsnk", x_enc, p["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", x_enc, p["wv"])
    return {"k": k, "v": v}
