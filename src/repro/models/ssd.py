"""Mamba-2 block with the SSD (state-space duality) chunked algorithm.

Follows arXiv:2405.21060 (the "quadratic-within-chunk, linear-across-chunk"
formulation): within a chunk the kernel is an attention-like masked-decay
matmul; across chunks a small (H, P, N) state is carried by a sequential
scan. Decode is a single O(1) state update — this is why mamba2 runs the
long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm, rms_norm_init


def ssd_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.headdim
    conv_dim = d_inner + 2 * s.d_state
    return d_inner, nheads, conv_dim


def ssd_init(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nheads, conv_dim = ssd_dims(cfg)
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * d_inner + 2 * s.d_state + nheads
    return {
        "w_in": dense_init(ks[0], d, d_in_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "A_log": jnp.zeros((nheads,), jnp.float32),   # A = -exp(A_log) = -1
        "D": jnp.ones((nheads,), jnp.float32),
        "norm": rms_norm_init(d_inner, dtype),
        "w_out": dense_init(ks[2], d_inner, d, dtype),
    }


def _causal_conv_full(x, w, b):
    """Depthwise causal conv along time. x: (B,T,C); w: (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i: i + x.shape[1]] * w[i]
    return out + b


def _segsum(a):
    """a: (..., L) -> (..., L, L) with out[i,j] = sum_{j<t<=i} a_t (j<=i)."""
    l = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    seg = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, seg, -jnp.inf)


def _split_in(cfg, zxbcdt):
    s = cfg.ssm
    d_inner, nheads, _ = ssd_dims(cfg)
    z, xc, B, C, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + s.d_state,
         2 * d_inner + 2 * s.d_state], axis=-1)
    return z, xc, B, C, dt


def ssd_apply_full(p, cfg, x, return_state: bool = False):
    """x: (B,T,D) -> (B,T,D); chunked SSD over the full sequence.

    With ``return_state`` also returns the decode state after position T-1
    (padding is dt=0 / x=0, so it does not perturb the state).
    """
    s = cfg.ssm
    b, t, _ = x.shape
    d_inner, nheads, conv_dim = ssd_dims(cfg)
    hp = s.headdim

    z, xc, B, C, dt = _split_in(cfg, jnp.einsum("btd,de->bte", x, p["w_in"]))
    conv_in = jnp.concatenate([xc, B, C], -1)
    xbc = jax.nn.silu(_causal_conv_full(conv_in, p["conv_w"], p["conv_b"]))
    xc, B, C = jnp.split(xbc, [d_inner, d_inner + s.d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    A = -jnp.exp(p["A_log"])                                     # (H,)
    xh = xc.reshape(b, t, nheads, hp).astype(jnp.float32)
    Bf = B.astype(jnp.float32)                                   # (B,T,N)
    Cf = C.astype(jnp.float32)

    # pad T to a multiple of the chunk length
    l = s.chunk
    pad = (-t) % l
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bf = jnp.pad(Bf, ((0, 0), (0, pad), (0, 0)))
        Cf = jnp.pad(Cf, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = xh.shape[1] // l

    xch = xh.reshape(b, nc, l, nheads, hp)
    Bch = Bf.reshape(b, nc, l, -1)
    Cch = Cf.reshape(b, nc, l, -1)
    dtc = dt.reshape(b, nc, l, nheads)
    a = dtc * A                                                  # (B,nc,L,H)
    a_cum = jnp.cumsum(a, axis=2)

    # within-chunk (diagonal) term
    Lmat = jnp.exp(_segsum(jnp.moveaxis(a, -1, 2)))              # (B,nc,H,L,L)
    xdt = xch * dtc[..., None]
    y_diag = jnp.einsum("bcln,bcsn,bchls,bcshp->bclhp",
                        Cch, Bch, Lmat, xdt)

    # per-chunk end states and the cross-chunk recurrence
    decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)          # (B,nc,L,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bch,
                        decay_states * dtc, xch)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])                    # (B,nc,H)

    def scan_fn(h, xs):
        st, dec = xs
        h_new = dec[:, :, None, None] * h + st
        return h_new, h                                          # emit PREV state

    h0 = jnp.zeros((b, nheads, hp, Bch.shape[-1]), jnp.float32)
    h_final, h_prev = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                          # (B,nc,H,P,N)

    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cch, h_prev,
                       jnp.exp(a_cum))
    y = (y_diag + y_off).reshape(b, nc * l, nheads, hp)[:, :t]
    y = y + p["D"][None, None, :, None] * xh[:, :t]
    y = y.reshape(b, t, d_inner).astype(x.dtype)

    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bti,id->btd", y, p["w_out"])
    if not return_state:
        return out
    # decode state after position T-1: SSD carry + last d_conv-1 conv inputs
    kc = s.d_conv - 1
    tail = conv_in[:, max(0, t - kc): t]
    if t < kc:
        tail = jnp.pad(tail, ((0, 0), (kc - t, 0), (0, 0)))
    return out, {"h": h_final, "conv": tail.astype(x.dtype)}


def ssd_init_state(cfg, batch, dtype):
    s = cfg.ssm
    d_inner, nheads, conv_dim = ssd_dims(cfg)
    return {
        "h": jnp.zeros((batch, nheads, s.headdim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
    }


def ssd_step(p, cfg, x, state):
    """x: (B,1,D); O(1) recurrent update."""
    s = cfg.ssm
    b = x.shape[0]
    d_inner, nheads, _ = ssd_dims(cfg)
    hp = s.headdim

    z, xc, B, C, dt = _split_in(cfg, jnp.einsum("btd,de->bte", x, p["w_in"]))
    xbc = jnp.concatenate([xc, B, C], -1)[:, 0]                  # (B,conv_dim)
    conv_buf = jnp.concatenate([state["conv"], xbc[:, None]], axis=1)
    out = jnp.einsum("bkc,kc->bc", conv_buf, p["conv_w"]) + p["conv_b"]
    xbc_c = jax.nn.silu(out)
    xc, B, C = jnp.split(xbc_c, [d_inner, d_inner + s.d_state], axis=-1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                         # (B,H)
    xh = xc.reshape(b, nheads, hp).astype(jnp.float32)
    Bf = B.astype(jnp.float32)                                   # (B,N)
    Cf = C.astype(jnp.float32)

    h = state["h"] * dA[:, :, None, None] + \
        jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bf)
    y = jnp.einsum("bhpn,bn->bhp", h, Cf) + p["D"][None, :, None] * xh
    y = y.reshape(b, 1, d_inner).astype(x.dtype)

    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    y = jnp.einsum("btd,de->bte", y, p["w_out"])
    return y, {"h": h, "conv": conv_buf[:, 1:]}
