"""Griffin recurrent block: causal conv + Real-Gated LRU (arXiv:2402.19427).

Training-time recurrence uses ``jax.lax.associative_scan`` (the RG-LRU is a
per-channel linear recurrence h_t = a_t h_{t-1} + b_t), so the 500k-token
sequence parallelises log-depth instead of running a length-T loop. Decode is
a single O(1) update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

_C = 8.0  # RG-LRU gate exponent constant (Griffin §2.4)


def rglru_width(cfg) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def rglru_init(key, cfg, dtype):
    w = rglru_width(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], d, w, dtype),       # recurrent branch in
        "w_gate": dense_init(ks[1], d, w, dtype),    # gelu gate branch in
        "conv_w": (jax.random.normal(ks[2], (cfg.rglru.d_conv, w), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_i": dense_init(ks[3], w, w, dtype),       # input gate
        "w_r": dense_init(ks[4], w, w, dtype),       # recurrence gate
        "lam": jnp.full((w,), 4.0, jnp.float32),     # a = sigmoid(lam) ~ .982
        "w_out": dense_init(ks[5], w, d, dtype),
    }


def _gates(p, xr):
    """xr: (..., W) conv output -> (a (f32), gated_input (f32))."""
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", xr, p["w_i"])
                       .astype(jnp.float32))
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", xr, p["w_r"])
                       .astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(p["lam"])      # log a_t  (<= 0)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * i * xr.astype(jnp.float32)
    return a, b


def _conv_full(x, w, b):
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i: i + x.shape[1]] * w[i]
    return out + b


def rglru_apply_full(p, cfg, x, return_state: bool = False):
    """x: (B,T,D) -> (B,T,D)."""
    xw = jnp.einsum("btd,dw->btw", x, p["w_x"])
    xr = _conv_full(xw, p["conv_w"], p["conv_b"])
    a, b = _gates(p, xr)                             # (B,T,W) f32

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, p["w_gate"]))
    y = (h.astype(x.dtype)) * gate
    out = jnp.einsum("btw,wd->btd", y, p["w_out"])
    if not return_state:
        return out
    kc = cfg.rglru.d_conv - 1
    t = x.shape[1]
    tail = xw[:, max(0, t - kc): t]
    if t < kc:
        tail = jnp.pad(tail, ((0, 0), (kc - t, 0), (0, 0)))
    return out, {"h": h[:, -1], "conv": tail.astype(x.dtype)}


def rglru_init_state(cfg, batch, dtype):
    w = rglru_width(cfg)
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.rglru.d_conv - 1, w), dtype),
    }


def rglru_step(p, cfg, x, state):
    """x: (B,1,D) -> (B,1,D); O(1) update."""
    xw = jnp.einsum("btd,dw->btw", x, p["w_x"])[:, 0]         # (B,W)
    conv_buf = jnp.concatenate([state["conv"], xw[:, None]], axis=1)
    xr = jnp.einsum("bkw,kw->bw", conv_buf, p["conv_w"]) + p["conv_b"]
    a, b = _gates(p, xr)
    h = a * state["h"] + b
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, p["w_gate"]))[:, 0]
    y = h.astype(x.dtype) * gate
    y = jnp.einsum("bw,wd->bd", y, p["w_out"])[:, None]
    return y, {"h": h, "conv": conv_buf[:, 1:]}
