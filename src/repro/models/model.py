"""Public model facade: ``build_model(cfg)`` -> init / loss_fn / prefill /
decode_step / input_specs for any assigned architecture.

Step functions are plain pure functions (pjit-able); the launcher decides
shardings. Decode state = {"pos": i32 scalar, "caches": pytree}.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, ModelConfig
from repro.models import encdec, transformer
from repro.models.common import dtype_of, rms_norm


def _xent(logits, labels, mask=None):
    """Mean next-token cross-entropy in f32. labels: (B,T) i32."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# (B,S,V) f32 logits above this budget use the seq-chunked loss below —
# a 256x4096x262k logits tensor would be ~1 TB and must never materialise
_XENT_CHUNK_BUDGET = 1 << 28
_XENT_CHUNK = 512


def _xent_chunked(x, labels, unembed_fn):
    """Sequence-chunked next-token loss: per-chunk logits are formed,
    reduced to a scalar and rematerialised in the backward pass, so peak
    memory is (B, chunk, V) instead of (B, S, V)."""
    b, t, _ = x.shape
    c = _XENT_CHUNK
    n = t // c

    def chunk_loss(xc, yc):
        logits = unembed_fn(xc)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, yc[..., None], axis=-1)[..., 0]
        return jnp.sum(ll)

    chunk_loss = jax.checkpoint(chunk_loss, prevent_cse=False)

    def body(tot, xs):
        xc, yc = xs
        return tot + chunk_loss(xc, yc), None

    xs = (jnp.moveaxis(x[:, : n * c].reshape(b, n, c, -1), 1, 0),
          jnp.moveaxis(labels[:, : n * c].reshape(b, n, c), 1, 0))
    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
    count = b * n * c
    if t % c:  # remainder chunk
        tot = tot + chunk_loss(x[:, n * c:], labels[:, n * c:])
        count = b * t
    return -tot / count


@dataclass
class Model:
    cfg: ModelConfig
    init: Callable[[Any], Any]
    loss_fn: Callable[..., Any]           # (params, batch) -> (loss, metrics)
    forward: Callable[..., Any]           # (params, batch) -> logits
    prefill: Callable[..., Any]           # (params, batch, cache_len) -> (logits, state)
    decode_step: Callable[..., Any]       # (params, state, batch) -> (logits, state)
    init_decode_state: Callable[..., Any]  # (batch_size, cache_len) -> state


# ---------------------------------------------------------------------------
# Decoder-only family (dense / moe / ssm / hybrid / vlm)

def _build_decoder(cfg: ModelConfig) -> Model:
    dtype = dtype_of(cfg)

    def init(key):
        return transformer.lm_init(key, cfg)

    def forward(params, batch):
        logits, _, extras, n_prefix = transformer.lm_apply(
            params, cfg, batch["tokens"], batch.get("patches"), mode="full")
        return logits

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        x, n_prefix = transformer.embed(params, cfg, tokens,
                                        batch.get("patches"))
        b, t, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
        x, _, extras = transformer.stack_apply(params["stack"], cfg, x,
                                               positions, "full")
        # next-token loss over the text region only
        xt = x[:, n_prefix:, :][:, :-1]
        labels = tokens[:, 1:]
        if xt.shape[0] * xt.shape[1] * cfg.vocab_size > _XENT_CHUNK_BUDGET:
            loss = _xent_chunked(
                xt, labels, lambda h: transformer.unembed(params, cfg, h))
        else:
            loss = _xent(transformer.unembed(params, cfg, xt), labels)
        aux = transformer.collect_moe_aux(cfg, extras)
        coef = cfg.moe.router_aux_coef if cfg.moe else 0.0
        return loss + coef * aux, {"xent": loss, "moe_aux": aux}

    def prefill(params, batch, cache_len: int):
        logits, caches, _, n_prefix = transformer.lm_apply(
            params, cfg, batch["tokens"], batch.get("patches"),
            mode="prefill", cache_len=cache_len)
        t = batch["tokens"].shape[1] + n_prefix
        state = {"pos": jnp.asarray(t, jnp.int32), "caches": caches}
        return logits[:, -1], state

    def decode_step(params, state, batch):
        logits, caches, extras, _ = transformer.lm_apply(
            params, cfg, batch["tokens"], None, mode="decode",
            caches=state["caches"], pos=state["pos"])
        new_state = {"pos": state["pos"] + 1, "caches": caches}
        return logits[:, -1], new_state

    def init_decode_state(batch_size: int, cache_len: int, pos: int = 0):
        caches = transformer.stack_cache_init(cfg, batch_size, cache_len,
                                              dtype)
        return {"pos": jnp.asarray(pos, jnp.int32), "caches": caches}

    return Model(cfg, init, loss_fn, forward, prefill, decode_step,
                 init_decode_state)


# ---------------------------------------------------------------------------
# Encoder-decoder family (audio)

def _build_encdec(cfg: ModelConfig) -> Model:
    dtype = dtype_of(cfg)

    def init(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "tok_emb": (jax.random.normal(
                k1, (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02
            ).astype(dtype),
            "encoder": encdec.encoder_init(k2, cfg, dtype),
            "decoder": encdec.decoder_init(k3, cfg, dtype),
            "final_ln": jnp.ones((cfg.d_model,), dtype),
            "head": (jax.random.normal(
                k4, (cfg.d_model, cfg.vocab_size), jnp.float32)
                * cfg.d_model ** -0.5).astype(dtype),
        }

    def _decode_stack(params, x, positions, memory, mode, caches=None,
                      pos=None, cache_len=0):
        x, new_caches = encdec.decoder_apply(
            params["decoder"], cfg, x, positions, memory, mode, caches, pos,
            cache_len)
        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        logits = jnp.einsum("btd,dv->btv", x,
                            params["head"]).astype(jnp.float32)
        return logits, new_caches

    def _hidden(params, batch):
        enc = encdec.encoder_apply(params["encoder"], cfg, batch["frames"])
        memory = encdec.cross_memory(params["decoder"], cfg, enc)
        x = jnp.take(params["tok_emb"], batch["tokens"], axis=0)
        b, t, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
        x, _ = encdec.decoder_apply(params["decoder"], cfg, x, positions,
                                    memory, "full")
        return rms_norm(x, params["final_ln"], cfg.norm_eps)

    def _head(params, h):
        return jnp.einsum("btd,dv->btv", h, params["head"]).astype(jnp.float32)

    def forward(params, batch):
        return _head(params, _hidden(params, batch))

    def loss_fn(params, batch):
        h = _hidden(params, batch)[:, :-1]
        labels = batch["tokens"][:, 1:]
        if h.shape[0] * h.shape[1] * cfg.vocab_size > _XENT_CHUNK_BUDGET:
            loss = _xent_chunked(h, labels, lambda hh: _head(params, hh))
        else:
            loss = _xent(_head(params, h), labels)
        return loss, {"xent": loss, "moe_aux": jnp.zeros((), jnp.float32)}

    def prefill(params, batch, cache_len: int):
        enc = encdec.encoder_apply(params["encoder"], cfg, batch["frames"])
        memory = encdec.cross_memory(params["decoder"], cfg, enc)
        x = jnp.take(params["tok_emb"], batch["tokens"], axis=0)
        b, t, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
        logits, caches = _decode_stack(params, x, positions, memory,
                                       "prefill", cache_len=cache_len)
        state = {"pos": jnp.asarray(t, jnp.int32), "caches": caches,
                 "memory": memory}
        return logits[:, -1], state

    def decode_step(params, state, batch):
        x = jnp.take(params["tok_emb"], batch["tokens"], axis=0)
        b = x.shape[0]
        positions = jnp.full((b, 1), state["pos"], jnp.int32)
        logits, caches = _decode_stack(params, x, positions, state["memory"],
                                       "decode", state["caches"],
                                       state["pos"])
        new_state = dict(state, pos=state["pos"] + 1, caches=caches)
        return logits[:, -1], new_state

    def init_decode_state(batch_size: int, cache_len: int, pos: int = 0):
        caches = encdec.decoder_cache_init(cfg, batch_size, cache_len, dtype)
        kvh, hd = cfg.num_kv_heads, cfg.hd
        memory = {
            "k": jnp.zeros((cfg.num_layers, batch_size, cfg.frontend_len,
                            kvh, hd), dtype),
            "v": jnp.zeros((cfg.num_layers, batch_size, cfg.frontend_len,
                            kvh, hd), dtype),
        }
        return {"pos": jnp.asarray(pos, jnp.int32), "caches": caches,
                "memory": memory}

    return Model(cfg, init, loss_fn, forward, prefill, decode_step,
                 init_decode_state)


def build_model(cfg: ModelConfig) -> Model:
    if cfg.encdec is not None:
        return _build_encdec(cfg)
    return _build_decoder(cfg)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input stubs for dry-runs (no allocation)

def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """Stand-in inputs for (arch x input-shape): train/prefill batches or a
    decode step batch. Modality frontends are stubbed embeddings (carve-out).
    """
    shp = INPUT_SHAPES[shape_name]
    b = shp.global_batch
    f32, i32 = jnp.float32, jnp.int32
    bf16 = dtype_of(cfg)

    def sds(shape, dt):
        return jax.ShapeDtypeStruct(shape, dt)

    if shp.mode in ("train", "prefill"):
        s = shp.seq_len
        batch: Dict[str, Any] = {}
        if cfg.frontend == "vision":
            batch["tokens"] = sds((b, s - cfg.frontend_len), i32)
            batch["patches"] = sds((b, cfg.frontend_len, cfg.frontend_dim),
                                   bf16)
        elif cfg.frontend == "audio":
            batch["tokens"] = sds((b, s), i32)
            batch["frames"] = sds((b, cfg.frontend_len, cfg.frontend_dim),
                                  bf16)
        else:
            batch["tokens"] = sds((b, s), i32)
        return batch

    # decode: one new token against a seq_len-deep cache
    return {"tokens": sds((b, 1), i32)}
