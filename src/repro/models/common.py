"""Shared building blocks: init helpers, norms, RoPE, dense FFNs.

All modules are pure functions over nested-dict params: ``*_init(key, ...)``
returns the param pytree, ``*_apply(params, ...)`` runs it. No framework
dependency (flax/optax are not available offline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rms_norm_init(dim: int, dtype):
    return jnp.ones((dim,), dtype)


def rms_norm(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (half-rotation / llama convention)

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., T, hd/2)
    cos = jnp.cos(ang)[..., None, :]                  # (..., T, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense gated FFN (SwiGLU / GeGLU)

def ffn_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def ffn_apply(p, x, kind: str = "swiglu"):
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
    return jnp.einsum("...f,fd->...d", act * u, p["w_down"])


def gelu_mlp_init(key, dims, dtype):
    """Plain MLP used by the predictor head: dims = [in, hid, ..., out]."""
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"w{i}": dense_init(keys[i], dims[i], dims[i + 1], dtype)
        for i in range(len(dims) - 1)
    } | {
        f"b{i}": jnp.zeros((dims[i + 1],), dtype)
        for i in range(len(dims) - 1)
    }


def gelu_mlp_apply(p, x, n_layers: int):
    for i in range(n_layers):
        x = jnp.einsum("...d,df->...f", x, p[f"w{i}"]) + p[f"b{i}"]
        if i < n_layers - 1:
            x = jax.nn.gelu(x)
    return x
