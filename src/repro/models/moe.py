"""Sparse MoE layer: softmax-top-k router + capacity-factor one-hot dispatch.

The dispatch einsum is the GSPMD-friendly formulation (Switch/MaxText style):
tokens are grouped, each group gets ``C = ceil(S_g * k * cf / E)`` slots per
expert, and dispatch/combine are einsums against a (G, S*k, E, C) one-hot.
With the expert axis sharded on "model" and groups on "data", XLA emits the
expert-parallel all-to-all. Router math runs in f32.

``moe_apply`` also returns the routed expert ids per token — the activation
trace the paper's predictor is trained on (core/tracing.py consumes it).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.launch import shardctx
from repro.models.common import dense_init, ffn_apply, ffn_init

# tokens per dispatch group (see DESIGN.md §8 / EXPERIMENTS.md §Perf —
# smaller groups cut dispatch-einsum FLOPs linearly at fixed capacity slack)
DEFAULT_GROUP = 4096


def moe_init(key, cfg, dtype):
    m = cfg.moe
    d, e, f = cfg.d_model, m.num_experts, m.d_ff_expert
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "w_router": dense_init(k1, d, e, jnp.float32),
        "w_gate": dense_init(k2, d, e * f, dtype).reshape(e, d, f),
        "w_up": dense_init(k3, d, e * f, dtype).reshape(e, d, f),
        "w_down": dense_init(k4, f, e * d, dtype).reshape(e, f, d),
    }
    if m.num_shared:
        p["shared"] = ffn_init(k5, d, m.num_shared * f, dtype)
    return p


def route(p, cfg, x):
    """Router: softmax over experts then top-k, renormalised (DeepSeek-V2).

    Returns (weights (B,T,k) f32, idx (B,T,k) i32, probs (B,T,E) f32).
    """
    m = cfg.moe
    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32), p["w_router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)
    w = w / (jnp.sum(w, -1, keepdims=True) + 1e-9)
    return w, idx, probs


def aux_load_balance_loss(cfg, probs, idx):
    """Switch-style load-balance loss: E * sum_e density_e * usage_e."""
    e = cfg.moe.num_experts
    density = jnp.mean(probs.reshape(-1, e), axis=0)             # router mass
    usage = jnp.mean(jax.nn.one_hot(idx.reshape(-1), e), axis=0) * \
        (1.0 / cfg.moe.top_k)                                    # token share
    return e * jnp.sum(density * usage)


def capacity(cfg, group_tokens: int) -> int:
    m = cfg.moe
    return max(1, math.ceil(group_tokens * m.top_k * m.capacity_factor
                            / m.num_experts))


def moe_gather_apply(p, cfg, x, w, idx):
    """Batch-1-style decode path: gather ONLY the routed experts' weights
    instead of running every expert over a capacity buffer (§Perf B1 — the
    paper's expert-fetch model at the sharded level). Worth it whenever
    n*top_k < num_experts: weight traffic drops ~E/(n*k)x.

    x: (B,T,D); w: (B,T,k); idx: (B,T,k) -> (B,T,D)."""
    m = cfg.moe
    b, t, d = x.shape
    flat_idx = idx.reshape(-1)                                # (n*k,)
    wg = jnp.take(p["w_gate"], flat_idx, axis=0)              # (n*k, D, F)
    wu = jnp.take(p["w_up"], flat_idx, axis=0)
    wd = jnp.take(p["w_down"], flat_idx, axis=0)
    xf = jnp.repeat(x.reshape(b * t, d), m.top_k, axis=0)     # (n*k, D)
    g = jnp.einsum("nd,ndf->nf", xf, wg)
    u = jnp.einsum("nd,ndf->nf", xf, wu)
    y = jnp.einsum("nf,nfd->nd", jax.nn.silu(g) * u, wd)      # (n*k, D)
    y = (y.reshape(b, t, m.top_k, d)
         * w[..., None].astype(x.dtype)).sum(axis=2)
    if m.num_shared:
        y = y + ffn_apply(p["shared"], x, "swiglu")
    return y


def expert_group_ffn(wg, wu, wd, x):
    """ONE expert's SwiGLU FFN over a shipped token group — the unit of
    work a peer shard computes in the expert-parallel dispatch path
    (launch/sharding.expert_dispatch_ffn; serving engines model the same
    computation through their slot-gather program).

    wg/wu: (D, F); wd: (F, D); x: (N, D) token activations. Returns the
    (N, D) *unweighted* expert outputs — the router's top-k combine
    weights are applied by the caller after the outputs return, so the
    weighted sum happens exactly where the local path does it.
    Accumulates in f32 (matching the reference kernels), returns x.dtype.
    """
    xf = x.astype(jnp.float32)
    g = xf @ wg.astype(jnp.float32)
    u = xf @ wu.astype(jnp.float32)
    y = (jax.nn.silu(g) * u) @ wd.astype(jnp.float32)
    return y.astype(x.dtype)


def moe_apply(p, cfg, x, group_tokens: int = 0, decode: bool = False):
    """x: (B,T,D) -> (out, aux_loss, expert_idx (B,T,k))."""
    m = cfg.moe
    b, t, d = x.shape
    w, idx, probs = route(p, cfg, x)
    aux = aux_load_balance_loss(cfg, probs, idx)

    n = b * t
    # NOTE (§Perf B1, refuted for sharded serving): under expert-parallel
    # sharding the gather path makes GSPMD broadcast the selected experts'
    # weights to every device (+1.5 GB all-reduce per step on llama4
    # long_500k) — one-hot dispatch already computes on the owning shard.
    # The gather path pays off only on an UNSHARDED expert store (the edge
    # engine, serving/engine.py) — so it is opt-in via decode_gather.
    if decode and getattr(m, "decode_gather", False)             and n * m.top_k < m.num_experts:
        return moe_gather_apply(p, cfg, x, w, idx), aux, idx
    sg = min(group_tokens or m.dispatch_group or DEFAULT_GROUP, n)
    if n % sg:
        sg = n  # fall back to one group for awkward sizes (small tests)
    g = n // sg
    c = capacity(cfg, sg)

    xf = x.reshape(g, sg, d)
    idx_g = idx.reshape(g, sg, m.top_k)
    w_g = w.reshape(g, sg, m.top_k).astype(x.dtype)

    # expert one-hot per (token, k-slot), flattened to (G, S*k, E)
    onehot = jax.nn.one_hot(idx_g, m.num_experts, dtype=jnp.int32)
    oh_flat = onehot.reshape(g, sg * m.top_k, m.num_experts)
    # position of each slot within its expert's capacity buffer
    pos = jnp.cumsum(oh_flat, axis=1) - 1                        # (G,S*k,E)
    keep = (pos < c) & (oh_flat > 0)
    dispatch = (keep[..., None]
                & (pos[..., None] == jnp.arange(c)[None, None, None]))
    dispatch = dispatch.astype(x.dtype)                          # (G,S*k,E,C)

    # route tokens to expert buffers: each of the S*k slots maps to token s//k
    x_rep = jnp.repeat(xf, m.top_k, axis=1)                      # (G,S*k,D)
    x_e = jnp.einsum("gtec,gtd->gecd", dispatch, x_rep)          # (G,E,C,D)

    h_gate = jnp.einsum("gecd,edf->gecf", x_e, p["w_gate"])
    h_up = jnp.einsum("gecd,edf->gecf", x_e, p["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    y_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"])           # (G,E,C,D)

    # combine: per slot, gather its expert output, weight it, then sum the
    # k slots belonging to each token. NOTE (§Perf A5, refuted twice): both
    # folding w into the dispatch mask and reduce-scatter-constraining the
    # (g,t,d) output made GSPMD materialise a second (G,S*k,E,C) tensor /
    # reshard-churn — the 3-operand einsum below is what XLA shards best.
    w_rep = w_g.reshape(g, sg * m.top_k)
    y_slot = jnp.einsum("gtec,gecd,gt->gtd", dispatch, y_e, w_rep)
    y = y_slot.reshape(g, sg, m.top_k, d).sum(axis=2).reshape(b, t, d)

    if m.num_shared:
        y = y + ffn_apply(p["shared"], x, "swiglu")
    return y, aux, idx
