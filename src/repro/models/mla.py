"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Train/prefill materialise per-head k/v from the compressed latent; decode
uses the *absorbed* formulation so the KV cache is only
(kv_lora_rank + rope_head_dim) per token — MLA's entire point, and the reason
the 128-head deepseek-v2 decode fits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch import shardctx
from repro.models.attention import NEG_INF, sdpa_any
from repro.models.common import apply_rope, dense_init, rms_norm, rms_norm_init


def mla_init(key, cfg, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.nope_head_dim + m.rope_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "w_dkv": dense_init(ks[0], d, m.kv_lora_rank + m.rope_head_dim, dtype),
        "kv_ln": rms_norm_init(m.kv_lora_rank, dtype),
        "w_uk": dense_init(ks[1], m.kv_lora_rank, h * m.nope_head_dim, dtype)
                .reshape(m.kv_lora_rank, h, m.nope_head_dim),
        "w_uv": dense_init(ks[2], m.kv_lora_rank, h * m.v_head_dim, dtype)
                .reshape(m.kv_lora_rank, h, m.v_head_dim),
        "wo": dense_init(ks[3], h * m.v_head_dim, d, dtype)
              .reshape(h, m.v_head_dim, d),
    }
    if m.q_lora_rank:
        p["w_dq"] = dense_init(ks[4], d, m.q_lora_rank, dtype)
        p["q_ln"] = rms_norm_init(m.q_lora_rank, dtype)
        p["w_uq"] = dense_init(ks[5], m.q_lora_rank, h * qk, dtype) \
            .reshape(m.q_lora_rank, h, qk)
    else:
        p["w_q"] = dense_init(ks[4], d, h * qk, dtype).reshape(d, h, qk)
    return p


def _project_q(p, cfg, x, positions):
    m = cfg.mla
    if m.q_lora_rank:
        cq = rms_norm(jnp.einsum("btd,dr->btr", x, p["w_dq"]), p["q_ln"],
                      cfg.norm_eps)
        q = jnp.einsum("btr,rhk->bthk", cq, p["w_uq"])
    else:
        q = jnp.einsum("btd,dhk->bthk", x, p["w_q"])
    q_nope = q[..., : m.nope_head_dim]
    q_rope = apply_rope(q[..., m.nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_ckv(p, cfg, x, positions):
    m = cfg.mla
    ckv_full = jnp.einsum("btd,dc->btc", x, p["w_dkv"])
    ckv = rms_norm(ckv_full[..., : m.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    k_rope = ckv_full[..., m.kv_lora_rank:][:, :, None, :]   # (B,T,1,r)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return ckv, k_rope


def mla_init_cache(cfg, batch, cache_len, dtype):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, cache_len, m.rope_head_dim), dtype),
    }


def mla_apply(p, cfg, x, positions, mode, cache=None, pos=None, cache_len=0):
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.num_heads
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5

    if mode in ("full", "prefill"):
        q_nope, q_rope = _project_q(p, cfg, x, positions)
        ckv, k_rope = _project_ckv(p, cfg, x, positions)
        k_nope = jnp.einsum("btc,chn->bthn", ckv, p["w_uk"])
        v = jnp.einsum("btc,chn->bthn", ckv, p["w_uv"])
        q = jnp.concatenate([q_nope, q_rope], -1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (b, t, h, m.rope_head_dim))], -1)
        # head-shard inside attention (see shardctx.constrain_qkv)
        q, k, v = (shardctx.constrain_qkv(z) for z in (q, k, v))
        qpos = positions[0] if positions.ndim == 2 else positions
        out = sdpa_any(q, k, v, qpos, qpos, "global", cfg, causal=True)
        y = jnp.einsum("bthv,hvd->btd", out, p["wo"])
        new_cache = None
        if mode == "prefill":
            pad = cache_len - t
            new_cache = {
                "ckv": jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))),
                "krope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
            }
        return y, new_cache

    # ---- decode: absorbed formulation, t == 1
    q_nope, q_rope = _project_q(p, cfg, x, positions)
    ckv_new, krope_new = _project_ckv(p, cfg, x, positions)
    c = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new, (0, pos, 0))
    r = jax.lax.dynamic_update_slice(cache["krope"], krope_new, (0, pos, 0))
    valid = (jnp.arange(c.shape[1]) <= pos)[None, None, :]   # (1,1,S)
    y = _mla_attend(p, cfg, q_nope, q_rope, c, r, valid, x.dtype)
    return y, {"ckv": c, "krope": r}


def _mla_attend(p, cfg, q_nope, q_rope, c, r, valid, out_dtype):
    """Absorbed-attention core shared by contiguous decode and the paged
    paths: q_nope (B,T,h,n), q_rope (B,T,h,rr), c (B,S,rank), r (B,S,rr),
    valid broadcastable to (B,T,S). Returns y (B,T,D)."""
    m = cfg.mla
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    q_abs = jnp.einsum("bthn,chn->bthc", q_nope, p["w_uk"])
    scores = (jnp.einsum("bthc,bsc->bhts", q_abs, c)
              + jnp.einsum("bthr,bsr->bhts", q_rope, r)).astype(jnp.float32)
    scores = scores * scale
    valid = jnp.broadcast_to(valid, (scores.shape[0],) + scores.shape[2:])
    scores = jnp.where(valid[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(out_dtype)
    o_lat = jnp.einsum("bhts,bsc->bthc", probs, c)
    out = jnp.einsum("bthc,chv->bthv", o_lat, p["w_uv"])
    return jnp.einsum("bthv,hvd->btd", out, p["wo"])


# ---------------------------------------------------------------------------
# Paged KV cache (block-table) paths — the compressed latent pages exactly
# like K/V: block b slot s of every MLA layer's pool holds the (c, r) latent
# for the absolute position a request's block table maps there, stored as
# ONE ``lat`` tensor with ckv in the first ``kv_lora_rank`` features and
# k_rope in the rest. That layout is what lets the absorbed decode reuse the
# paged flash-decode kernel as a single-"kv-head" attend: K is the whole
# latent page, V is its ckv prefix — one fetch, no concat on the read path.
# serving/kvpool.py owns the block id space; block 0 is the scratch block.

def mla_paged_init_cache(cfg, num_blocks: int, block_size: int, dtype):
    m = cfg.mla
    return {
        "lat": jnp.zeros(
            (num_blocks, block_size, m.kv_lora_rank + m.rope_head_dim),
            dtype),
    }


def mla_paged_copy_block(cache, src, dst):
    """Copy one latent pool page ``src -> dst`` — the MLA device half of
    copy-on-write (the single ``lat`` tensor is the whole page)."""
    return {"lat": cache["lat"].at[dst].set(cache["lat"][src])}


def _mla_paged_gather(cache, tables, rank: int):
    """tables: (N,W) -> (ckv (N,W*bs,rank), krope (N,W*bs,rr)) in absolute
    position order — the materialising read of the parity-reference path."""
    n, w = tables.shape
    bs = cache["lat"].shape[1]
    lat = jnp.take(cache["lat"], tables.reshape(-1), axis=0).reshape(
        n, w * bs, cache["lat"].shape[-1])
    return lat[..., :rank], lat[..., rank:]


def _mla_paged_scatter(cache, ckv_new, krope_new, bids, slots):
    """Write one latent row per lane: ckv_new (L,rank), krope_new (L,rr)."""
    lat_new = jnp.concatenate([ckv_new, krope_new], axis=-1)
    return {"lat": cache["lat"].at[bids, slots].set(lat_new)}


def _mla_kernel_attend(p, cfg, q_nope, q_rope, cache, tables, pos, kernel):
    """Absorbed MLA attend through the paged flash-decode kernel.

    q_nope (B,T,H,n) / q_rope (B,T,H,rr) flatten to L = B*T lanes; tables
    (L,W), pos (L,). The latent pool is the kernel's shared-page layout
    (``v_pool=None``): V = the ckv prefix of each fetched K tile, one page
    read; the score scale is the materialised head dim's, matching
    ``_mla_attend``.
    """
    from repro.kernels import ops
    m = cfg.mla
    b, t, h, _ = q_nope.shape
    q_abs = jnp.einsum("bthn,chn->bthc", q_nope, p["w_uk"])
    qk = jnp.concatenate([q_abs, q_rope], axis=-1)         # (B,T,H,rank+rr)
    qk = qk.reshape(b * t, 1, h, qk.shape[-1])             # KVH=1, G=H
    pool = cache["lat"][:, :, None, :]                     # (nb,bs,1,rank+rr)
    o_lat = ops.paged_flash_decode(
        qk, pool, None, tables, pos,
        scale=(m.nope_head_dim + m.rope_head_dim) ** -0.5,
        dv=m.kv_lora_rank, backend=kernel)
    o_lat = o_lat.reshape(b, t, h, m.kv_lora_rank)
    out = jnp.einsum("bthc,chv->bthv", o_lat, p["w_uv"])
    return jnp.einsum("bthv,hvd->btd", out, p["wo"])


def mla_paged_decode(p, cfg, x, cache, tables, pos, kernel=None):
    """One decode token per lane: x (N,1,D), tables (N,W), pos (N,).
    ``kernel`` selects the paged flash-decode backend; None keeps the
    gather + ``_mla_attend`` parity reference."""
    bs = cache["lat"].shape[1]
    positions = pos[:, None]
    q_nope, q_rope = _project_q(p, cfg, x, positions)
    ckv_new, krope_new = _project_ckv(p, cfg, x, positions)
    bids = jnp.take_along_axis(tables, (pos // bs)[:, None], axis=1)[:, 0]
    cache = _mla_paged_scatter(cache, ckv_new[:, 0], krope_new[:, 0],
                               bids, pos % bs)
    if kernel is None:
        c, r = _mla_paged_gather(cache, tables, cfg.mla.kv_lora_rank)
        valid = (jnp.arange(c.shape[1])[None, None, :]
                 <= pos[:, None, None])                    # (N,1,S)
        y = _mla_attend(p, cfg, q_nope, q_rope, c, r, valid, x.dtype)
    else:
        y = _mla_kernel_attend(p, cfg, q_nope, q_rope, cache, tables, pos,
                               kernel)
    return y, cache


def mla_paged_prefill(p, cfg, x, cache, table, t0, n_valid, kernel=None):
    """One prompt chunk of a single request: x (1,C,D), the first
    ``n_valid`` tokens are real at positions t0..t0+n_valid-1; pads scatter
    to the scratch block. Per-token math matches ``mla_paged_decode`` — on
    the kernel route each chunk token becomes one kernel lane."""
    c_len = x.shape[1]
    bs = cache["lat"].shape[1]
    idx = jnp.arange(c_len)
    positions = t0 + idx[None, :]                          # (1,C)
    q_nope, q_rope = _project_q(p, cfg, x, positions)
    ckv_new, krope_new = _project_ckv(p, cfg, x, positions)
    real = idx < n_valid
    p_abs = t0 + idx
    lb = jnp.clip(p_abs // bs, 0, table.shape[0] - 1)
    bids = jnp.where(real, jnp.take(table, lb), 0)
    slots = jnp.where(real, p_abs % bs, 0)
    cache = _mla_paged_scatter(cache, ckv_new[0], krope_new[0], bids, slots)
    if kernel is None:
        c, r = _mla_paged_gather(cache, table[None, :], cfg.mla.kv_lora_rank)
        valid = (jnp.arange(c.shape[1])[None, None, :]
                 <= positions[:, :, None])                 # (1,C,S)
        y = _mla_attend(p, cfg, q_nope, q_rope, c, r, valid, x.dtype)
    else:
        lane_tables = jnp.broadcast_to(table[None, :],
                                       (c_len, table.shape[0]))
        y = _mla_kernel_attend(p, cfg, q_nope, q_rope, cache, lane_tables,
                               positions[0], kernel)
    return y, cache
