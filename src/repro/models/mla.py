"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Train/prefill materialise per-head k/v from the compressed latent; decode
uses the *absorbed* formulation so the KV cache is only
(kv_lora_rank + rope_head_dim) per token — MLA's entire point, and the reason
the 128-head deepseek-v2 decode fits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch import shardctx
from repro.models.attention import NEG_INF, sdpa_any
from repro.models.common import apply_rope, dense_init, rms_norm, rms_norm_init


def mla_init(key, cfg, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.nope_head_dim + m.rope_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "w_dkv": dense_init(ks[0], d, m.kv_lora_rank + m.rope_head_dim, dtype),
        "kv_ln": rms_norm_init(m.kv_lora_rank, dtype),
        "w_uk": dense_init(ks[1], m.kv_lora_rank, h * m.nope_head_dim, dtype)
                .reshape(m.kv_lora_rank, h, m.nope_head_dim),
        "w_uv": dense_init(ks[2], m.kv_lora_rank, h * m.v_head_dim, dtype)
                .reshape(m.kv_lora_rank, h, m.v_head_dim),
        "wo": dense_init(ks[3], h * m.v_head_dim, d, dtype)
              .reshape(h, m.v_head_dim, d),
    }
    if m.q_lora_rank:
        p["w_dq"] = dense_init(ks[4], d, m.q_lora_rank, dtype)
        p["q_ln"] = rms_norm_init(m.q_lora_rank, dtype)
        p["w_uq"] = dense_init(ks[5], m.q_lora_rank, h * qk, dtype) \
            .reshape(m.q_lora_rank, h, qk)
    else:
        p["w_q"] = dense_init(ks[4], d, h * qk, dtype).reshape(d, h, qk)
    return p


def _project_q(p, cfg, x, positions):
    m = cfg.mla
    if m.q_lora_rank:
        cq = rms_norm(jnp.einsum("btd,dr->btr", x, p["w_dq"]), p["q_ln"],
                      cfg.norm_eps)
        q = jnp.einsum("btr,rhk->bthk", cq, p["w_uq"])
    else:
        q = jnp.einsum("btd,dhk->bthk", x, p["w_q"])
    q_nope = q[..., : m.nope_head_dim]
    q_rope = apply_rope(q[..., m.nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_ckv(p, cfg, x, positions):
    m = cfg.mla
    ckv_full = jnp.einsum("btd,dc->btc", x, p["w_dkv"])
    ckv = rms_norm(ckv_full[..., : m.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    k_rope = ckv_full[..., m.kv_lora_rank:][:, :, None, :]   # (B,T,1,r)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return ckv, k_rope


def mla_init_cache(cfg, batch, cache_len, dtype):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, cache_len, m.rope_head_dim), dtype),
    }


def mla_apply(p, cfg, x, positions, mode, cache=None, pos=None, cache_len=0):
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.num_heads
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5

    if mode in ("full", "prefill"):
        q_nope, q_rope = _project_q(p, cfg, x, positions)
        ckv, k_rope = _project_ckv(p, cfg, x, positions)
        k_nope = jnp.einsum("btc,chn->bthn", ckv, p["w_uk"])
        v = jnp.einsum("btc,chn->bthn", ckv, p["w_uv"])
        q = jnp.concatenate([q_nope, q_rope], -1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (b, t, h, m.rope_head_dim))], -1)
        # head-shard inside attention (see shardctx.constrain_qkv)
        q, k, v = (shardctx.constrain_qkv(z) for z in (q, k, v))
        qpos = positions[0] if positions.ndim == 2 else positions
        out = sdpa_any(q, k, v, qpos, qpos, "global", cfg, causal=True)
        y = jnp.einsum("bthv,hvd->btd", out, p["wo"])
        new_cache = None
        if mode == "prefill":
            pad = cache_len - t
            new_cache = {
                "ckv": jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))),
                "krope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
            }
        return y, new_cache

    # ---- decode: absorbed formulation, t == 1
    q_nope, q_rope = _project_q(p, cfg, x, positions)
    ckv_new, krope_new = _project_ckv(p, cfg, x, positions)
    c = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new, (0, pos, 0))
    r = jax.lax.dynamic_update_slice(cache["krope"], krope_new, (0, pos, 0))
    q_abs = jnp.einsum("bthn,chn->bthc", q_nope, p["w_uk"])
    scores = (jnp.einsum("bthc,bsc->bhts", q_abs, c)
              + jnp.einsum("bthr,bsr->bhts", q_rope, r)).astype(jnp.float32)
    scores = scores * scale
    valid = jnp.arange(c.shape[1]) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhts,bsc->bthc", probs, c)
    out = jnp.einsum("bthc,chv->bthv", o_lat, p["w_uv"])
    y = jnp.einsum("bthv,hvd->btd", out, p["wo"])
    return y, {"ckv": c, "krope": r}
