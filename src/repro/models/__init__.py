from repro.models.model import Model, build_model, input_specs  # noqa: F401
