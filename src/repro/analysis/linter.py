"""AST rule engine for the repo-contract linter (stdlib only).

The serving stack's correctness rests on conventions the type system
cannot see: refcount acquire/release pairing, trace-time purity of jitted
code, pow-2 shape bucketing at jit call sites, and "every knob/stat is
documented, serialized, and test-pinned". This module is the machinery;
the repo-specific rules live in ``rules.py`` and plug in through
:class:`Rule`.

Diagnostics are ``file:line:rule-id message``. A finding is silenced only
by an *audited suppression* on the offending line (or a standalone
comment on the line above)::

    # lint: disable=rule-id -- why this is safe

The reason after ``--`` is mandatory: a suppression without one (or
naming an unknown rule) is itself a finding (``bad-suppression``) that no
comment can silence. ``tools/check_lint.py`` drives this over ``src/``,
``benchmarks/`` and ``tools/`` in CI and emits the JSON artifact.
"""
from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

#: rule id for malformed suppression comments; never suppressable.
BAD_SUPPRESSION = "bad-suppression"

#: rule id for files the engine cannot parse; never suppressable.
PARSE_ERROR = "parse-error"

_SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*disable=([A-Za-z0-9_\-]+(?:\s*,\s*[A-Za-z0-9_\-]+)*)"
    r"(?:\s*--\s*(?P<reason>\S.*?))?\s*$")


@dataclass
class Diagnostic:
    """One linter finding, renderable as ``file:line:rule-id message``.

      * ``file`` — path relative to the lint root.
      * ``line`` — 1-based line of the offending statement.
      * ``rule`` — the rule id that fired.
      * ``message`` — human-readable description of the violation.
      * ``suppressed`` — True when an audited suppression covers it.
      * ``reason`` — the suppression's mandatory justification (None for
        active findings).
    """
    file: str
    line: int
    rule: str
    message: str
    suppressed: bool = False
    reason: Optional[str] = None

    def format(self) -> str:
        return f"{self.file}:{self.line}:{self.rule} {self.message}"

    def as_dict(self) -> dict:
        d = {"file": self.file, "line": self.line, "rule": self.rule,
             "message": self.message}
        if self.suppressed:
            d["suppressed"] = True
            d["reason"] = self.reason
        return d


@dataclass
class Suppression:
    """A parsed ``# lint: disable=...`` comment."""
    line: int                  # line the comment sits on
    target: int                # line whose diagnostics it covers
    rules: Tuple[str, ...]
    reason: Optional[str]


@dataclass
class ModuleInfo:
    """One parsed source file plus its suppression table."""
    path: str                  # absolute path
    rel: str                   # path relative to the lint root
    source: str
    tree: ast.Module
    suppressions: List[Suppression] = field(default_factory=list)

    @property
    def lines(self) -> List[str]:
        return self.source.splitlines()


@dataclass
class Project:
    """Every linted module plus the repo root, for cross-file rules."""
    root: str
    modules: List[ModuleInfo]

    def read_texts(self, reldir: str) -> Dict[str, str]:
        """Sources of ``*.py`` directly under ``root/reldir`` ({} if the
        directory does not exist) — e.g. the tests/ corpus parity-pin
        greps even though tests are not themselves linted."""
        out: Dict[str, str] = {}
        d = os.path.join(self.root, reldir)
        if not os.path.isdir(d):
            return out
        for name in sorted(os.listdir(d)):
            if name.endswith(".py"):
                try:
                    with open(os.path.join(d, name), encoding="utf-8") as f:
                        out[os.path.join(reldir, name)] = f.read()
                except OSError:
                    continue
        return out


class Rule:
    """Base class for pluggable lint rules.

    Subclasses set ``rule_id``/``description`` and override one (or both)
    of ``check_module`` (called per file) and ``check_project`` (called
    once with every file, for cross-file contracts)."""

    rule_id: str = ""
    description: str = ""

    def check_module(self, mod: ModuleInfo) -> Iterable[Diagnostic]:
        return ()

    def check_project(self, project: Project) -> Iterable[Diagnostic]:
        return ()


def parse_suppressions(source: str) -> List[Suppression]:
    """Scan a file for suppression comments.

    Real COMMENT tokens only (a disable-example inside a docstring is
    text, not a suppression). A comment trailing code covers its own
    line; a standalone comment line covers the next code line, so
    multi-line statements can be suppressed by a comment above them —
    diagnostics anchor to a statement's *first* line."""
    import io
    import tokenize
    out: List[Suppression] = []
    pending: List[Suppression] = []
    try:
        tokens = list(tokenize.generate_tokens(
            io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError):
        return out
    _skip = {tokenize.COMMENT, tokenize.NL, tokenize.NEWLINE,
             tokenize.INDENT, tokenize.DEDENT, tokenize.ENDMARKER,
             getattr(tokenize, "ENCODING", -1)}
    code_lines = sorted({t.start[0] for t in tokens if t.type not in _skip})
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _SUPPRESS_RE.search(tok.string)
        if not m:
            continue
        line = tok.start[0]
        rules = tuple(r.strip() for r in m.group(1).split(","))
        sup = Suppression(line=line, target=line, rules=rules,
                          reason=m.group("reason"))
        if tok.line[: tok.start[1]].strip():
            out.append(sup)               # trailing: covers its own line
        else:
            pending.append(sup)           # standalone: covers next code line
    for sup in pending:
        nxt = [ln for ln in code_lines if ln > sup.line]
        if nxt:
            sup.target = nxt[0]
            out.append(sup)
    return out


@dataclass
class LintReport:
    """Outcome of one lint run: active findings + audited suppressions."""
    root: str
    files: List[str]
    rule_ids: List[str]
    findings: List[Diagnostic]
    suppressed: List[Diagnostic]

    @property
    def ok(self) -> bool:
        return not self.findings

    def by_rule(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for d in self.findings:
            counts[d.rule] = counts.get(d.rule, 0) + 1
        return counts

    def to_json(self) -> str:
        doc = {
            "version": 1,
            "root": self.root,
            "files_scanned": len(self.files),
            "rules": self.rule_ids,
            "findings": [d.as_dict() for d in self.findings],
            "suppressed": [d.as_dict() for d in self.suppressed],
            "summary": {
                "findings": len(self.findings),
                "suppressed": len(self.suppressed),
                "by_rule": self.by_rule(),
            },
        }
        return json.dumps(doc, indent=2, sort_keys=False) + "\n"


def _collect_files(root: str, paths: Sequence[str]) -> List[str]:
    files: List[str] = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(full) and full.endswith(".py"):
            files.append(full)
            continue
        for dirpath, dirnames, filenames in os.walk(full):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for name in sorted(filenames):
                if name.endswith(".py"):
                    files.append(os.path.join(dirpath, name))
    return sorted(set(files))


def load_module(root: str, path: str) -> Tuple[Optional[ModuleInfo],
                                               Optional[Diagnostic]]:
    rel = os.path.relpath(path, root)
    try:
        with open(path, encoding="utf-8") as f:
            source = f.read()
        tree = ast.parse(source, filename=rel)
    except (OSError, SyntaxError, ValueError) as e:
        line = getattr(e, "lineno", None) or 1
        return None, Diagnostic(rel, line, PARSE_ERROR,
                                f"cannot parse: {e}")
    return ModuleInfo(path=path, rel=rel, source=source, tree=tree,
                      suppressions=parse_suppressions(source)), None


def _apply_suppressions(diags: List[Diagnostic],
                        mods: Dict[str, ModuleInfo],
                        known_rules: set) -> Tuple[List[Diagnostic],
                                                   List[Diagnostic],
                                                   List[Diagnostic]]:
    """Split diagnostics into (active, suppressed) and emit
    ``bad-suppression`` findings for malformed comments."""
    bad: List[Diagnostic] = []
    sup_index: Dict[Tuple[str, int, str], Suppression] = {}
    for rel, mod in mods.items():
        for sup in mod.suppressions:
            unknown = [r for r in sup.rules if r not in known_rules]
            if sup.reason is None:
                bad.append(Diagnostic(
                    rel, sup.line, BAD_SUPPRESSION,
                    "suppression without a reason — write "
                    "'# lint: disable=<rule> -- <why this is safe>'"))
                continue
            if unknown:
                bad.append(Diagnostic(
                    rel, sup.line, BAD_SUPPRESSION,
                    f"suppression names unknown rule(s): "
                    f"{', '.join(unknown)}"))
                continue
            for r in sup.rules:
                sup_index[(rel, sup.target, r)] = sup
    active: List[Diagnostic] = []
    suppressed: List[Diagnostic] = []
    for d in diags:
        sup = sup_index.get((d.file, d.line, d.rule))
        if sup is not None and d.rule not in (BAD_SUPPRESSION, PARSE_ERROR):
            d.suppressed, d.reason = True, sup.reason
            suppressed.append(d)
        else:
            active.append(d)
    return active, suppressed, bad


def run_lint(root: str, paths: Sequence[str],
             rules: Sequence[Rule]) -> LintReport:
    """Lint every ``*.py`` under ``paths`` (relative to ``root``) with
    ``rules``; returns a :class:`LintReport` with suppressions applied."""
    root = os.path.abspath(root)
    files = _collect_files(root, paths)
    mods: Dict[str, ModuleInfo] = {}
    diags: List[Diagnostic] = []
    for path in files:
        mod, err = load_module(root, path)
        if err is not None:
            diags.append(err)
            continue
        mods[mod.rel] = mod
    project = Project(root=root, modules=list(mods.values()))
    for rule in rules:
        for mod in project.modules:
            diags.extend(rule.check_module(mod))
        diags.extend(rule.check_project(project))
    known = {r.rule_id for r in rules}
    active, suppressed, bad = _apply_suppressions(diags, mods, known)
    active.extend(bad)
    key = (lambda d: (d.file, d.line, d.rule))
    return LintReport(
        root=root,
        files=[os.path.relpath(p, root) for p in files],
        rule_ids=sorted(known),
        findings=sorted(active, key=key),
        suppressed=sorted(suppressed, key=key),
    )
