"""Leak-sanitizer mode: re-check the refcount ledgers at every retire.

``KVBlockPool.check_leaks`` proves two exact invariants
(``allocs - releases == blocks live`` and ``allocs + retains - ref_drops
== sum(refcounts)``) and the scheduler already runs it once per
``_run_paged`` drain. Under the sanitizer the check runs at **every
request retire** instead — the moment a table release could first go
asymmetric — plus a full residency-ledger sweep of the tiered expert
store when one is attached. ``benchmarks/engine_bench.py --sanitize``
installs this and reports the check count in its artifacts; a failure
surfaces as the assertion at the exact retire that broke the ledger,
not as an unaccounted block three PRs later.
"""
from __future__ import annotations

from typing import Optional


class LeakSanitizer:
    """Wraps a :class:`~repro.serving.scheduler.BatchedOffloadEngine` so
    every ``_retire`` re-proves the pool + residency-ledger invariants.

    Usage::

        san = LeakSanitizer(engine).install()
        engine.run_workload(...)
        san.uninstall()
        artifact["leak_checks"] = san.checks
    """

    def __init__(self, engine):
        self.engine = engine
        self.checks = 0          # ledger sweeps that passed
        self._orig_retire = None

    def install(self) -> "LeakSanitizer":
        if self._orig_retire is not None:
            return self
        orig = self.engine._retire

        def checked_retire(lanes, req, results):
            orig(lanes, req, results)
            self.check_now()

        self._orig_retire = orig
        self.engine._retire = checked_retire
        return self

    def uninstall(self) -> None:
        if self._orig_retire is not None:
            self.engine._retire = self._orig_retire
            self._orig_retire = None

    def __enter__(self) -> "LeakSanitizer":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()

    # ------------------------------------------------------------------
    def check_now(self) -> None:
        """One sweep: pool refcount arithmetic (mid-run form, no expected
        in-use pin) + the expert store's residency ledger if present."""
        pool = getattr(self.engine, "pool", None)
        if pool is not None:
            pool.check_leaks()
        store = getattr(getattr(self.engine, "core", None), "store", None)
        ledger = getattr(store, "ledger", None)
        if ledger is not None:
            ledger.check()
        self.checks += 1


def sanitize_engine(engine) -> Optional[LeakSanitizer]:
    """Install a :class:`LeakSanitizer` when the engine has a ``_retire``
    hook (batched scheduler); None for engines without one (batch-1
    ``OffloadEngine`` has no retire path to instrument)."""
    if hasattr(engine, "_retire"):
        return LeakSanitizer(engine).install()
    return None
