"""The repo-specific lint rules (see ``linter.py`` for the engine).

Six contracts, each born from a bug class this stack can actually have:

  * ``refcount-pairing`` — a module that acquires references
    (``retain``/``pin``/``fill``/``try_reserve``) must contain the paired
    drop verb somewhere; an acquire with no reachable release path is how
    the PoolStats/ResidencyLedger arithmetic goes out of balance. Also
    flags a ``try_reserve`` whose boolean result is discarded.
  * ``tracer-purity`` — inside a jitted function: Python ``if``/``while``
    on traced values, ``int()``/``float()``/``bool()``/``.item()`` on
    tracers, and closures over mutable engine state (``self.*`` reads),
    all of which either crash at trace time or silently bake state into
    the compiled program.
  * ``bucket-discipline`` — jit call sites passing raw Python ints for
    parameters that are neither declared static nor routed through the
    pow-2 bucket helpers; un-bucketed dynamic sizes are the classic
    mid-run recompile (the retrace guard is the runtime twin of this).
  * ``stats-registration`` — every field of the stats dataclasses must be
    named in its class docstring *and* reach an artifact (a blanket
    ``as_dict`` on the class, or by name in ``benchmarks/engine_bench.py``
    / a ``dispatch_summary``), so counters cannot silently stop being
    reported.
  * ``parity-pin`` — every ``ServeConfig``/``TierConfig`` knob must be
    referenced by at least one module under ``tests/``: an un-pinned knob
    is a code path CI never exercises.
  * ``metric-registration`` — every literal metric name passed to a
    telemetry ``.counter()``/``.gauge()``/``.histogram()`` call must be a
    key of the central ``METRICS`` catalogue
    (``src/repro/serving/telemetry.py``), so a typo'd metric name is a
    lint finding instead of a silently-empty time series.

All rules are pure-AST/stdlib: the lint CI job needs no jax install.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.linter import Diagnostic, ModuleInfo, Project, Rule

# ---------------------------------------------------------------------------
# shared: the per-module jit index
# ---------------------------------------------------------------------------

#: args in these positions of ``partial(jax.jit, f, ...)`` / decorators
_JIT_NAMES = {"jit"}


def _is_jit_ref(node: ast.AST) -> bool:
    """``jax.jit`` / ``jit`` (as imported name) reference."""
    if isinstance(node, ast.Attribute):
        return node.attr in _JIT_NAMES
    if isinstance(node, ast.Name):
        return node.id in _JIT_NAMES
    return False


def _const_strs(node: ast.AST) -> List[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.append(elt.value)
        return out
    return []


def _const_ints(node: ast.AST) -> List[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [elt.value for elt in node.elts
                if isinstance(elt, ast.Constant)
                and isinstance(elt.value, int)]
    return []


def _param_names(fn) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args]
    if a.vararg:
        names.append(a.vararg.arg)
    names += [p.arg for p in a.kwonlyargs]
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _static_from_call(call: ast.Call, params: List[str]) -> Set[str]:
    """static_argnames / static_argnums keywords of a jit/partial call."""
    static: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            static.update(_const_strs(kw.value))
        elif kw.arg == "static_argnums":
            for i in _const_ints(kw.value):
                if 0 <= i < len(params):
                    static.add(params[i])
    return static


@dataclass
class JitFunction:
    """One function known (syntactically) to be wrapped by ``jax.jit``."""
    node: ast.AST                       # FunctionDef or Lambda
    name: str                           # def name / assigned name
    params: List[str]
    static: Set[str]


@dataclass
class JitIndex:
    """Per-module table of jitted functions and their call aliases."""
    functions: List[JitFunction] = field(default_factory=list)
    #: callable-name -> JitFunction, covering the def name, plain-name
    #: aliases (``f = jax.jit(g)``) and attribute aliases
    #: (``self._attn = attn_batched`` -> key ``_attn``)
    by_callee: Dict[str, JitFunction] = field(default_factory=dict)


def build_jit_index(mod: ModuleInfo) -> JitIndex:
    idx = JitIndex()
    defs: Dict[str, ast.AST] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs[node.name] = node

    def register(jf: JitFunction):
        idx.functions.append(jf)
        idx.by_callee[jf.name] = jf

    # decorated defs: @jax.jit / @partial(jax.jit, static_argnames=...)
    for node in ast.walk(mod.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        params = _param_names(node)
        for dec in node.decorator_list:
            if _is_jit_ref(dec):
                register(JitFunction(node, node.name, params, set()))
                break
            if isinstance(dec, ast.Call):
                # @partial(jax.jit, ...) or @jax.jit(...)
                wraps_jit = (_is_jit_ref(dec.func)
                             or any(_is_jit_ref(a) for a in dec.args))
                if wraps_jit:
                    register(JitFunction(node, node.name, params,
                                         _static_from_call(dec, params)))
                    break

    # assignments: name = jax.jit(fn_or_lambda, ...) and attr aliases
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        target = node.targets[0]
        value = node.value
        tname = None
        if isinstance(target, ast.Name):
            tname = target.id
        elif isinstance(target, ast.Attribute):
            tname = target.attr
        if tname is None:
            continue
        if isinstance(value, ast.Call) and _is_jit_ref(value.func) \
                and value.args:
            fn = value.args[0]
            if isinstance(fn, ast.Lambda):
                params = _param_names(fn)
                register(JitFunction(fn, tname, params,
                                     _static_from_call(value, params)))
            elif isinstance(fn, ast.Name) and fn.id in defs:
                wrapped = defs[fn.id]
                params = _param_names(wrapped)
                register(JitFunction(wrapped, tname, params,
                                     _static_from_call(value, params)))
        elif isinstance(value, ast.Name) and value.id in idx.by_callee:
            # self._attn = attn_batched — alias to an already-jitted def
            jf = idx.by_callee[value.id]
            idx.by_callee[tname] = jf
    return idx


# ---------------------------------------------------------------------------
# rule 1: refcount-pairing
# ---------------------------------------------------------------------------

class RefcountPairingRule(Rule):
    rule_id = "refcount-pairing"
    description = ("reference acquires (retain/pin/fill/try_reserve) need "
                   "a reachable paired drop verb in the same module")

    #: acquire method -> acceptable drop verbs
    PAIRS: Dict[str, Tuple[str, ...]] = {
        "retain": ("free", "release"),
        "pin": ("unpin",),
        "fill": ("release", "drop"),
        "try_reserve": ("unreserve", "release", "return_reservation"),
    }

    @staticmethod
    def _method_calls(node: ast.AST) -> List[ast.Call]:
        return [n for n in ast.walk(node)
                if isinstance(n, ast.Call)
                and isinstance(n.func, ast.Attribute)]

    def check_module(self, mod: ModuleInfo) -> Iterable[Diagnostic]:
        module_verbs = {c.func.attr for c in self._method_calls(mod.tree)}
        # method *definitions* count as drop paths too: a class that
        # defines release()/unpin() is the owner of the drop side even if
        # nothing in this module calls it (callers live elsewhere)
        module_verbs |= {n.name for n in ast.walk(mod.tree)
                         if isinstance(n, ast.FunctionDef)}
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for call in self._method_calls(fn):
                verb = call.func.attr
                drops = self.PAIRS.get(verb)
                if drops is None:
                    continue
                if not any(d in module_verbs for d in drops):
                    yield Diagnostic(
                        mod.rel, call.lineno, self.rule_id,
                        f"'{verb}' acquired in {fn.name}() but no paired "
                        f"{'/'.join(drops)} anywhere in this module — "
                        "refcount ledger cannot balance")
        # a discarded try_reserve is an admission-control leak: the
        # reservation is taken whether or not the caller looked
        for stmt in ast.walk(mod.tree):
            if (isinstance(stmt, ast.Expr)
                    and isinstance(stmt.value, ast.Call)
                    and isinstance(stmt.value.func, ast.Attribute)
                    and stmt.value.func.attr == "try_reserve"):
                yield Diagnostic(
                    mod.rel, stmt.lineno, self.rule_id,
                    "try_reserve() result discarded — on success the "
                    "reservation leaks with no holder to unreserve it")


# ---------------------------------------------------------------------------
# rule 2: tracer-purity
# ---------------------------------------------------------------------------

_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "weak_type"}


class TracerPurityRule(Rule):
    rule_id = "tracer-purity"
    description = ("no Python control flow / int()/float()/.item() on "
                   "traced values or self.* closures inside jitted code")

    def check_module(self, mod: ModuleInfo) -> Iterable[Diagnostic]:
        for jf in build_jit_index(mod).functions:
            yield from self._check_fn(mod, jf)

    # -- helpers ----------------------------------------------------------
    def _traced_use(self, node: ast.AST, traced: Set[str]) -> Optional[str]:
        """Name of a traced value used *as a value* in ``node`` (None if
        every traced reference is static metadata like ``x.shape``)."""
        if isinstance(node, ast.Attribute) and node.attr in _SHAPE_ATTRS:
            return None                       # x.shape / x.dtype: static
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id in ("len", "isinstance",
                                                    "type"):
                return None                   # len(x) is static under trace
        if isinstance(node, ast.Compare):
            # `x is None` / `x is not None` tests pytree structure
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops) \
                    and all(isinstance(c, ast.Constant) and c.value is None
                            for c in node.comparators):
                return None
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) \
                and node.id in traced:
            return node.id
        for child in ast.iter_child_nodes(node):
            hit = self._traced_use(child, traced)
            if hit:
                return hit
        return None

    def _check_fn(self, mod: ModuleInfo,
                  jf: JitFunction) -> Iterable[Diagnostic]:
        traced = set(jf.params) - jf.static
        check_self = "self" not in jf.params
        body = jf.node.body if isinstance(jf.node.body, list) \
            else [jf.node.body]
        yield from self._walk(mod, jf, body, traced, check_self)

    def _walk(self, mod, jf, stmts, traced: Set[str],
              check_self: bool) -> Iterable[Diagnostic]:
        for stmt in stmts:
            for node in ast.walk(stmt):
                if isinstance(node, (ast.FunctionDef, ast.Lambda)):
                    # nested fns (vmap rows, scan bodies) see traced args
                    traced = traced | set(_param_names(node))
                if isinstance(node, (ast.If, ast.While)):
                    name = self._traced_use(node.test, traced)
                    if name:
                        kw = "while" if isinstance(node, ast.While) else "if"
                        yield Diagnostic(
                            mod.rel, node.lineno, self.rule_id,
                            f"Python `{kw}` on traced value '{name}' "
                            f"inside jitted {jf.name}() — trace-time "
                            "branch; use lax.cond/where or declare it "
                            "static")
                elif isinstance(node, ast.Call):
                    f = node.func
                    if isinstance(f, ast.Name) \
                            and f.id in ("int", "float", "bool"):
                        for arg in node.args:
                            name = self._traced_use(arg, traced)
                            if name:
                                yield Diagnostic(
                                    mod.rel, node.lineno, self.rule_id,
                                    f"{f.id}() forces traced value "
                                    f"'{name}' to a Python scalar inside "
                                    f"jitted {jf.name}()")
                                break
                    elif isinstance(f, ast.Attribute) and f.attr == "item":
                        yield Diagnostic(
                            mod.rel, node.lineno, self.rule_id,
                            f".item() inside jitted {jf.name}() — host "
                            "sync / trace-time concretization")
                elif (check_self and isinstance(node, ast.Attribute)
                        and isinstance(node.value, ast.Name)
                        and node.value.id == "self"
                        and isinstance(node.ctx, ast.Load)):
                    yield Diagnostic(
                        mod.rel, node.lineno, self.rule_id,
                        f"jitted {jf.name}() closes over engine state "
                        f"'self.{node.attr}' — bind it to a local at "
                        "build time so the compiled program cannot drift "
                        "from the object")


# ---------------------------------------------------------------------------
# rule 3: bucket-discipline
# ---------------------------------------------------------------------------

_BUCKET_HELPERS = {"bucket_size", "blocks_for"}
_ARRAY_WRAPPERS = {"asarray", "array", "full", "zeros", "ones", "arange"}


class BucketDisciplineRule(Rule):
    rule_id = "bucket-discipline"
    description = ("jit call sites must not pass raw Python ints for "
                   "non-static params unless routed through the pow-2 "
                   "bucket helpers")

    @staticmethod
    def _contains_call_to(node: ast.AST, names: Set[str]) -> bool:
        for n in ast.walk(node):
            if isinstance(n, ast.Call):
                f = n.func
                fname = f.id if isinstance(f, ast.Name) else (
                    f.attr if isinstance(f, ast.Attribute) else None)
                if fname in names:
                    return True
        return False

    def _int_like_vars(self, fn: ast.AST) -> Set[str]:
        """Names visibly bound to raw Python ints in ``fn``: int literals,
        ``len(...)``, arithmetic over those, or params annotated ``int``.
        A name whose binding routes through a bucket helper is *not*
        int-like (it is already disciplined)."""
        likely: Set[str] = set()
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for p in fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs:
                ann = p.annotation
                if isinstance(ann, ast.Name) and ann.id == "int":
                    likely.add(p.arg)
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                name = node.targets[0].id
                if self._contains_call_to(node.value, _BUCKET_HELPERS):
                    likely.discard(name)
                elif self._raw_int_expr(node.value, likely):
                    likely.add(name)
        return likely

    def _raw_int_expr(self, node: ast.AST, int_vars: Set[str]) -> bool:
        if isinstance(node, ast.Constant):
            return isinstance(node.value, int) \
                and not isinstance(node.value, bool)
        if isinstance(node, ast.Name):
            return node.id in int_vars
        if isinstance(node, ast.Call):
            f = node.func
            fname = f.id if isinstance(f, ast.Name) else (
                f.attr if isinstance(f, ast.Attribute) else None)
            if fname == "len":
                return True
            if fname in ("int", "min", "max"):
                return any(self._raw_int_expr(a, int_vars)
                           for a in node.args)
            return False
        if isinstance(node, ast.BinOp):
            return self._raw_int_expr(node.left, int_vars) \
                and self._raw_int_expr(node.right, int_vars)
        if isinstance(node, ast.UnaryOp):
            return self._raw_int_expr(node.operand, int_vars)
        return False

    def check_module(self, mod: ModuleInfo) -> Iterable[Diagnostic]:
        idx = build_jit_index(mod)
        if not idx.by_callee:
            return
        jitted_nodes = {id(jf.node) for jf in idx.functions}
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if id(fn) in jitted_nodes:
                continue                  # call sites, not jitted bodies
            int_vars = self._int_like_vars(fn)
            for call in ast.walk(fn):
                if not isinstance(call, ast.Call):
                    continue
                f = call.func
                cname = f.id if isinstance(f, ast.Name) else (
                    f.attr if isinstance(f, ast.Attribute) else None)
                jf = idx.by_callee.get(cname or "")
                if jf is None:
                    continue
                yield from self._check_call(mod, fn, call, jf, int_vars)

    def _check_call(self, mod, fn, call: ast.Call, jf: JitFunction,
                    int_vars: Set[str]) -> Iterable[Diagnostic]:
        bound: List[Tuple[str, ast.AST]] = []
        for i, arg in enumerate(call.args):
            if isinstance(arg, ast.Starred):
                break
            if i < len(jf.params):
                bound.append((jf.params[i], arg))
        for kw in call.keywords:
            if kw.arg is not None:
                bound.append((kw.arg, kw.value))
        for pname, expr in bound:
            if pname in jf.static:
                continue
            if self._contains_call_to(expr, _BUCKET_HELPERS
                                      | _ARRAY_WRAPPERS):
                continue
            if self._raw_int_expr(expr, int_vars):
                yield Diagnostic(
                    mod.rel, call.lineno, self.rule_id,
                    f"call to jitted {jf.name}() passes raw Python int "
                    f"for param '{pname}' (not static, not bucketed) — "
                    "declare it static, pad through bucket_size()/"
                    "blocks_for(), or wrap in jnp.asarray")


# ---------------------------------------------------------------------------
# rule 4: stats-registration
# ---------------------------------------------------------------------------

_STATS_CLASSES = ("EngineStats", "PoolStats", "StoreStats", "CacheStats",
                  "LatencyStats")
_SERIALIZER_FNS = ("dispatch_summary", "as_dict")
_SERIALIZER_FILES = ("benchmarks/engine_bench.py",)


class StatsRegistrationRule(Rule):
    rule_id = "stats-registration"
    description = ("stats dataclass fields must be docstring-named and "
                   "serialized (blanket as_dict or by name in engine_bench"
                   "/dispatch_summary)")

    @staticmethod
    def _class_fields(cls: ast.ClassDef) -> List[Tuple[str, int]]:
        out = []
        for stmt in cls.body:
            if isinstance(stmt, ast.AnnAssign) \
                    and isinstance(stmt.target, ast.Name) \
                    and not stmt.target.id.startswith("_"):
                out.append((stmt.target.id, stmt.lineno))
        return out

    @staticmethod
    def _has_blanket_as_dict(cls: ast.ClassDef) -> bool:
        for stmt in cls.body:
            if isinstance(stmt, ast.FunctionDef) and stmt.name == "as_dict":
                for n in ast.walk(stmt):
                    if isinstance(n, ast.Call):
                        f = n.func
                        fname = f.id if isinstance(f, ast.Name) else (
                            f.attr if isinstance(f, ast.Attribute) else None)
                        if fname == "asdict":
                            return True
        return False

    def check_project(self, project: Project) -> Iterable[Diagnostic]:
        corpus = []
        by_rel = {m.rel.replace("\\", "/"): m for m in project.modules}
        for rel in _SERIALIZER_FILES:
            mod = by_rel.get(rel)
            if mod is not None:
                corpus.append(mod.source)
        for mod in project.modules:
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.FunctionDef) \
                        and node.name in _SERIALIZER_FNS:
                    corpus.append(ast.get_source_segment(mod.source, node)
                                  or "")
        corpus_text = "\n".join(corpus)
        for mod in project.modules:
            for node in ast.walk(mod.tree):
                if not (isinstance(node, ast.ClassDef)
                        and node.name in _STATS_CLASSES):
                    continue
                doc = ast.get_docstring(node) or ""
                blanket = self._has_blanket_as_dict(node)
                for name, line in self._class_fields(node):
                    if not re.search(rf"``{re.escape(name)}``", doc):
                        yield Diagnostic(
                            mod.rel, line, self.rule_id,
                            f"{node.name}.{name} is not named in the "
                            "class docstring")
                    if not blanket and not re.search(
                            rf"\b{re.escape(name)}\b", corpus_text):
                        yield Diagnostic(
                            mod.rel, line, self.rule_id,
                            f"{node.name}.{name} is never serialized — "
                            "add it to an as_dict/dispatch_summary or an "
                            "engine_bench artifact")


# ---------------------------------------------------------------------------
# rule 5: parity-pin
# ---------------------------------------------------------------------------

_CONFIG_CLASSES = ("ServeConfig", "TierConfig")


class ParityPinRule(Rule):
    rule_id = "parity-pin"
    description = ("every ServeConfig/TierConfig knob must be referenced "
                   "by at least one module under tests/")

    def check_project(self, project: Project) -> Iterable[Diagnostic]:
        tests = project.read_texts("tests")
        if not tests:
            return
        corpus = "\n".join(tests.values())
        for mod in project.modules:
            for node in ast.walk(mod.tree):
                if not (isinstance(node, ast.ClassDef)
                        and node.name in _CONFIG_CLASSES):
                    continue
                for name, line in \
                        StatsRegistrationRule._class_fields(node):
                    if not re.search(rf"\b{re.escape(name)}\b", corpus):
                        yield Diagnostic(
                            mod.rel, line, self.rule_id,
                            f"{node.name}.{name} is referenced by no test "
                            "module — an un-pinned knob is a code path CI "
                            "never exercises")


# ---------------------------------------------------------------------------
# rule 6: metric-registration
# ---------------------------------------------------------------------------

#: telemetry emit methods whose first positional arg is a metric name
_METRIC_EMITTERS = ("counter", "gauge", "histogram")


class MetricRegistrationRule(Rule):
    rule_id = "metric-registration"
    description = ("literal metric names passed to telemetry counter/"
                   "gauge/histogram calls must be keys of the METRICS "
                   "catalogue")

    @staticmethod
    def _catalogue(project: Project) -> Optional[Set[str]]:
        """Literal string keys of a module-level ``METRICS = {...}``."""
        for mod in project.modules:
            for node in mod.tree.body:
                if not (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and node.targets[0].id == "METRICS"
                        and isinstance(node.value, ast.Dict)):
                    continue
                keys = set()
                for k in node.value.keys:
                    if isinstance(k, ast.Constant) \
                            and isinstance(k.value, str):
                        keys.add(k.value)
                return keys
        return None

    def check_project(self, project: Project) -> Iterable[Diagnostic]:
        known = self._catalogue(project)
        if known is None:  # no catalogue module in this project: no rule
            return
        for mod in project.modules:
            for node in ast.walk(mod.tree):
                if not (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr in _METRIC_EMITTERS
                        and node.args):
                    continue
                first = node.args[0]
                # only literal names are checkable (np.histogram(arr, ...)
                # and dynamic names pass through untouched)
                if not (isinstance(first, ast.Constant)
                        and isinstance(first.value, str)):
                    continue
                if first.value not in known:
                    yield Diagnostic(
                        mod.rel, node.lineno, self.rule_id,
                        f"metric name '{first.value}' is not registered "
                        "in the METRICS catalogue "
                        "(repro/serving/telemetry.py) — register it or "
                        "fix the typo")


def default_rules() -> List[Rule]:
    """The shipped rule set, in reporting order."""
    return [
        RefcountPairingRule(),
        TracerPurityRule(),
        BucketDisciplineRule(),
        StatsRegistrationRule(),
        ParityPinRule(),
        MetricRegistrationRule(),
    ]
