"""Compile-event retrace guard: pin "a warmed engine compiles nothing".

Every jitted program in the serving stack is shape-bucketed (decode
lanes, prefill chunks, block tables all pad to pow-2 buckets) precisely
so that a warmed engine never pays an XLA compile mid-run — PR 6's SLO
numbers assume it. This module turns that convention into an assertable
invariant: :class:`RetraceGuard` hooks the ``jax.log_compiles`` event
stream (the WARNING records jax emits per actual XLA compilation, cache
hits excluded) and counts compilations per jitted program, so a test or
bench can warm an engine, take a snapshot, run traffic, and assert zero
new programs compiled::

    with RetraceGuard() as guard:
        warm(engine)                     # compiles the bucket family
        with guard.frozen("warmed engine"):
            engine.run_workload(...)     # any compile -> RetraceError

The hook is logging-based (``jax._src.interpreters.pxla`` "Compiling
<name> ..." records, with the ``jax._src.dispatch`` "Finished XLA
compilation" records as a fallback source), so it needs no private API
beyond the documented ``jax_log_compiles`` flag. ``self_check`` guards
the guard: if warmup observed zero compile events the hook is broken
(jax renamed its loggers) and freezing would be vacuous — fail loudly
instead.
"""
from __future__ import annotations

import logging
import re
import threading
from collections import Counter, defaultdict
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional

_COMPILE_LOGGERS = ("jax._src.interpreters.pxla", "jax._src.dispatch")

#: one record per actual XLA compilation (primary source)
_PXLA_RE = re.compile(r"^Compiling (\S+) with global shapes and types "
                      r"(\[.*?\])")
#: fallback source if the pxla logger ever goes quiet across jax versions
_DISPATCH_RE = re.compile(r"^Finished XLA compilation of "
                          r"(?:jit\()?([^\s()]+)\)? in")


class RetraceError(AssertionError):
    """A frozen (warmed) region compiled new XLA programs."""


class _CompileLogHandler(logging.Handler):
    def __init__(self, guard: "RetraceGuard"):
        super().__init__(level=logging.DEBUG)
        self._guard = guard

    def emit(self, record: logging.LogRecord) -> None:
        try:
            self._guard._observe(record.name, record.getMessage())
        except Exception:       # a sanitizer must never break the run
            pass


class RetraceGuard:
    """Counts XLA compilations per jitted program while active.

    Use as a context manager: entering enables ``jax_log_compiles`` and
    attaches a log handler; exiting restores the previous flag value.
    ``counts()`` maps program name -> compilations (one per shape bucket),
    ``frozen()`` wraps a region that must compile nothing new."""

    def __init__(self):
        self._lock = threading.Lock()
        self._pxla: Counter = Counter()
        self._dispatch: Counter = Counter()
        self._signatures: Dict[str, List[str]] = defaultdict(list)
        self._handler: Optional[_CompileLogHandler] = None
        self._prev_flag: Optional[bool] = None
        self._prev_levels: Dict[str, int] = {}

    # -- event intake ------------------------------------------------------
    def _observe(self, logger_name: str, message: str) -> None:
        m = _PXLA_RE.match(message)
        if m:
            with self._lock:
                self._pxla[m.group(1)] += 1
                self._signatures[m.group(1)].append(m.group(2))
            return
        m = _DISPATCH_RE.match(message)
        if m:
            with self._lock:
                self._dispatch[m.group(1)] += 1

    # -- lifecycle ---------------------------------------------------------
    def __enter__(self) -> "RetraceGuard":
        import jax
        self._prev_flag = bool(jax.config.jax_log_compiles)
        jax.config.update("jax_log_compiles", True)
        self._handler = _CompileLogHandler(self)
        for name in _COMPILE_LOGGERS:
            lg = logging.getLogger(name)
            self._prev_levels[name] = lg.level
            # log_compiles promotes compile records to WARNING; make sure
            # the logger does not filter below that regardless of app config
            if lg.level > logging.WARNING:
                lg.setLevel(logging.WARNING)
            lg.addHandler(self._handler)
        return self

    def __exit__(self, *exc) -> None:
        import jax
        for name in _COMPILE_LOGGERS:
            lg = logging.getLogger(name)
            if self._handler is not None:
                lg.removeHandler(self._handler)
            if name in self._prev_levels:
                lg.setLevel(self._prev_levels[name])
        self._handler = None
        if self._prev_flag is not None:
            jax.config.update("jax_log_compiles", self._prev_flag)
        self._prev_flag = None

    # -- queries -----------------------------------------------------------
    def counts(self) -> Dict[str, int]:
        """Program name -> compilations observed (pxla source preferred;
        dispatch-completion records only if pxla saw nothing)."""
        with self._lock:
            src = self._pxla if self._pxla else self._dispatch
            return dict(src)

    def total(self) -> int:
        return sum(self.counts().values())

    def signatures(self, program: str) -> List[str]:
        """Argument-shape signatures compiled for ``program`` — each entry
        is one bucket; duplicates mean the engine recompiled a shape it
        had already paid for."""
        with self._lock:
            return list(self._signatures.get(program, ()))

    def snapshot(self) -> Dict[str, int]:
        return self.counts()

    def new_since(self, snapshot: Dict[str, int]) -> Dict[str, int]:
        """Programs (with counts) compiled after ``snapshot`` was taken."""
        now = self.counts()
        return {name: n - snapshot.get(name, 0)
                for name, n in now.items() if n > snapshot.get(name, 0)}

    def self_check(self) -> None:
        """Raise if the hook observed no compile events at all — a frozen
        region would then pass vacuously (e.g. jax renamed its compile
        loggers)."""
        if self.total() == 0:
            raise RetraceError(
                "RetraceGuard observed zero compile events — the "
                "jax.log_compiles hook is not wired (jax logger rename?); "
                "a frozen-region assertion would be vacuous")

    @contextmanager
    def frozen(self, what: str = "frozen region") -> Iterator[None]:
        """Assert that no new XLA program compiles inside the block."""
        before = self.snapshot()
        yield
        new = self.new_since(before)
        if new:
            detail = ", ".join(f"{name} x{n}"
                               for name, n in sorted(new.items()))
            raise RetraceError(
                f"{what} compiled {sum(new.values())} new XLA program(s) "
                f"mid-run: {detail} — an unbucketed shape or a rebuilt "
                "closure slipped into the hot path")
