"""Static analysis + runtime sanitizers for the serving stack's contracts.

Two halves:

  * **Static** (stdlib-only, importable without jax): an AST rule engine
    (``linter.py``) with the repo-specific rules in ``rules.py`` —
    refcount pairing, tracer purity, shape-bucket discipline, stats
    registration, config/test parity. Driven by ``tools/check_lint.py``
    in CI; suppressions are ``# lint: disable=<rule> -- <reason>`` with
    the reason mandatory.
  * **Runtime** (``retrace_guard.py``, ``sanitize.py``): a compile-event
    counter that pins "a warmed engine compiles zero new programs
    mid-run", and a leak sanitizer that re-checks the KV pool's refcount
    ledger (and the expert store's residency ledger) at every retire.
"""
from repro.analysis.linter import (  # noqa: F401
    Diagnostic,
    LintReport,
    Rule,
    run_lint,
)
from repro.analysis.rules import default_rules  # noqa: F401

__all__ = [
    "Diagnostic",
    "LintReport",
    "Rule",
    "run_lint",
    "default_rules",
    "RetraceGuard",
    "RetraceError",
    "LeakSanitizer",
    "sanitize_engine",
]


def __getattr__(name):
    # the runtime half imports jax; keep the static half importable without
    # it (the CI lint job installs no third-party deps)
    if name in ("RetraceGuard", "RetraceError"):
        from repro.analysis import retrace_guard
        return getattr(retrace_guard, name)
    if name in ("LeakSanitizer", "sanitize_engine"):
        from repro.analysis import sanitize
        return getattr(sanitize, name)
    raise AttributeError(name)
