"""HLO-text cost analysis that accounts for loop trip counts.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so a 60-layer
``lax.scan`` model under-reports flops ~60x. This parser walks the compiled
module text: per-computation dot flops and collective bytes, then resolves
fusions/calls/whiles recursively, multiplying while bodies by their
``known_trip_count`` backend config. All numbers are per-device (the module
is post-SPMD-partitioning).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List

_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "u32": 4, "s32": 4,
          "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2,
          "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}

_SHAPE = re.compile(r"\b(\w+)\[([0-9,]*)\]")
_INSTR = re.compile(r"^\s+(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CALLS = re.compile(r"calls=(%[\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=(%[\w.\-]+)")
_WHILE = re.compile(r"\bwhile\(.*?condition=(%[\w.\-]+), body=(%[\w.\-]+)")
_TRIP = re.compile(r'known_trip_count\\?":\s*\{\\?"n\\?":\\?"(\d+)')
_COLL = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")

_OPS_CUT = re.compile(
    r"\b(dot|fusion|while|call|custom-call|all-gather|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute|get-tuple-element|"
    r"parameter|constant|convert|broadcast|reshape|transpose|add|multiply|"
    r"dynamic-slice|dynamic-update-slice|iota|tuple|bitcast|copy|slice|"
    r"reduce|compare|select|exponential|divide|subtract|maximum|minimum|"
    r"rsqrt|negate|log|tanh|concatenate|pad|scatter|gather|convolution|"
    r"rng|sort|clamp|sign|and|or|not|xor|abs|floor|ceil|power|remainder|"
    r"cbrt|erf|logistic|is-finite|atan2|sqrt|reduce-window|rev|map|"
    r"partition-id|replica-id|domain|after-all|infeed|outfeed|"
    r"optimization-barrier|send|recv|cosine|sine|real|imag|complex|"
    r"stochastic-convert|dynamic-reshape|async-start|async-done)\b")


def _shapes_in(type_str: str) -> List[tuple]:
    out = []
    for m in _SHAPE.finditer(type_str):
        dt = m.group(1)
        if dt not in _BYTES:
            continue
        dims = [int(x) for x in m.group(2).split(",") if x]
        out.append((dt, dims))
    return out


def _elems(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _nbytes(type_str: str) -> float:
    return float(sum(_elems(d) * _BYTES[dt] for dt, d in _shapes_in(type_str)))


def _result_type(rest: str) -> str:
    """Everything before the opcode = the result type string."""
    m = _OPS_CUT.search(rest)
    return rest[: m.start()] if m else rest


def _instr_bytes(opname: str, res_b: float, op_sizes) -> float:
    """HBM-traffic model for one instruction.

    Slice-like ops (fusion/dynamic-slice/DUS/copy) get two corrections:
      * in-place update pattern — exactly one operand matches the result
        shape and a much smaller operand exists (a KV-cache DUS inside a
        layer scan): traffic = 2x the updated slice, not 2x the buffer;
      * slice-read pattern — an operand much larger than the result (a
        scan's stacked xs being dynamic-sliced): operand contribution is
        capped at 2x the result.
    """
    slice_like = opname in ("fusion", "dynamic-slice",
                            "dynamic-update-slice", "copy")
    if slice_like:
        same = [ob for ob in op_sizes if ob == res_b]
        small = [ob for ob in op_sizes if ob < max(res_b, 1) / 4]
        if len(same) == 1 and small:
            return 2.0 * max(small)          # in-place buffer update
        nb = res_b
        for ob in op_sizes:
            nb += min(ob, 2.0 * max(res_b, 1))
        return nb
    return res_b + float(sum(op_sizes))


@dataclass
class CompCost:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    refs: List[tuple] = field(default_factory=list)  # (kind, name, mult)


def _parse(text: str):
    comps: Dict[str, CompCost] = {}
    result_shape: Dict[str, list] = {}   # %instr -> first (dtype, dims)
    cur: CompCost | None = None
    entry = None

    for raw in text.splitlines():
        if raw and not raw[0].isspace():
            s = raw.strip()
            if s.endswith("{") and "->" in s:
                is_entry = s.startswith("ENTRY")
                name = s.split()[1] if is_entry else s.split()[0]
                name = name.split("(")[0].lstrip("%")
                cur = comps.setdefault(name, CompCost())
                if is_entry:
                    entry = name
                # parameter types (header "name: type" pairs)
                for pm in re.finditer(r"([\w.\-]+):\s*(\([^)]*\)|[\w]+"
                                      r"\[[0-9,]*\](?:\{[0-9,]*\})?)", s):
                    sh = _shapes_in(pm.group(2))
                    if sh:
                        result_shape["%" + pm.group(1)] = sh[0]
            continue
        if cur is None:
            continue
        m = _INSTR.match(raw)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        rt = _result_type(rest)
        sh = _shapes_in(rt)
        if sh:
            result_shape[name] = sh[0]

        # HBM traffic estimate: result + operand bytes for every top-level
        # instruction that touches memory (fusion internals excluded by the
        # bytes-resolution rule in analyze()). Slice-like ops (dynamic-slice
        # of a scan's stacked xs, in-place dynamic-update-slice of a KV
        # cache) only touch the slice, not the whole buffer — cap each
        # operand at 2x the result size for those, otherwise a 60-layer
        # decode scan "reads" the entire stacked cache every iteration.
        opm = _OPS_CUT.search(rest)
        opname = opm.group(1) if opm else ""
        if opname not in ("parameter", "constant", "tuple",
                          "get-tuple-element", "bitcast", "after-all",
                          "partition-id", "replica-id", "iota", "while",
                          "domain", "optimization-barrier"):
            res_b = _nbytes(rt)
            attrs_cut = re.split(r"(?:calls=|to_apply=|condition=)", rest)[0]
            arg_str = attrs_cut.split("(", 1)[1] if "(" in attrs_cut else ""
            op_sizes = []
            for op_ref in re.findall(r"%[\w.\-]+", arg_str):
                if op_ref in result_shape:
                    dt, dims = result_shape[op_ref]
                    op_sizes.append(_elems(dims) * _BYTES[dt])
            cur.hbm_bytes += _instr_bytes(opname, res_b, op_sizes)

        cm = _COLL.search(rest)
        if cm:
            if cm.group(2) == "-done":
                continue
            op = cm.group(1)
            cur.coll[op] = cur.coll.get(op, 0.0) + _nbytes(rt)
            continue
        wm = _WHILE.search(rest)
        if wm:
            trip = 1
            tm = _TRIP.search(rest)
            if tm:
                trip = int(tm.group(1))
            cur.refs.append(("while", wm.group(2).lstrip("%"), trip))
            cur.refs.append(("while", wm.group(1).lstrip("%"), trip))
            continue
        if re.search(r"\bdot\(", rest):
            res_elems = sum(_elems(d) for _, d in _shapes_in(rt))
            # lhs operand ref: first %name inside the parens (the operand's
            # own type string contains commas, so naive comma-splitting
            # truncates mid-shape and loses the contracting-dim factor)
            lhs_refs = re.findall(r"%[\w.\-]+", rest.split("dot(", 1)[1])
            lhs = lhs_refs[0] if lhs_refs else ""
            k = 1
            lc = _LHS_C.search(rest)
            if lc and lhs in result_shape:
                dims = result_shape[lhs][1]
                for ci in [int(x) for x in lc.group(1).split(",") if x]:
                    if ci < len(dims):
                        k *= dims[ci]
            cur.dot_flops += 2.0 * res_elems * k
            continue
        if "convolution(" in rest:
            # depthwise/1d convs in this codebase are tiny; approximate
            res_elems = sum(_elems(d) for _, d in _shapes_in(rt))
            cur.dot_flops += 2.0 * res_elems  # lower bound; negligible share
            continue
        for rx in (_CALLS, _TO_APPLY):
            fm = rx.search(rest)
            if fm:
                cur.refs.append(("fusion", fm.group(1).lstrip("%"), 1))
                break
    return comps, entry


def analyze(text: str) -> dict:
    comps, entry = _parse(text)
    memo: Dict[str, tuple] = {}

    def resolve(name: str, stack=()) -> tuple:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return 0.0, 0.0, {}
        c = comps[name]
        flops = c.dot_flops
        hbm = c.hbm_bytes
        coll = dict(c.coll)
        for kind, ref, mult in c.refs:
            f, b, co = resolve(ref, stack + (name,))
            flops += mult * f
            if kind == "while":       # fusion internals never hit HBM
                hbm += mult * b
            for k, v in co.items():
                coll[k] = coll.get(k, 0.0) + mult * v
        memo[name] = (flops, hbm, coll)
        return memo[name]

    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0, "collective_bytes": {},
                "collective_total": 0.0}
    flops, hbm, coll = resolve(entry)
    return {"flops": flops, "hbm_bytes": hbm, "collective_bytes": coll,
            "collective_total": float(sum(coll.values()))}
