"""Top-contributor analysis over compiled HLO: which collective/dot
instructions (with loop multiplicity) dominate — the dry-run 'profiler'
driving §Perf hypotheses.
"""
from __future__ import annotations

import re
from collections import Counter
from typing import Dict

from repro.launch.hlo_cost import (_INSTR, _OPS_CUT, _SHAPE, _TRIP, _WHILE,
                                   _instr_bytes, _nbytes, _result_type,
                                   _shapes_in)

_META = re.compile(r'op_name="([^"]*)"')


def top_contributors(text: str, top: int = 15):
    """Returns (collectives, dots): lists of (bytes|flops, mult, op, shape,
    op_name) sorted desc, with while-loop multiplicity applied."""
    # 1. map computation name -> while multiplicity (1 level is enough here:
    #    nested loop mults multiply)
    mult: Dict[str, int] = {}
    comp_of_line = []
    cur = None
    comps: Dict[str, list] = {}
    for raw in text.splitlines():
        if raw and not raw[0].isspace():
            s = raw.strip()
            if s.endswith("{") and "->" in s:
                name = s.split()[1] if s.startswith("ENTRY") else s.split()[0]
                cur = name.split("(")[0].lstrip("%")
                comps[cur] = []
            continue
        if cur is not None:
            comps[cur].append(raw)

    # find while edges: parent -> (body, trip)
    edges = []
    for cname, lines in comps.items():
        for raw in lines:
            m = _INSTR.match(raw)
            if not m:
                continue
            wm = _WHILE.search(m.group(2))
            if wm:
                tm = _TRIP.search(m.group(2))
                trip = int(tm.group(1)) if tm else 1
                edges.append((cname, wm.group(2).lstrip("%"), trip))

    # propagate multiplicity from entry
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            entry = line.split()[1].lstrip("%").split("(")[0]
            break
    mult = {entry: 1}
    changed = True
    while changed:
        changed = False
        for parent, body, trip in edges:
            if parent in mult:
                m = mult[parent] * trip
                if mult.get(body) != m:
                    mult[body] = m
                    changed = True

    colls = []
    coll_re = re.compile(
        r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(-start)?\(")
    for cname, lines in comps.items():
        m_c = mult.get(cname, 1)
        for raw in lines:
            m = _INSTR.match(raw)
            if not m:
                continue
            rest = m.group(2)
            cm = coll_re.search(rest)
            if cm and "-done" not in rest.split("(")[0]:
                rt = _result_type(rest)
                nb = _nbytes(rt) * m_c
                name_m = _META.search(rest)
                colls.append((nb, m_c, cm.group(1), rt.strip()[:60],
                              (name_m.group(1) if name_m else "")[:90]))
    colls.sort(reverse=True)
    return colls[:top]


def top_hbm(text: str, top: int = 15):
    """Rank instructions by result+operand bytes x loop multiplicity (the
    same model hlo_cost.analyze sums into the memory roofline term)."""
    comps: Dict[str, list] = {}
    cur = None
    result_shape: Dict[str, tuple] = {}
    for raw in text.splitlines():
        if raw and not raw[0].isspace():
            s = raw.strip()
            if s.endswith("{") and "->" in s:
                name = s.split()[1] if s.startswith("ENTRY") else s.split()[0]
                cur = name.split("(")[0].lstrip("%")
                comps[cur] = []
                for pm in re.finditer(
                        r"([\w.\-]+):\s*(\([^)]*\)|[\w]+\[[0-9,]*\]"
                        r"(?:\{[0-9,]*\})?)", s):
                    sh = _shapes_in(pm.group(2))
                    if sh:
                        result_shape["%" + pm.group(1)] = sh[0]
            continue
        if cur is not None:
            comps[cur].append(raw)

    # reuse multiplicity propagation from top_contributors
    edges = []
    entry = None
    for cname, lines in comps.items():
        for raw in lines:
            m = _INSTR.match(raw)
            if m:
                wm = _WHILE.search(m.group(2))
                if wm:
                    tm = _TRIP.search(m.group(2))
                    edges.append((cname, wm.group(2).lstrip("%"),
                                  int(tm.group(1)) if tm else 1))
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            entry = line.split()[1].lstrip("%").split("(")[0]
            break
    mult = {entry: 1}
    changed = True
    while changed:
        changed = False
        for parent, body, trip in edges:
            if parent in mult and mult.get(body) != mult[parent] * trip:
                mult[body] = mult[parent] * trip
                changed = True

    from repro.launch.hlo_cost import _BYTES, _elems
    rows = []
    skip_ops = ("parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "after-all", "partition-id", "replica-id",
                "iota", "while", "domain", "optimization-barrier")
    for cname, lines in comps.items():
        m_c = mult.get(cname, 1)
        for raw in lines:
            m = _INSTR.match(raw)
            if not m:
                continue
            name, rest = m.group(1), m.group(2)
            rt = _result_type(rest)
            sh = _shapes_in(rt)
            if sh:
                result_shape[name] = sh[0]
            opm = _OPS_CUT.search(rest)
            if not opm or opm.group(1) in skip_ops:
                continue
            res_b = _nbytes(rt)
            attrs_cut = re.split(r"(?:calls=|to_apply=|condition=)",
                                 rest)[0]
            arg_str = attrs_cut.split("(", 1)[1] if "(" in attrs_cut else ""
            op_sizes = []
            for ref in re.findall(r"%[\w.\-]+", arg_str):
                if ref in result_shape:
                    dt, dims = result_shape[ref]
                    op_sizes.append(_elems(dims) * _BYTES[dt])
            nb = _instr_bytes(opm.group(1), res_b, op_sizes)
            if nb * m_c > 0:
                name_m = _META.search(rest)
                rows.append((nb * m_c, m_c, opm.group(1), rt.strip()[:46],
                             (name_m.group(1) if name_m else "")[:80]))
    rows.sort(reverse=True)
    return rows[:top]


def main():
    import sys
    text = open(sys.argv[1]).read()
    print("top collectives (bytes x loop-mult):")
    for nb, m, op, shape, name in top_contributors(text):
        print(f"  {nb / 2**30:9.2f} GiB x{m:4d} {op:18s} {shape:40s} {name}")


if __name__ == "__main__":
    main()
