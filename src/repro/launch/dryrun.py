import os
if __name__ == "__main__":
    # must land before jax initialises; only when run as the dry-run tool —
    # library importers (engines pulling the per-layer roofline estimates)
    # must NOT have their process forced to 512 host devices
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x input-shape) step on the
# production mesh, print memory_analysis/cost_analysis, and extract roofline
# terms. No real allocation: params/batches/states are ShapeDtypeStructs.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json f]

import argparse
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch import shardctx
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16, data_axes,
                               make_mini_mesh, make_production_mesh)
from repro.launch.sharding import (act_sharding, batch_spec, shard_decode_state,
                                   shard_params)
from repro.models import build_model, input_specs
from repro.training.optimizer import make_adamw

SKIPS = {
    # long_500k needs sub-quadratic attention (DESIGN.md long-context table)
    ("pixtral-12b", "long_500k"): "pure full attention",
    ("deepseek-v2-236b", "long_500k"): "full (latent) attention",
    ("yi-6b", "long_500k"): "pure full attention",
    ("phi3-mini-3.8b", "long_500k"): "pure full attention",
    ("internlm2-1.8b", "long_500k"): "pure full attention",
    ("seamless-m4t-large-v2", "long_500k"): "full-attention decoder",
}

def _abstract(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def _attn_layer_params(cfg, kind: str) -> int:
    d, hd = cfg.d_model, cfg.hd
    if kind == "mla":
        m = cfg.mla
        qk = m.nope_head_dim + m.rope_head_dim
        return (d * m.q_lora_rank + m.q_lora_rank * cfg.num_heads * qk
                + d * (m.kv_lora_rank + m.rope_head_dim)
                + m.kv_lora_rank * cfg.num_heads * (m.nope_head_dim
                                                    + m.v_head_dim)
                + cfg.num_heads * m.v_head_dim * d)
    if kind == "ssd":
        s = cfg.ssm
        di = s.expand * d
        return d * (2 * di + 2 * s.d_state + di // s.headdim) + di * d
    if kind == "rglru":
        w = cfg.rglru.lru_width or d
        return 2 * d * w + 3 * w + w * d
    return (d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd
            + cfg.num_heads * hd * d)


def _kv_read_positions(cfg, kind: str, cache_len: int) -> int:
    if kind == "local":
        return min(cache_len, cfg.window)
    if kind == "chunked":
        return min(cache_len, cfg.chunk)
    if kind in ("rglru", "ssd"):
        return 0                     # bounded recurrent state, not a KV scan
    return cache_len


def decode_layer_roofline(cfg, batch: int = 1, cache_len: int = 1024,
                          peak_flops: float = PEAK_FLOPS_BF16,
                          hbm_bw: float = HBM_BW):
    """Per-layer ``(attn_s, ffn_s)`` roofline estimates for ONE decode step.

    The analytic twin of the compiled dry-run's cost extraction, resolved
    per layer: each half's time is ``max(flops/peak, bytes/bw)`` with
    matvec flops over the half's parameters plus the attention KV scan, and
    bytes covering the weights plus the KV read. The serving engines use
    this to *derive* ``layer_compute_s`` instead of taking it as a knob —
    the OverlapTracker's compute clock then reflects the architecture, so
    modeled stall/overlap reports are calibrated per arch (ROADMAP
    "Measured overlap"). A measured-walltime override rescales these
    per-layer terms to a step's real wall clock (``DecodeCore`` with
    ``layer_compute_s="measured"``).
    """
    dt = jnp.dtype(cfg.dtype).itemsize
    d = cfg.d_model
    kinds = cfg.layer_kinds()
    out = []
    for li, kind in enumerate(kinds):
        ap = _attn_layer_params(cfg, kind)
        kv_pos = _kv_read_positions(cfg, kind, cache_len)
        if kind == "mla":
            m = cfg.mla
            qk_dim = cfg.num_heads * (m.nope_head_dim + m.rope_head_dim)
            kv_bytes = kv_pos * (m.kv_lora_rank + m.rope_head_dim) * dt
        else:
            qk_dim = cfg.num_heads * cfg.hd
            kv_bytes = kv_pos * 2 * cfg.num_kv_heads * cfg.hd * dt
        attn_flops = batch * (2 * ap + 4 * kv_pos * qk_dim)
        attn_bytes = ap * dt + batch * kv_bytes
        attn_s = max(attn_flops / peak_flops, attn_bytes / hbm_bw)

        ffn_s = 0.0
        if kind != "ssd":
            m = cfg.moe
            if m is not None and li >= m.first_dense_layers:
                per = 3 * d * m.d_ff_expert
                active = (m.top_k + m.num_shared) * per + d * m.num_experts
                ffn_flops = 2 * active * batch
                # distinct routed experts' weights stream once per step
                ffn_bytes = (min(batch * m.top_k, m.num_experts) + m.num_shared
                             ) * per * dt + d * m.num_experts * dt
            else:
                dff = cfg.d_ff
                if m is not None and m.d_ff_dense:
                    dff = m.d_ff_dense
                ffn_flops = 2 * 3 * d * dff * batch
                ffn_bytes = 3 * d * dff * dt
            ffn_s = max(ffn_flops / peak_flops, ffn_bytes / hbm_bw)
        out.append((attn_s, ffn_s))
    return out


def expert_ffn_roofline(cfg, peak_flops: float = PEAK_FLOPS_BF16,
                        hbm_bw: float = HBM_BW):
    """``(per_token_s, base_s)`` roofline terms for ONE expert's FFN
    computed remotely (the ship half of the fetch-vs-ship decision,
    serving/expertstore.DispatchPlanner).

    ``per_token_s`` is the matvec flops leg — ``2 * 3*d*d_ff_expert /
    peak`` per shipped token; ``base_s`` is the token-independent leg —
    the peer streaming the expert's weights from its own DRAM once
    (``3*d*d_ff_expert * itemsize / hbm_bw``). Same parameter-count and
    max(flops, bytes)-free split as :func:`decode_layer_roofline`'s MoE
    branch, factored per expert: at decode token counts the weight read
    dominates, which is exactly why shipping a few tokens beats fetching
    weights over a much slower interconnect.
    """
    m = cfg.moe
    assert m is not None, "expert_ffn_roofline needs an MoE config"
    per = 3 * cfg.d_model * m.d_ff_expert
    dt = jnp.dtype(cfg.dtype).itemsize
    return 2 * per / peak_flops, per * dt / hbm_bw


def build_step(arch: str, shape_name: str, mesh, cfg_transform=None,
               microbatch: int = 1):
    """Returns (step_fn, example_args (abstract), in_shardings, donate)."""
    cfg = get_config(arch)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    model = build_model(cfg)
    shp = INPUT_SHAPES[shape_name]
    batch = input_specs(cfg, shape_name)

    params_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shard = shard_params(cfg, params_abs, mesh)
    b_shard = jax.tree.map(batch_spec(cfg, shape_name, mesh), batch)

    if shp.mode == "train":
        opt_init, opt_update = make_adamw(lr=3e-4, clip=1.0)
        opt_abs = jax.eval_shape(opt_init, params_abs)
        o_shard = jax.tree.map(
            lambda l, s=None: None, opt_abs)  # placeholder, set below
        # optimizer state shards like params (mu/nu) + replicated step
        o_shard = {
            "mu": shard_params(cfg, opt_abs["mu"], mesh),
            "nu": shard_params(cfg, opt_abs["nu"], mesh),
            "step": jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()),
        }

        def train_step(params, opt_state, batch, microbatch: int = 1):
            def lf(p, mb):
                loss, mets = model.loss_fn(p, mb)
                return loss, mets

            if microbatch <= 1:
                (loss, _), grads = jax.value_and_grad(lf, has_aux=True)(
                    params, batch)
            else:
                # gradient accumulation: peak activation/temp memory drops
                # ~microbatch-x; per-token collectives unchanged (§Perf A6)
                mbs = jax.tree.map(
                    lambda l: l.reshape((microbatch, l.shape[0] // microbatch)
                                        + l.shape[1:]), batch)

                def acc(carry, mb):
                    g_acc, l_acc = carry
                    (loss, _), g = jax.value_and_grad(lf, has_aux=True)(
                        params, mb)
                    g_acc = jax.tree.map(
                        lambda a, b2: a + b2.astype(jnp.float32), g_acc, g)
                    return (g_acc, l_acc + loss), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss), _ = jax.lax.scan(acc, (g0, 0.0), mbs)
                grads = jax.tree.map(lambda g: g / microbatch, grads)
                loss = loss / microbatch
            params, opt_state, stats = opt_update(grads, opt_state, params)
            return params, opt_state, loss

        import functools
        step = functools.partial(train_step, microbatch=microbatch)
        return (step, (params_abs, opt_abs, batch),
                (p_shard, o_shard, b_shard), (0, 1))

    if shp.mode == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch, cache_len=shp.seq_len)
        return prefill_step, (params_abs, batch), (p_shard, b_shard), ()

    # decode
    state_abs = jax.eval_shape(
        partial(model.init_decode_state, shp.global_batch, shp.seq_len))
    s_shard = shard_decode_state(cfg, state_abs, mesh)

    def serve_step(params, state, batch):
        return model.decode_step(params, state, batch)

    return serve_step, (params_abs, state_abs, batch), \
        (p_shard, s_shard, b_shard), (1,)


def run_one(arch: str, shape_name: str, mesh, verbose: bool = True,
            remat: bool = True, cfg_transform=None,
            microbatch: int = 1) -> dict:
    if (arch, shape_name) in SKIPS:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "reason": SKIPS[(arch, shape_name)]}
    cfg = get_config(arch)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    shp = INPUT_SHAPES[shape_name]
    t0 = time.time()
    step, args, in_sh, donate = build_step(arch, shape_name, mesh,
                                           cfg_transform, microbatch)
    act_sh = act_sharding(cfg, shape_name, mesh)
    with mesh, shardctx.activation_sharding(
            act_sh, remat=remat and shp.mode == "train", mesh=mesh,
            dp_axes=data_axes(mesh)):
        lowered = jax.jit(step, in_shardings=in_sh,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    # loop-trip-aware per-device cost model (see hlo_cost.py; the built-in
    # compiled.cost_analysis() counts while bodies once)
    cost = hlo_analyze(hlo)

    n_chips = int(np.prod(list(mesh.shape.values())))
    flops = float(cost["flops"])                 # per device
    hbm_bytes = float(cost["hbm_bytes"])         # per device
    coll = {k: float(v) for k, v in cost["collective_bytes"].items()}
    coll_total = float(cost["collective_total"])
    terms = {
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": hbm_bytes / HBM_BW,
        "collective_s": coll_total / ICI_BW,
    }
    dominant = max(terms, key=terms.get)

    na = cfg.active_param_count()
    tokens = shp.global_batch * (shp.seq_len if shp.mode in
                                 ("train", "prefill") else 1)
    mult = 6 if shp.mode == "train" else 2
    model_flops = mult * na * tokens             # global
    model_flops_dev = model_flops / n_chips

    out = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mode": shp.mode,
        "chips": n_chips,
        "compile_s": round(time.time() - t0, 1),
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": hbm_bytes,
        "collective_bytes": coll,
        "collective_total": coll_total,
        "terms_s": {k: float(f"{v:.6g}") for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": float(model_flops),
        "useful_ratio": float(model_flops_dev / flops) if flops else 0.0,
        "bytes_per_device": {
            "argument": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "peak": mem.argument_size_in_bytes + mem.temp_size_in_bytes,
        },
    }
    if verbose:
        print(f"[{arch} x {shape_name}] compiled in {out['compile_s']}s on "
              f"{n_chips} chips")
        print(f"  mem/device: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB")
        print(f"  HLO/dev: {flops:.3e} flops, {hbm_bytes:.3e} bytes, "
              f"collectives={coll_total:.3e}B {coll}")
        print(f"  roofline terms (s): " +
              ", ".join(f"{k}={v:.4g}" for k, v in terms.items()) +
              f" -> dominant: {dominant}")
        print(f"  MODEL_FLOPS(global)={model_flops:.3e} useful/HLO="
              f"{out['useful_ratio']:.3f}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mini", action="store_true",
                    help="8-device test mesh (for CI)")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--moe-group", type=int, default=0,
                    help="override MoE dispatch group size (perf lever)")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="gradient-accumulation microbatches (perf lever)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    cfg_transform = None
    if args.moe_group:
        import dataclasses

        def cfg_transform(cfg, _g=args.moe_group):
            if cfg.moe is None:
                return cfg
            return cfg.replace(
                moe=dataclasses.replace(cfg.moe, dispatch_group=_g))

    mesh = (make_mini_mesh(multi_pod=args.multi_pod) if args.mini
            else make_production_mesh(multi_pod=args.multi_pod))
    print(f"mesh: {dict(mesh.shape)} ({int(np.prod(list(mesh.shape.values())))}"
          f" devices)")

    combos = []
    if args.all:
        combos = [(a, s) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES]
    else:
        archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
        shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
        combos = [(a, s) for a in archs for s in shapes]

    results = []
    failed = []
    for arch, shape in combos:
        try:
            results.append(run_one(arch, shape, mesh,
                                   remat=not args.no_remat,
                                   cfg_transform=cfg_transform,
                                   microbatch=args.microbatch))
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failed.append((arch, shape, str(e)[:200]))
            results.append({"arch": arch, "shape": shape, "status": "fail",
                            "error": str(e)[:500]})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skip")
    print(f"\n=== dry-run summary: {ok} ok, {sk} skip, {len(failed)} fail ===")
    for a, s, e in failed:
        print(f"  FAIL {a} x {s}: {e}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
