"""Serving launcher: batch-1 offloaded decode with a chosen prefetch policy.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-v2-lite \
      --capacity-frac 0.2 --policy moe-infinity --tokens 64
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.policies import (GlobalFrequencyPolicy, MoEInfinityPolicy,
                                 NextLayerAllPolicy, NoPrefetchPolicy,
                                 OnlineMoEBeyondPolicy, RandomPolicy)
from repro.core.tracing import collect_traces, moe_layer_ids
from repro.data import make_topic_corpus, sample_prompts
from repro.launch.train import train
from repro.models import build_model
from repro.serving.engine import OffloadEngine


def build_policy(name: str, cfg, train_traces, width: int = 6,
                 predictor=None, pcfg=None):
    n_layers = len(moe_layer_ids(cfg))
    e = cfg.moe.num_experts
    if name == "none":
        return NoPrefetchPolicy()
    if name == "random":
        return RandomPolicy(e, width)
    if name == "next-layer-all":
        return NextLayerAllPolicy(e)
    if name == "global-frequency":
        return GlobalFrequencyPolicy(train_traces, n_layers, e, width)
    if name == "moe-infinity":
        return MoEInfinityPolicy(train_traces, n_layers, e, width)
    if name == "moe-beyond":
        assert predictor is not None
        return OnlineMoEBeyondPolicy(predictor, pcfg, width)
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite")
    ap.add_argument("--policy", default="moe-infinity")
    ap.add_argument("--capacity-frac", type=float, default=0.2)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--n-train-traces", type=int, default=8)
    args = ap.parse_args()

    params, _ = train(args.arch, reduced=True, steps=args.train_steps,
                      batch_size=16, seq_len=64)
    cfg = get_reduced(args.arch)
    model = build_model(cfg)
    corpus = make_topic_corpus(cfg.vocab_size, n_topics=8, seed=0)

    train_traces = collect_traces(
        model, params, sample_prompts(corpus, args.n_train_traces, 16),
        max_new=48, cache_len=80)

    n_layers = len(moe_layer_ids(cfg))
    capacity = max(1, int(args.capacity_frac * n_layers
                          * cfg.moe.num_experts))
    policy = build_policy(args.policy, cfg, train_traces)
    engine = OffloadEngine(model, params, policy, capacity)

    prompt = sample_prompts(corpus, 1, 16, seed=123)[0]
    t0 = time.time()
    out = engine.generate(prompt, max_new=args.tokens,
                          cache_len=len(prompt) + args.tokens + 1)
    dt = time.time() - t0
    s = engine.stats
    print(f"policy={policy.name} capacity={capacity} "
          f"({args.capacity_frac:.0%} of {n_layers * cfg.moe.num_experts})")
    print(f"generated {len(out)} tokens in {dt:.1f}s")
    print(f"cache hit rate: {s.hit_rate:.3f} ({s.hits} hits / {s.misses} "
          f"misses), fetched {s.fetch_bytes / 2**20:.1f} MiB, "
          f"simulated stall {s.sim_stall_s * 1e3:.1f} ms total")


if __name__ == "__main__":
    main()
