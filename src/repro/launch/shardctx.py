"""Activation-sharding context: the launcher installs PartitionSpecs here and
model code calls ``constrain_act`` / ``constrain_qkv`` at layer boundaries.
Outside a mesh context these are no-ops, so tests and CPU runs are
unaffected.

Why ``constrain_qkv`` exists (EXPERIMENTS.md §Perf, hypothesis A1): with
between-layer activations sequence-sharded on "model" (Megatron-SP style,
needed so remat carries fit HBM), XLA re-gathers K/V inside every q-chunk
scan iteration — collectives are not hoisted out of while loops. Pinning
q/k/v to head-sharded right after the projections turns that into ONE
seq->head reshard per layer.
"""
from __future__ import annotations

import contextlib
import threading

import jax

_state = threading.local()


def current_act_spec():
    return getattr(_state, "act_spec", None)


def current_remat() -> bool:
    return getattr(_state, "remat", False)


def _mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def activation_sharding(act_spec=None, remat: bool = False, mesh=None,
                        dp_axes=None):
    """act_spec: NamedSharding for (B, T, D) activations between layers."""
    prev = (current_act_spec(), current_remat(), _mesh(),
            getattr(_state, "dp_axes", None))
    _state.act_spec = act_spec
    _state.remat = remat
    _state.mesh = mesh
    _state.dp_axes = dp_axes
    try:
        yield
    finally:
        (_state.act_spec, _state.remat, _state.mesh,
         _state.dp_axes) = prev


def constrain_act(x):
    spec = current_act_spec()
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_qkv(x):
    """Pin a (B, T, H, hd) projection to head-sharded on "model" (batch on
    the data axes) when H divides; no-op outside a launcher context."""
    mesh = _mesh()
    if mesh is None or x.ndim != 4:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    n_model = mesh.shape["model"]
    dp = getattr(_state, "dp_axes", None)
    b, t, h, hd = x.shape
    bdim = dp if (dp and b % _axes_size(mesh, dp) == 0) else None
    hdim = "model" if h % n_model == 0 and h >= n_model else None
    if hdim is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(bdim, None, hdim, None)))


def constrain_tokens(x, dim: int = 1):
    """Pin a tensor's token dim to "model"-sharded (the §Perf A5 lever: the
    MoE combine's (g, t, d) output becomes a reduce-scatter over the expert
    shards instead of a full all-reduce). No-op outside a launcher context
    or when the dim does not divide."""
    mesh = _mesh()
    if mesh is None:
        return x
    n_model = mesh.shape["model"]
    if x.shape[dim] % n_model or x.shape[dim] < n_model:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = [None] * x.ndim
    spec[dim] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def _axes_size(mesh, axes) -> int:
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
