"""Sharding rules: params (FSDP x TP x EP), decode states and batches.

Strategy (DESIGN.md §8):
  * params: one matmul dim on "model" (TP) — expert dim for MoE weights,
    head dim for attention, d_ff for FFNs — and one dim on "data" (FSDP,
    all-gathered just in time). Scan-stacked leaves skip the leading G dim.
  * batches: batch on (pod, data); long_500k (batch=1) shards the sequence.
  * decode KV caches: batch on (pod, data) when divisible, sequence on
    "model"; recurrent states shard their widest divisible dims.

Everything is divisibility-guarded, so the same rules serve the 16x16 and
2x16x16 production meshes and the 8-device test mesh.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, ModelConfig
from repro.launch.mesh import data_axes


def _axis_size(mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return mesh.shape[name]


def _fits(dim: int, n: int) -> bool:
    return n > 0 and dim % n == 0 and dim >= n


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_spec(path: str, shape, cfg: ModelConfig, mesh) -> P:
    """PartitionSpec for one parameter leaf."""
    nd = len(shape)
    dp = data_axes(mesh)
    n_model = _axis_size(mesh, "model")
    n_data = _axis_size(mesh, dp)
    spec = [None] * nd
    i0 = 1 if "/scan/" in f"/{path}/" or path.startswith("scan/") else 0
    dims = list(range(i0, nd))
    if not dims:
        return P()

    used = set()

    def assign(i, ax):
        spec[i] = ax
        used.add(i)

    # ---- model (TP / EP) dim ------------------------------------------
    model_dim = None
    if cfg.moe is not None and ("moe/w_gate" in path or "moe/w_up" in path
                                or "moe/w_down" in path):
        for i in dims:                     # expert dim -> expert parallel
            if shape[i] == cfg.moe.num_experts and _fits(shape[i], n_model):
                model_dim = i
                break
    if model_dim is None and ("attn/" in path or "self_attn" in path
                              or "cross_attn" in path):
        for i in dims:                     # head dim -> tensor parallel
            if shape[i] in (cfg.num_heads, cfg.num_kv_heads) \
                    and _fits(shape[i], n_model):
                model_dim = i
    if model_dim is None and "tok_emb" in path:
        if _fits(shape[0], n_model):
            model_dim = 0                  # vocab on model
    if model_dim is None:
        # largest trailing dim divisible by model (prefer last)
        for i in reversed(dims):
            if _fits(shape[i], n_model) and shape[i] >= 2 * n_model:
                model_dim = i
                break
    if model_dim is not None:
        assign(model_dim, "model")

    # ---- data (FSDP) dim ----------------------------------------------
    for i in dims:
        if i not in used and _fits(shape[i], n_data):
            assign(i, dp)
            break

    return P(*spec)


def shard_params(cfg: ModelConfig, abstract_params, mesh) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    out = [NamedSharding(mesh, param_spec(_path_str(p), leaf.shape, cfg,
                                          mesh))
           for p, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_spec(cfg: ModelConfig, shape_name: str, mesh) -> Any:
    """Shardings for the input batch dict."""
    shp = INPUT_SHAPES[shape_name]
    dp = data_axes(mesh)
    n_data = _axis_size(mesh, dp)
    bdim = dp if _fits(shp.global_batch, n_data) else None

    def leaf_spec(leaf_shape):
        spec = [bdim] + [None] * (len(leaf_shape) - 1)
        if bdim is None and len(leaf_shape) > 1 \
                and _fits(leaf_shape[1], n_data):
            spec[1] = dp                  # batch=1: shard sequence instead
        return P(*spec)

    def to_sharding(leaf):
        return NamedSharding(mesh, leaf_spec(leaf.shape))

    return to_sharding


def _state_leaf_spec(path: str, shape, cfg, mesh) -> P:
    dp = data_axes(mesh)
    n_data = _axis_size(mesh, dp)
    n_model = _axis_size(mesh, "model")
    nd = len(shape)
    if nd == 0:
        return P()
    # stacked leading layer/group dim for scanned caches & encdec memory
    i0 = 1 if ("scan/" in path or "memory/" in path
               or (cfg.encdec is not None and "caches/" in path)) else 0
    spec = [None] * nd
    dims = list(range(i0, nd))
    if not dims:
        return P()
    b_i = dims[0]
    if _fits(shape[b_i], n_data):
        spec[b_i] = dp
        rest = dims[1:]
    else:
        rest = dims[1:]
    # sequence dim (largest) on model; fall back to any divisible dim
    if rest:
        cand = max(rest, key=lambda i: shape[i])
        if shape[cand] >= 4 * n_model and _fits(shape[cand], n_model):
            spec[cand] = "model"
        elif spec[b_i] is None and _fits(shape[cand], n_data):
            spec[cand] = dp
    return P(*spec)


def shard_decode_state(cfg: ModelConfig, abstract_state, mesh) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
    out = []
    for p, leaf in flat:
        path = _path_str(p)
        out.append(NamedSharding(mesh,
                                 _state_leaf_spec(path, leaf.shape, cfg,
                                                  mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)


def expert_dispatch_ffn(mesh, wg, wu, wd, x_send, eid_send):
    """Expert-parallel compute dispatch: ship ``(tokens, expert_id)``
    groups across a 1-D ``("expert",)`` mesh (launch/mesh.make_expert_mesh)
    with a real ``lax.all_to_all``, compute each token's expert FFN on the
    shard that *owns* the expert, and return the outputs to the sender —
    the multi-device ground truth of the serving engines' modeled ship
    path (``TierConfig.dispatch``), runnable on CPU under
    ``--xla_force_host_platform_device_count``.

    wg/wu: (E, D, F); wd: (E, F, D) — expert-sharded, ``E`` divisible by
    the mesh's ``S`` shards, shard ``s`` owning global experts
    ``[s*E/S, (s+1)*E/S)``. ``x_send``: (S, S, C, D) send buffers —
    ``x_send[s, d, c]`` is source shard ``s``'s c-th token for destination
    shard ``d``; ``eid_send``: (S, S, C) int32 global expert ids aligned
    with it, ``-1`` marking padding slots (their outputs are zeroed).
    Every non-padding ``eid_send[s, d]`` entry must name an expert homed
    on shard ``d``. Returns (S, S, C, D): ``out[s, d, c]`` is the expert
    output for ``x_send[s, d, c]``, back on the source shard, unweighted
    (the caller applies the router's combine weights, exactly like
    :func:`repro.models.moe.expert_group_ffn`). f32 accumulation, output
    in ``x_send.dtype``.
    """
    from jax.experimental.shard_map import shard_map
    from jax import lax

    s_mesh = _axis_size(mesh, "expert")
    e_local = wg.shape[0] // s_mesh
    assert wg.shape[0] % s_mesh == 0, \
        f"num_experts {wg.shape[0]} not divisible by {s_mesh} shards"
    assert x_send.shape[0] == s_mesh and x_send.shape[1] == s_mesh

    def body(wg_l, wu_l, wd_l, xs, es):
        # wg_l/wu_l: (E/S, D, F); wd_l: (E/S, F, D) — this shard's experts
        # xs: (1, S, C, D); es: (1, S, C) — this shard's send buffers
        xs, es = xs[0], es[0]
        # dispatch: row d of the send buffer goes to shard d; afterwards
        # row j holds what shard j sent HERE
        xr = lax.all_to_all(xs, "expert", split_axis=0, concat_axis=0,
                            tiled=True)
        er = lax.all_to_all(es, "expert", split_axis=0, concat_axis=0,
                            tiled=True)
        shard = lax.axis_index("expert")
        le = jnp.clip(er - shard * e_local, 0, e_local - 1)   # (S, C)
        g_sel = jnp.take(wg_l, le, axis=0).astype(jnp.float32)
        u_sel = jnp.take(wu_l, le, axis=0).astype(jnp.float32)
        d_sel = jnp.take(wd_l, le, axis=0).astype(jnp.float32)
        xf = xr.astype(jnp.float32)
        g = jnp.einsum("scd,scdf->scf", xf, g_sel)
        u = jnp.einsum("scd,scdf->scf", xf, u_sel)
        y = jnp.einsum("scf,scfd->scd", jax.nn.silu(g) * u, d_sel)
        y = jnp.where((er >= 0)[..., None], y, 0.0).astype(xs.dtype)
        # return trip: row j goes back to source shard j
        yr = lax.all_to_all(y, "expert", split_axis=0, concat_axis=0,
                            tiled=True)
        return yr[None]

    return shard_map(
        body, mesh=mesh,
        in_specs=(P("expert"), P("expert"), P("expert"),
                  P("expert"), P("expert")),
        out_specs=P("expert"))(wg, wu, wd, x_send, eid_send)


def act_sharding(cfg: ModelConfig, shape_name: str, mesh):
    """Between-layer activation constraint (B, T, D): batch on data,
    sequence on model (Megatron-style sequence parallelism)."""
    shp = INPUT_SHAPES[shape_name]
    dp = data_axes(mesh)
    n_data = _axis_size(mesh, dp)
    bdim = dp if _fits(shp.global_batch, n_data) else None
    if shp.mode == "decode":
        return NamedSharding(mesh, P(bdim, None, None))
    n_model = _axis_size(mesh, "model")
    sdim = "model" if shp.seq_len % n_model == 0 else None
    return NamedSharding(mesh, P(bdim, sdim, None))
