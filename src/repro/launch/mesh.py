"""Production meshes. Defined as functions so importing this module never
touches jax device state (device count is locked at first jax init)."""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mini_mesh(*, multi_pod: bool = False):
    """Small mesh for CI-scale dry-run tests (8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_expert_mesh(num_shards: int):
    """1-D ``("expert",)`` mesh over the first ``num_shards`` devices —
    the expert-parallel dispatch mesh (launch/sharding.expert_dispatch_ffn).
    Built with an explicit device slice (not ``jax.make_mesh``) so a
    4-shard mesh works on an 8-device host: CI forces host devices via
    ``--xla_force_host_platform_device_count`` the way launch/dryrun.py
    does, and shard counts need not divide the device count."""
    devices = jax.devices()
    assert num_shards <= len(devices), (
        f"expert mesh needs {num_shards} devices, have {len(devices)}")
    return jax.sharding.Mesh(np.array(devices[:num_shards]), ("expert",))


def data_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# TPU v5e hardware constants (roofline targets)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # B/s per chip
ICI_BW = 50e9                   # B/s per link
