"""Distributed training launcher.

On real hardware this runs the pjit train loop on the production mesh; on
this CPU container it runs reduced configs on the host device (or the mini
host-device mesh via --mini-mesh, set XLA_FLAGS yourself for that).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch deepseek-v2-lite \
      --reduced --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.data import lm_batches, make_topic_corpus
from repro.launch import shardctx
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import act_sharding, shard_params
from repro.models import build_model
from repro.training import checkpoint as ckpt
from repro.training.optimizer import cosine_schedule, make_adamw


def train(arch: str, reduced: bool = True, steps: int = 100,
          batch_size: int = 8, seq_len: int = 128, lr: float = 3e-3,
          seed: int = 0, save: str | None = None, log=print,
          production_mesh: bool = False):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    log(f"arch={cfg.name} params={n_params/1e6:.1f}M layers={cfg.num_layers}")

    opt_init, opt_update = make_adamw(
        lr=lr, clip=1.0, schedule=cosine_schedule(1.0, warmup=20,
                                                  total=steps))
    opt_state = opt_init(params)
    corpus = make_topic_corpus(cfg.vocab_size, n_topics=8, seed=seed)

    def train_step(params, opt_state, batch):
        def lf(p):
            return model.loss_fn(p, batch)
        (loss, mets), grads = jax.value_and_grad(lf, has_aux=True)(params)
        params, opt_state, stats = opt_update(grads, opt_state, params)
        return params, opt_state, loss, mets, stats["grad_norm"]

    if production_mesh:
        mesh = make_production_mesh()
        p_shard = shard_params(cfg, jax.eval_shape(lambda: params), mesh)
        step_fn = jax.jit(train_step, in_shardings=(p_shard, None, None))
    else:
        step_fn = jax.jit(train_step)

    losses = []
    t0 = time.time()
    for i, tokens in enumerate(lm_batches(corpus, batch_size, seq_len,
                                          steps, seed=seed + 1)):
        batch = {"tokens": jnp.asarray(tokens[:, :seq_len])}
        if cfg.frontend == "vision":
            batch["patches"] = jnp.zeros(
                (batch_size, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
        if cfg.frontend == "audio":
            batch["frames"] = jnp.zeros(
                (batch_size, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
        params, opt_state, loss, mets, gnorm = step_fn(params, opt_state,
                                                       batch)
        losses.append(float(loss))
        if i % max(1, steps // 10) == 0:
            log(f"step {i:5d} loss={float(loss):.4f} "
                f"xent={float(mets['xent']):.4f} gnorm={float(gnorm):.2f} "
                f"({(time.time() - t0) / (i + 1):.2f}s/step)")
    if save:
        ckpt.save(save, params)
        log(f"saved params to {save}")
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--save", default=None)
    args = ap.parse_args()
    train(args.arch, args.reduced, args.steps, args.batch, args.seq, args.lr,
          save=args.save)


if __name__ == "__main__":
    main()
