"""Kernel execution-mode helpers shared by every Pallas wrapper.

Pallas kernels compile for TPU; everywhere else they run through the
interpreter (a jitted XLA program that walks the grid), which validates the
kernel body bit-for-bit but at interpreter speed. The helpers here centralise
that decision so callers can say "interpret=None -> do the right thing for
this backend" instead of hardcoding ``interpret=True``.
"""
from __future__ import annotations

from typing import Optional

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """None -> interpret everywhere except a real TPU backend; an explicit
    bool always wins (tests force ``interpret=True`` to pin the kernel body
    on CPU, benchmarks force ``False`` on TPU)."""
    if interpret is None:
        return not on_tpu()
    return bool(interpret)


def default_kernel_backend() -> str:
    """Backend the serving hot path should compile to: the real Pallas
    kernel on TPU, the jnp flash twin (same blockwise online softmax, no
    interpreter overhead) elsewhere."""
    return "tpu" if on_tpu() else "jnp"
