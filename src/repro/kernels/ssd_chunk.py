"""Mamba-2 SSD within-chunk kernel: the quadratic (L x L) masked-decay
attention-like term, computed per (sequence-chunk, head) tile in VMEM.

y[l] = C[l] . sum_{s<=l} exp(a_cum[l] - a_cum[s]) * dt[s] * (B[s] x[s])

Grid: (B*Nc, H). Per step the (L, N) B/C tiles and the (L, P) x tile live in
VMEM; the (L, L) decay mask never leaves it. L=chunk (128) and P/N are
128-multiples at full scale, MXU-aligned.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _kernel(c_ref, b_ref, x_ref, acum_ref, o_ref):
    c = c_ref[0].astype(jnp.float32)          # (L, N)
    b = b_ref[0].astype(jnp.float32)          # (L, N)
    x = x_ref[0, 0].astype(jnp.float32)       # (L, P)  (already * dt)
    ac = acum_ref[0, 0].astype(jnp.float32)   # (L,)

    l = c.shape[0]
    seg = ac[:, None] - ac[None, :]           # a_cum[l] - a_cum[s]
    iota_l = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    iota_s = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    mask = iota_s <= iota_l
    decay = jnp.where(mask, jnp.exp(seg), 0.0)  # (L, L), lower-tri

    scores = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # (L, L)
    m = scores * decay
    o_ref[0, 0] = jnp.dot(m, x, preferred_element_type=jnp.float32) \
        .astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(c, b, xdt, a_cum, interpret: bool = True):
    """c, b: (G, L, N); xdt: (G, H, L, P); a_cum: (G, H, L) -> (G, H, L, P).

    G = batch*num_chunks flattened; B/C shared across heads (1 group).
    """
    g, l, n = c.shape
    _, h, _, p = xdt.shape

    out = pl.pallas_call(
        _kernel,
        grid=(g, h),
        in_specs=[
            pl.BlockSpec((1, l, n), lambda i, j: (i, 0, 0)),     # C
            pl.BlockSpec((1, l, n), lambda i, j: (i, 0, 0)),     # B
            pl.BlockSpec((1, 1, l, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, l), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, l, p), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, h, l, p), xdt.dtype),
        interpret=interpret,
    )(c, b, xdt, a_cum)
    return out


def ssd_chunk_ref(c, b, xdt, a_cum):
    """Pure-jnp oracle (mirrors models/ssd.py's y_diag einsum)."""
    cf = c.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    xf = xdt.astype(jnp.float32)
    ac = a_cum.astype(jnp.float32)
    l = cf.shape[1]
    seg = ac[..., :, None] - ac[..., None, :]            # (G,H,L,L)
    mask = jnp.tril(jnp.ones((l, l), bool))
    decay = jnp.where(mask, jnp.exp(seg), 0.0)
    scores = jnp.einsum("gln,gsn->gls", cf, bf)          # (G,L,L)
    m = scores[:, None] * decay                          # (G,H,L,L)
    return jnp.einsum("ghls,ghsp->ghlp", m, xf).astype(xdt.dtype)
