"""Cached-expert SwiGLU FFN kernel — the batch-1 decode compute the paper's
prefetcher keeps fed.

Grid: (k experts, F/BF ffn blocks). Each step loads one expert's
(D, BF)+(D, BF)+(BF, D) weight tiles from the slot buffer into VMEM, runs
the gated matmuls on the MXU (D and BF are 128-multiples by construction),
and accumulates ``weights[k] *`` partial output into the (1, D) out tile.
The x vector stays resident in VMEM across all grid steps.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, wg_ref, wu_ref, wd_ref, o_ref):
    ke = pl.program_id(0)
    fb = pl.program_id(1)

    @pl.when((ke == 0) & (fb == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                   # (1, D)
    wg = wg_ref[0].astype(jnp.float32)                   # (D, BF)
    wu = wu_ref[0].astype(jnp.float32)
    wd = wd_ref[0].astype(jnp.float32)                   # (BF, D)
    g = jnp.dot(x, wg, preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu, preferred_element_type=jnp.float32)
    h = (g * jax.nn.sigmoid(g)) * u                      # silu(g) * u
    y = jnp.dot(h, wd, preferred_element_type=jnp.float32)
    o_ref[...] += w_ref[0, ke] * y


@partial(jax.jit, static_argnames=("block_f", "interpret"))
def expert_ffn(x, weights, wg, wu, wd, block_f: int = 512,
               interpret: bool = True):
    """x: (D,); weights: (k,); wg/wu: (k,D,F); wd: (k,F,D) -> (D,)."""
    k, d, f = wg.shape
    bf = min(block_f, f)
    while f % bf:                     # largest divisor of f <= block_f
        bf -= 1

    out = pl.pallas_call(
        _kernel,
        grid=(k, f // bf),
        in_specs=[
            pl.BlockSpec((1, d), lambda ke, fb: (0, 0)),        # x
            pl.BlockSpec((1, k), lambda ke, fb: (0, 0)),        # weights
            pl.BlockSpec((1, d, bf), lambda ke, fb: (ke, 0, fb)),
            pl.BlockSpec((1, d, bf), lambda ke, fb: (ke, 0, fb)),
            pl.BlockSpec((1, bf, d), lambda ke, fb: (ke, fb, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda ke, fb: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(x[None, :], weights[None, :].astype(jnp.float32), wg, wu, wd)
    return out[0].astype(x.dtype)
