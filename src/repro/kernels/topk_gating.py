"""Fused MoE router kernel: softmax over experts + iterative top-k select +
renormalise, one VMEM pass per token block.

TPU adaptation: the hot loop of every MoE layer is the router — on GPU this
is a cuBLAS matmul + thrust sort; on TPU we fuse the softmax and the k
argmax passes so the (T, E) logits tile never leaves VMEM. Token blocks are
MXU/VPU-aligned (multiples of 8x128 lanes); k is a static unrolled loop.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _kernel(logits_ref, w_ref, idx_ref, *, k: int, n_experts: int):
    x = logits_ref[...].astype(jnp.float32)             # (BT, Epad)
    e_iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = e_iota < n_experts
    x = jnp.where(valid, x, NEG)

    # stable softmax over the expert axis
    m = jnp.max(x, axis=-1, keepdims=True)
    ex = jnp.exp(x - m)
    ex = jnp.where(valid, ex, 0.0)
    probs = ex / jnp.sum(ex, axis=-1, keepdims=True)

    # iterative top-k (k static, unrolled): argmax -> record -> mask
    remaining = probs
    ws = []
    ids = []
    for _ in range(k):
        best = jnp.max(remaining, axis=-1)              # (BT,)
        is_best = remaining == best[:, None]
        # first-match index via iota trick (TPU-safe, no argmax over lanes)
        bid = jnp.min(jnp.where(is_best, e_iota, n_experts), axis=-1)
        ws.append(best)
        ids.append(bid.astype(jnp.int32))
        remaining = jnp.where(e_iota == bid[:, None], 0.0, remaining)

    w = jnp.stack(ws, axis=-1)                          # (BT, k)
    w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)
    w_ref[...] = w
    idx_ref[...] = jnp.stack(ids, axis=-1)


@partial(jax.jit, static_argnames=("k", "block_t", "interpret"))
def topk_gating(logits: jnp.ndarray, k: int, block_t: int = 256,
                interpret: bool = True):
    """logits: (T, E) -> (weights (T, k) f32, idx (T, k) i32)."""
    t, e = logits.shape
    bt = min(block_t, t)
    pad_t = (-t) % bt
    e_pad = (-e) % 128                                  # lane alignment
    x = jnp.pad(logits, ((0, pad_t), (0, e_pad)), constant_values=NEG)
    tp, ep = x.shape

    w, idx = pl.pallas_call(
        partial(_kernel, k=k, n_experts=e),
        grid=(tp // bt,),
        in_specs=[pl.BlockSpec((bt, ep), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((bt, k), lambda i: (i, 0)),
                   pl.BlockSpec((bt, k), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((tp, k), jnp.float32),
                   jax.ShapeDtypeStruct((tp, k), jnp.int32)),
        interpret=interpret,
    )(x)
    return w[:t], idx[:t]
