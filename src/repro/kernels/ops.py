"""Jit'd public wrappers for the Pallas kernels with backend selection.

backend:
  "jnp"     — the pure-jnp oracle (used on CPU / for the dry-run lowering)
  "pallas"  — Pallas in interpret mode (CPU-validated kernel body)
  "tpu"     — Pallas compiled for TPU (the deployment target)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.expert_ffn import expert_ffn as _expert_ffn_pallas
from repro.kernels.flash_attention import flash_decode as _flash_pallas
from repro.kernels.topk_gating import topk_gating as _topk_pallas


def topk_gating(logits: jnp.ndarray, k: int, backend: str = "jnp"):
    if backend == "jnp":
        return ref.topk_gating_ref(logits, k)
    return _topk_pallas(logits, k, interpret=(backend != "tpu"))


def expert_ffn(x, weights, wg, wu, wd, backend: str = "jnp"):
    if backend == "jnp":
        return ref.expert_ffn_ref(x, weights, wg, wu, wd)
    return _expert_ffn_pallas(x, weights, wg, wu, wd,
                              interpret=(backend != "tpu"))


def flash_decode(q, k, v, valid_len, backend: str = "jnp"):
    if backend == "jnp":
        return ref.flash_decode_ref(q, k, v, valid_len)
    return _flash_pallas(q, k, v, valid_len, interpret=(backend != "tpu"))


def paged_flash_decode(q, k_pool, v_pool, tables, pos, scale=None, dv=None,
                       backend: str = "jnp"):
    """Paged flash-decode over a block-table KV pool.

    q: (N, KVH, G, dk); pools: (num_blocks, BS, KVH, *); tables: (N, W);
    pos: (N,). ``v_pool=None`` is the shared-page (MLA latent) layout —
    V slices out of the K fetch, one page read. backend "jnp" runs the
    lax.scan flash twin (the off-TPU serving route — same online-softmax
    recurrence, no interpreter overhead); "pallas" runs the kernel body in
    interpret mode (the CI validation route); "tpu" compiles it.
    """
    from repro.kernels import paged_attention as pa
    if backend not in ("jnp", "pallas", "tpu"):
        raise ValueError(f"unknown paged_flash_decode backend {backend!r}")
    if backend == "jnp":
        return pa.paged_flash_decode_jnp(q, k_pool, v_pool, tables, pos,
                                         scale=scale, dv=dv)
    return pa.paged_flash_decode_pallas(q, k_pool, v_pool, tables, pos,
                                        scale=scale, dv=dv,
                                        interpret=(backend != "tpu"))


def ssd_chunk(c, b, xdt, a_cum, backend: str = "jnp"):
    from repro.kernels.ssd_chunk import ssd_chunk as _p, ssd_chunk_ref as _r
    if backend == "jnp":
        return _r(c, b, xdt, a_cum)
    return _p(c, b, xdt, a_cum, interpret=(backend != "tpu"))
