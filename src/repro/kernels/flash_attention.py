"""Flash-decode kernel: one query token against a long KV cache with online
softmax over KV blocks (the long_500k serving hot spot).

Grid: (KVH kv-heads, S/BS kv blocks). Running (max, sum, acc) live in VMEM
scratch; each step rescales the accumulator — the (S,) score row is never
materialised in HBM. Positions >= valid_len are masked (decode against a
partially-filled cache).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

NEG = -1e30


def _kernel(vlen_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_s: int, scale: float):
    sb = pl.program_id(1)

    @pl.when(sb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (G, hd)
    k = k_ref[...][:, 0].astype(jnp.float32)             # (BS, hd)
    v = v_ref[...][:, 0].astype(jnp.float32)             # (BS, hd)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G,BS)
    kpos = sb * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < vlen_ref[0], s, NEG)

    m_prev = m_ref[...]                                  # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    p = jnp.exp(s - m_new)                               # (G, BS)
    alpha = jnp.exp(m_prev - m_new)                      # (G, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(sb == pl.num_programs(1) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode(q, k, v, valid_len, block_s: int = 512,
                 interpret: Optional[bool] = None):
    """q: (H, hd); k/v: (S, KVH, hd); valid_len: i32 -> (H, hd).

    ``interpret=None`` auto-resolves via the backend (compiled on TPU,
    interpreted elsewhere); pass an explicit bool to override.
    """
    s, kvh, hd = k.shape
    h = q.shape[0]
    g = h // kvh
    bs = min(block_s, s)
    pad_s = (-s) % bs
    if pad_s:
        k = jnp.pad(k, ((0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, pad_s), (0, 0), (0, 0)))
    sp = k.shape[0]
    qg = q.reshape(kvh, g, hd)
    vlen = jnp.full((1,), valid_len, jnp.int32)

    out = pl.pallas_call(
        partial(_kernel, block_s=bs, scale=hd ** -0.5),
        grid=(kvh, sp // bs),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # valid_len scalar
            pl.BlockSpec((1, g, hd), lambda n, sb: (n, 0, 0)),
            pl.BlockSpec((bs, 1, hd), lambda n, sb: (sb, n, 0)),
            pl.BlockSpec((bs, 1, hd), lambda n, sb: (sb, n, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda n, sb: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((kvh, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),             # running max
            pltpu.VMEM((g, 1), jnp.float32),             # running sum
            pltpu.VMEM((g, hd), jnp.float32),            # output accumulator
        ],
        interpret=resolve_interpret(interpret),
    )(vlen, qg, k, v)
    return out.reshape(h, hd)
