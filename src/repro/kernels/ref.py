"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_gating_ref(logits: jnp.ndarray, k: int):
    """logits: (T, E) -> (weights (T,k) f32, idx (T,k) i32).

    Softmax over all experts, take top-k, renormalise (DeepSeek-V2 router).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / (jnp.sum(w, -1, keepdims=True) + 1e-9)
    return w, idx.astype(jnp.int32)


def expert_ffn_ref(x: jnp.ndarray, weights: jnp.ndarray, wg: jnp.ndarray,
                   wu: jnp.ndarray, wd: jnp.ndarray):
    """Batch-1 cached-expert SwiGLU FFN.

    x: (D,); weights: (k,); wg/wu: (k, D, F); wd: (k, F, D) -> (D,).
    y = sum_k weights[k] * (silu(x @ wg_k) * (x @ wu_k)) @ wd_k
    """
    xf = x.astype(jnp.float32)
    g = jnp.einsum("d,kdf->kf", xf, wg.astype(jnp.float32))
    u = jnp.einsum("d,kdf->kf", xf, wu.astype(jnp.float32))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("kf,kfd->kd", h, wd.astype(jnp.float32))
    return jnp.einsum("k,kd->d", weights.astype(jnp.float32), y).astype(x.dtype)


def paged_flash_decode_ref(q, k_pool, v_pool, tables, pos, scale=None,
                           dv=None):
    """Dense oracle for the paged flash-decode kernel: gather-and-
    materialise every lane's pages, then one softmax over the whole row.

    q: (N, KVH, G, dk); k_pool/v_pool: (num_blocks, BS, KVH, *) —
    ``v_pool=None`` is the shared-page (MLA latent) layout, V = the first
    ``dv`` features of K; tables: (N, W) int32 block tables; pos: (N,)
    int32 — key positions ``> pos[lane]`` are masked.
    Returns (N, KVH, G, dv).
    """
    n, kvh, g, dk = q.shape
    bs = k_pool.shape[1]
    w = tables.shape[1]
    dvp = k_pool.shape[-1] if v_pool is None else v_pool.shape[-1]
    dv = dvp if dv is None else dv
    scale = dk ** -0.5 if scale is None else scale
    k = jnp.take(k_pool, tables.reshape(-1), axis=0).reshape(
        n, w * bs, kvh, dk)
    if v_pool is None:
        v = k[..., :dv]
    else:
        v = jnp.take(v_pool, tables.reshape(-1), axis=0).reshape(
            n, w * bs, kvh, dvp)[..., :dv]
    scores = jnp.einsum("njgd,nsjd->njgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    valid = jnp.arange(w * bs)[None, :] <= pos[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("njgs,nsjd->njgd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     valid_len: jnp.ndarray | int):
    """Single-token decode attention against a KV cache.

    q: (H, hd); k/v: (S, KVH, hd); positions >= valid_len are masked.
    GQA: H = KVH * G. Returns (H, hd).
    """
    s, kvh, hd = k.shape
    h = q.shape[0]
    g = h // kvh
    qg = q.reshape(kvh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("ngd,snd->ngs", qg, k.astype(jnp.float32))
    scores = scores * (hd ** -0.5)
    mask = jnp.arange(s) < valid_len
    scores = jnp.where(mask[None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("ngs,snd->ngd", probs, v.astype(jnp.float32))
    return out.reshape(h, hd).astype(q.dtype)
