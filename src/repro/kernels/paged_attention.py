"""Paged flash-decode: block-table attention reading KV pages in place.

One query token per *lane* against a block-paged KV pool — the read half of
the paged serving engine. The grid is ``(lanes, kv_heads, table_width)``:
for each (lane, head) the innermost axis walks the lane's block table in
logical order, and a ``PrefetchScalarGridSpec`` index map turns the table
entry into the pool block to fetch, so K/V pages stream from the
``(num_blocks, block_size, KVH, hd)`` pool directly — the
``(N, W*block_size, ...)`` contiguous copy of the gather path is never
materialised. Online softmax (running max / sum / accumulator in VMEM
scratch, rescaled per block) makes the walk single-pass; positions
``>= pos+1`` are masked, which also neutralises the scratch block 0 and any
unreferenced pool block a scratch-padded table names (their logical
positions always exceed ``pos``).

Two layouts share the one kernel:
  * GQA:  q ``(N, KVH, G, hd)`` against separate K and V pools.
  * MLA:  the absorbed decode is a single-"kv-head" attend where K is the
    whole ``(c, r)`` latent page and V is its first ``kv_lora_rank``
    features — pass ``v_pool=None`` with ``dv=rank`` and the kernel slices
    V out of the fetched K tile (one DMA per page, no second fetch, no
    concat).

``paged_flash_decode_jnp`` is the lax.scan twin of the kernel — identical
blockwise online-softmax recurrence, gathering at most ``tile_blocks``
table entries per step so off-TPU serving doesn't pay interpreter overhead.
Its live tile is the whole ``(N, W*BS, ...)`` copy whenever the table is
narrower than one tile (short/medium contexts — unavoidable without a real
kernel); past that the copy stays capped at ``tile_blocks`` blocks while
the gather route's keeps growing. ``kernels/ref.py::paged_flash_decode_ref``
is the dense oracle both are pinned against.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

NEG = -1e30

# table entries walked per jnp-twin scan step: big enough that the einsum
# dominates the loop overhead, small enough that the live KV tile stays
# O(tile * block_size) positions instead of the full sequence
JNP_TILE_BLOCKS = 128


def _online_step(pos_ref, q_ref, o_ref, m_ref, l_ref, acc_ref, k, v, *,
                 block_size: int, scale: float):
    """Shared online-softmax body: one (lane, kv-head, block) grid step.
    k: (BS, dk), v: (BS, dv) — already loaded by the caller."""
    lane = pl.program_id(0)
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, dk)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G,BS)
    kpos = w * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos <= pos_ref[lane], s, NEG)

    m_prev = m_ref[...]                              # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    p = jnp.exp(s - m_new)                           # (G, BS)
    alpha = jnp.exp(m_prev - m_new)                  # (G, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(w == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def _kernel(tables_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, block_size: int, scale: float,
            dv: int):
    k = k_ref[0][:, 0].astype(jnp.float32)           # (BS, dk)
    v = v_ref[0][:, 0, :dv].astype(jnp.float32)      # (BS, dv)
    _online_step(pos_ref, q_ref, o_ref, m_ref, l_ref, acc_ref, k, v,
                 block_size=block_size, scale=scale)


def _kernel_shared(tables_ref, pos_ref, q_ref, k_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, block_size: int, scale: float,
                   dv: int):
    # MLA latent layout: V is the leading ``dv`` features of the K tile —
    # one page fetch feeds both dots
    k = k_ref[0][:, 0].astype(jnp.float32)           # (BS, dk)
    _online_step(pos_ref, q_ref, o_ref, m_ref, l_ref, acc_ref, k,
                 k[:, :dv], block_size=block_size, scale=scale)


@partial(jax.jit, static_argnames=("scale", "dv", "interpret"))
def paged_flash_decode_pallas(q, k_pool, v_pool, tables, pos,
                              scale: Optional[float] = None,
                              dv: Optional[int] = None,
                              interpret: Optional[bool] = None):
    """q: (N, KVH, G, dk); k_pool/v_pool: (num_blocks, BS, KVH, *);
    tables: (N, W) int32; pos: (N,) int32 -> (N, KVH, G, dv).

    ``v_pool=None`` is the shared-page layout (MLA latents): V is sliced
    out of the fetched K tile, one DMA per page. ``dv`` selects the leading
    value features of the V tile (``kv_lora_rank`` for MLA); ``scale``
    overrides the ``dk**-0.5`` score scale (MLA scales by the materialised
    head dim, not the latent dim). ``interpret=None`` auto-resolves:
    compiled on TPU, interpreted elsewhere.
    """
    n, kvh, g, dk = q.shape
    bs = k_pool.shape[1]
    w = tables.shape[1]
    dvp = k_pool.shape[-1] if v_pool is None else v_pool.shape[-1]
    dv = dvp if dv is None else dv
    scale = dk ** -0.5 if scale is None else scale

    in_specs = [
        pl.BlockSpec((1, 1, g, dk), lambda i, j, k, t, p: (i, j, 0, 0)),
        pl.BlockSpec((1, bs, 1, dk),
                     lambda i, j, k, t, p: (t[i, k], 0, j, 0)),
    ]
    operands = [q, k_pool]
    if v_pool is None:
        body = _kernel_shared
    else:
        body = _kernel
        in_specs.append(pl.BlockSpec((1, bs, 1, dvp),
                                     lambda i, j, k, t, p: (t[i, k], 0, j,
                                                            0)))
        operands.append(v_pool)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                        # tables, pos
        grid=(n, kvh, w),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, dv),
                               lambda i, j, k, t, p: (i, j, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),          # running max
            pltpu.VMEM((g, 1), jnp.float32),          # running sum
            pltpu.VMEM((g, dv), jnp.float32),         # output accumulator
        ],
    )
    return pl.pallas_call(
        partial(body, block_size=bs, scale=scale, dv=dv),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, kvh, g, dv), q.dtype),
        interpret=resolve_interpret(interpret),
    )(tables.astype(jnp.int32), pos.astype(jnp.int32), *operands)


@partial(jax.jit, static_argnames=("scale", "dv", "tile_blocks"))
def paged_flash_decode_jnp(q, k_pool, v_pool, tables, pos,
                           scale: Optional[float] = None,
                           dv: Optional[int] = None,
                           tile_blocks: int = JNP_TILE_BLOCKS):
    """lax.scan twin of the Pallas kernel (same shapes, same recurrence).

    Each scan step gathers at most ``tile_blocks`` table entries per lane
    and applies the identical online-softmax update the kernel applies per
    block, so masked positions (pads, scratch, unreferenced blocks)
    contribute exactly zero in both. The live tile IS the full
    ``(N, W*BS, ...)`` copy while the table fits one tile; past
    ``tile_blocks`` blocks it stays capped while the gather route's copy
    keeps growing. ``v_pool=None`` is the shared-page (MLA latent) layout:
    V slices out of the gathered K tile, halving the gather traffic.
    """
    n, kvh, g, dk = q.shape
    bs = k_pool.shape[1]
    dvp = k_pool.shape[-1] if v_pool is None else v_pool.shape[-1]
    dv = dvp if dv is None else dv
    scale = dk ** -0.5 if scale is None else scale

    w = tables.shape[1]
    tile = min(tile_blocks, w)
    padw = (-w) % tile
    if padw:                                          # scratch-pad: masked
        tables = jnp.pad(tables, ((0, 0), (0, padw)))
    tiled = tables.reshape(n, -1, tile)               # (N, WT, tile)
    qf = q.astype(jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        wi, bids = xs                                 # bids: (N, tile)
        k = jnp.take(k_pool, bids.reshape(-1), axis=0).reshape(
            n, tile * bs, kvh, dk)
        if v_pool is None:
            v = k[..., :dv]
        else:
            v = jnp.take(v_pool, bids.reshape(-1), axis=0).reshape(
                n, tile * bs, kvh, dvp)[..., :dv]
        s = jnp.einsum("njgd,nsjd->njgs", qf, k.astype(jnp.float32)) * scale
        kpos = wi * tile * bs + jnp.arange(tile * bs)
        s = jnp.where(kpos[None, None, None, :] <= pos[:, None, None, None],
                      s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, -1, keepdims=True)
        acc = acc * alpha + jnp.einsum("njgs,nsjd->njgd", p,
                                       v.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((n, kvh, g, 1), NEG, jnp.float32)
    l0 = jnp.zeros((n, kvh, g, 1), jnp.float32)
    a0 = jnp.zeros((n, kvh, g, dv), jnp.float32)
    (_, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.arange(tiled.shape[1]), jnp.moveaxis(tiled, 1, 0)))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
