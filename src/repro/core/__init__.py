# The paper's primary contribution: learned expert-activation prediction
# and cache-prefetch for MoE decoding (tracing -> predictor -> simulator).
from repro.core.cache import CacheStats, ExpertCache  # noqa: F401
from repro.core.eam import EAMC, REAMBuilder, build_ream, kmeans  # noqa: F401
from repro.core.predictor import (  # noqa: F401
    bce_loss, predictor_apply, predictor_init, predictor_lr_fn)
from repro.core.simulator import (  # noqa: F401
    SimConfig, SimResult, simulate, sweep_capacity)
from repro.core.tracing import (  # noqa: F401
    Trace, collect_trace, collect_traces, load_traces, moe_layer_ids,
    save_traces)
