"""Evaluation metrics (paper §3.2.4): position-wise accuracy (both readings)
and macro F1 over experts."""
from __future__ import annotations

import numpy as np


def select_experts(logits: np.ndarray, top_k: int, threshold: float = 0.5):
    """Paper's rule: top-k by sigmoid prob, kept only if prob > threshold.
    logits: (..., E) -> bool (..., E)."""
    probs = 1.0 / (1.0 + np.exp(-logits.astype(np.float64)))
    e = probs.shape[-1]
    k = min(top_k, e)
    kth = np.partition(probs, e - k, axis=-1)[..., e - k: e - k + 1]
    in_topk = probs >= kth
    return in_topk & (probs > threshold)


def elementwise_accuracy(pred: np.ndarray, true: np.ndarray,
                         mask: np.ndarray | None = None) -> float:
    """Per-(position, expert) binary accuracy — the reading under which the
    paper's 97.5% (with 6:58 imbalance) is reproducible."""
    eq = (pred.astype(bool) == true.astype(bool))
    if mask is not None:
        return float(eq[mask.astype(bool)].mean())
    return float(eq.mean())


def exact_set_accuracy(pred: np.ndarray, true: np.ndarray,
                       mask: np.ndarray | None = None) -> float:
    """Fraction of positions whose predicted expert set matches exactly."""
    match = np.all(pred.astype(bool) == true.astype(bool), axis=-1)
    if mask is not None:
        return float(match[mask.astype(bool)].mean())
    return float(match.mean())


def macro_f1(pred: np.ndarray, true: np.ndarray,
             mask: np.ndarray | None = None) -> float:
    """Mean per-expert F1 (expert = one binary classification problem)."""
    p = pred.reshape(-1, pred.shape[-1]).astype(bool)
    t = true.reshape(-1, true.shape[-1]).astype(bool)
    if mask is not None:
        keep = mask.reshape(-1).astype(bool)
        p, t = p[keep], t[keep]
    tp = np.sum(p & t, axis=0).astype(np.float64)
    fp = np.sum(p & ~t, axis=0).astype(np.float64)
    fn = np.sum(~p & t, axis=0).astype(np.float64)
    f1 = 2 * tp / np.maximum(2 * tp + fp + fn, 1e-9)
    # experts never active AND never predicted contribute f1=0 in strict
    # macro; follow sklearn's zero_division=0 convention
    return float(f1.mean())


def prediction_hit_rate(pred_sets, true_sets) -> float:
    """Fraction of ground-truth activations present in the predicted set."""
    hits = total = 0
    for p, t in zip(pred_sets, true_sets):
        ps = set(p)
        hits += sum(1 for e in t if e in ps)
        total += len(t)
    return hits / max(total, 1)
