"""Evaluation metrics: predictor quality (paper §3.2.4) and serving-side
latency/SLO accounting.

The first half scores expert-activation predictors — position-wise accuracy
(both readings) and macro F1 over experts. The second half is the serving
harness's measurement vocabulary: per-request latency records
(:class:`RequestLatency`), percentile summaries, and goodput-under-SLO
(:class:`LatencyStats`), consumed by ``serving/scheduler.py`` and reported
by ``benchmarks/engine_bench.py --slo``."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional

import numpy as np


def select_experts(logits: np.ndarray, top_k: int, threshold: float = 0.5):
    """Paper's rule: top-k by sigmoid prob, kept only if prob > threshold.
    logits: (..., E) -> bool (..., E)."""
    probs = 1.0 / (1.0 + np.exp(-logits.astype(np.float64)))
    e = probs.shape[-1]
    k = min(top_k, e)
    kth = np.partition(probs, e - k, axis=-1)[..., e - k: e - k + 1]
    in_topk = probs >= kth
    return in_topk & (probs > threshold)


def elementwise_accuracy(pred: np.ndarray, true: np.ndarray,
                         mask: np.ndarray | None = None) -> float:
    """Per-(position, expert) binary accuracy — the reading under which the
    paper's 97.5% (with 6:58 imbalance) is reproducible."""
    eq = (pred.astype(bool) == true.astype(bool))
    if mask is not None:
        return float(eq[mask.astype(bool)].mean())
    return float(eq.mean())


def exact_set_accuracy(pred: np.ndarray, true: np.ndarray,
                       mask: np.ndarray | None = None) -> float:
    """Fraction of positions whose predicted expert set matches exactly."""
    match = np.all(pred.astype(bool) == true.astype(bool), axis=-1)
    if mask is not None:
        return float(match[mask.astype(bool)].mean())
    return float(match.mean())


def macro_f1(pred: np.ndarray, true: np.ndarray,
             mask: np.ndarray | None = None) -> float:
    """Mean per-expert F1 (expert = one binary classification problem)."""
    p = pred.reshape(-1, pred.shape[-1]).astype(bool)
    t = true.reshape(-1, true.shape[-1]).astype(bool)
    if mask is not None:
        keep = mask.reshape(-1).astype(bool)
        p, t = p[keep], t[keep]
    tp = np.sum(p & t, axis=0).astype(np.float64)
    fp = np.sum(p & ~t, axis=0).astype(np.float64)
    fn = np.sum(~p & t, axis=0).astype(np.float64)
    f1 = 2 * tp / np.maximum(2 * tp + fp + fn, 1e-9)
    # experts never active AND never predicted contribute f1=0 in strict
    # macro; follow sklearn's zero_division=0 convention
    return float(f1.mean())


def prediction_hit_rate(pred_sets, true_sets) -> float:
    """Fraction of ground-truth activations present in the predicted set."""
    hits = total = 0
    for p, t in zip(pred_sets, true_sets):
        ps = set(p)
        hits += sum(1 for e in t if e in ps)
        total += len(t)
    return hits / max(total, 1)


def prf_from_counts(tp: float, fp: float, fn: float):
    """(precision, recall, micro-F1) from summed confusion counts — the
    single formula shared by :func:`f1_over_window` and the telemetry
    scoreboard, so per-window rows aggregate exactly to run totals
    (micro-F1 composes over count sums; averaged F1 values do not).
    Empty denominators follow the zero_division=0 convention."""
    precision = tp / max(tp + fp, 1)
    recall = tp / max(tp + fn, 1)
    f1 = 2 * tp / max(2 * tp + fp + fn, 1)
    return precision, recall, f1


@dataclass
class WindowF1:
    """Micro-averaged predictor quality over one scoring window.

    ``tp``/``fp``/``fn`` are confusion counts summed over the window's
    (predicted set, routed set) pairs; ``precision``/``recall``/``f1``
    derive from them via :func:`prf_from_counts`. Adding two windows'
    counts and re-deriving gives the exact combined-window figures."""
    tp: int = 0
    fp: int = 0
    fn: int = 0

    @property
    def precision(self) -> float:
        return prf_from_counts(self.tp, self.fp, self.fn)[0]

    @property
    def recall(self) -> float:
        return prf_from_counts(self.tp, self.fp, self.fn)[1]

    @property
    def f1(self) -> float:
        return prf_from_counts(self.tp, self.fp, self.fn)[2]


def f1_over_window(predicted, actual) -> WindowF1:
    """Micro P/R/F1 of paired expert-id sets over a window.

    ``predicted``/``actual`` are parallel iterables of id collections
    (one pair per MoE-layer visit). Consistency with the paper-era batch
    helpers, pinned by tests: ``recall == prediction_hit_rate(predicted,
    actual)``, ``precision == prediction_hit_rate(actual, predicted)``,
    and ``f1`` equals the micro-F1 of the equivalent binary arrays."""
    w = WindowF1()
    for p, t in zip(predicted, actual):
        ps, ts = set(int(e) for e in p), set(int(e) for e in t)
        w.tp += len(ps & ts)
        w.fp += len(ps - ts)
        w.fn += len(ts - ps)
    return w


# ---------------------------------------------------------------------------
# Serving-side latency / SLO metrics
# ---------------------------------------------------------------------------

def percentile(xs: Iterable[float], q: float) -> float:
    """Linear-interpolated percentile of ``xs`` (q in [0, 100]); 0.0 for an
    empty sample so JSON reports stay finite."""
    xs = list(xs)
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs, np.float64), q))


@dataclass
class RequestLatency:
    """One request's wall-clock milestones, recorded by the scheduler.

    All ``*_s`` fields are ``time.perf_counter()`` seconds on the serving
    host's clock; ``-1.0`` means "never happened".

      * ``rid`` — the engine-assigned request id.
      * ``priority`` — the request's priority class (lower = more urgent).
      * ``arrival_s`` — when the request became visible to the scheduler
        (its workload arrival offset under ``run_workload``, submit time
        under the closed loop), so TTFT includes queueing delay.
      * ``first_token_s`` — when the first *sampled* token landed.
      * ``finish_s`` — when the request retired (or was rejected).
      * ``tokens_out`` — sampled tokens returned.
      * ``preemptions`` — times this request was evicted and re-admitted.
      * ``rejected`` — refused at admission (worst case exceeds the pool);
        a rejected request can never meet an SLO.
      * ``slo_ttft_s`` / ``slo_per_token_s`` — the request's latency
        budgets (None = unconstrained on that axis).
    """
    rid: int
    priority: int = 0
    arrival_s: float = 0.0
    first_token_s: float = -1.0
    finish_s: float = -1.0
    tokens_out: int = 0
    preemptions: int = 0
    rejected: bool = False
    slo_ttft_s: Optional[float] = None
    slo_per_token_s: Optional[float] = None

    @property
    def ttft_s(self) -> Optional[float]:
        """Arrival-to-first-sampled-token seconds (None if no token)."""
        if self.first_token_s < 0:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def tpot_s(self) -> Optional[float]:
        """Mean time-per-output-token over the decode tail (None until two
        sampled tokens exist to measure an interval between)."""
        if self.tokens_out < 2 or self.first_token_s < 0 or self.finish_s < 0:
            return None
        return (self.finish_s - self.first_token_s) / (self.tokens_out - 1)

    @property
    def has_slo(self) -> bool:
        return self.slo_ttft_s is not None or self.slo_per_token_s is not None

    @property
    def slo_met(self) -> bool:
        """True when the request completed inside every budget it declared
        (requests with no SLO trivially meet it once they complete)."""
        if self.rejected:
            return False
        if self.slo_ttft_s is not None:
            if self.ttft_s is None or self.ttft_s > self.slo_ttft_s:
                return False
        if self.slo_per_token_s is not None:
            tpot = self.tpot_s
            if tpot is not None and tpot > self.slo_per_token_s:
                return False
        return True


@dataclass
class LatencyStats:
    """Aggregate latency/SLO summary of one serving run.

    All ``*_s`` fields are seconds; ``*_rps`` are requests per second of
    run wall-clock.

      * ``n`` — requests recorded (completed + rejected).
      * ``completed`` — requests that retired with a result.
      * ``rejected`` — requests refused at admission.
      * ``preemptions`` — total evict-and-resume events across requests.
      * ``ttft_p50_s``/``ttft_p95_s``/``ttft_p99_s`` — arrival-to-first-
        token percentiles over requests that produced a token.
      * ``tpot_p50_s``/``tpot_p95_s``/``tpot_p99_s`` — per-output-token
        latency percentiles over requests with >= 2 sampled tokens.
      * ``slo_requests`` — how many requests declared any SLO.
      * ``slo_met`` — how many completed inside all their budgets.
      * ``slo_attainment`` — ``slo_met / slo_requests`` (1.0 when nothing
        declared an SLO).
      * ``throughput_rps`` — completed requests / elapsed.
      * ``goodput_rps`` — SLO-meeting completed requests / elapsed: the
        headline "goodput under SLO" an open-loop sweep reports.
      * ``elapsed_s`` — run wall-clock the rates are normalised by.
    """
    n: int = 0
    completed: int = 0
    rejected: int = 0
    preemptions: int = 0
    ttft_p50_s: float = 0.0
    ttft_p95_s: float = 0.0
    ttft_p99_s: float = 0.0
    tpot_p50_s: float = 0.0
    tpot_p95_s: float = 0.0
    tpot_p99_s: float = 0.0
    slo_requests: int = 0
    slo_met: int = 0
    slo_attainment: float = 1.0
    throughput_rps: float = 0.0
    goodput_rps: float = 0.0
    elapsed_s: float = 0.0

    def as_dict(self) -> Dict[str, float]:
        """Plain-float dict (JSON-ready benchmark artifact rows)."""
        from dataclasses import asdict
        return {k: (float(v) if isinstance(v, float) else int(v))
                for k, v in asdict(self).items()}


def latency_stats(records: Iterable[RequestLatency],
                  elapsed_s: float) -> LatencyStats:
    """Summarise per-request records into a :class:`LatencyStats`.

    ``records`` may be any subset (e.g. one priority class) — the bench
    calls this per class as well as for the whole run."""
    recs: List[RequestLatency] = list(records)
    ttfts = [r.ttft_s for r in recs if r.ttft_s is not None]
    tpots = [r.tpot_s for r in recs if r.tpot_s is not None]
    completed = [r for r in recs if not r.rejected]
    with_slo = [r for r in recs if r.has_slo]
    met = [r for r in recs if r.has_slo and r.slo_met]
    good = [r for r in completed if r.slo_met]
    el = max(elapsed_s, 1e-9)
    return LatencyStats(
        n=len(recs),
        completed=len(completed),
        rejected=len(recs) - len(completed),
        preemptions=sum(r.preemptions for r in recs),
        ttft_p50_s=percentile(ttfts, 50),
        ttft_p95_s=percentile(ttfts, 95),
        ttft_p99_s=percentile(ttfts, 99),
        tpot_p50_s=percentile(tpots, 50),
        tpot_p95_s=percentile(tpots, 95),
        tpot_p99_s=percentile(tpots, 99),
        slo_requests=len(with_slo),
        slo_met=len(met),
        slo_attainment=(len(met) / len(with_slo)) if with_slo else 1.0,
        throughput_rps=len(completed) / el,
        goodput_rps=len(good) / el,
        elapsed_s=elapsed_s,
    )
