"""GPU-VRAM-as-expert-cache model (paper §2.3): fixed expert-slot capacity,
LRU, LFU or predictor-driven ("learned") eviction, explicit prefetch, full
hit/miss accounting.

Keys are (layer, expert) pairs. This object is the *simulator's* cache; the
device-resident jittable slot-buffer lives in serving/offload.py.

``policy="learned"`` turns the activation predictor into the replacement
policy (the paper's thesis applied to *eviction*, not just prefetch): a
:class:`~repro.core.policies.ReuseDistanceScorer` maps the multi-horizon
prediction window to a per-key predicted-next-use distance, and eviction
picks the unpinned key predicted furthest from reuse — a key no prediction
covers counts as infinitely far (the predictor does not foresee its use),
and LRU order breaks ties, so with no predictions at all the policy
degrades to exact LRU. Victim provenance (prediction-informed vs pure LRU
fallback) is counted in :class:`CacheStats`.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Hashable, Iterable, Optional


@dataclass
class CacheStats:
    """Hit/miss/eviction accounting for one :class:`ExpertCache`.

      * ``hits`` / ``misses`` — resident vs not at ``access`` time.
      * ``prefetches`` — prefetches that actually inserted an entry.
      * ``prefetch_hits`` — accesses served by a prefetched entry.
      * ``deep_prefetch_hits`` — accesses served by an entry prefetched
        more than one MoE layer ahead (horizon-aware deep prefetch).
      * ``redundant_prefetches`` — prefetches of an already-resident key
        (recency refresh only, no insert).
      * ``evictions`` — entries evicted to make room (all policies).
      * ``evictions_learned`` — learned-mode evictions where at least one
        candidate had a live reuse-distance prediction (the victim choice
        was prediction-informed).
      * ``evictions_lru`` — learned-mode evictions that fell back to pure
        LRU order because no candidate had a prediction.
      * ``demand_fetches`` — misses that triggered an on-demand insert.
    """
    hits: int = 0
    misses: int = 0
    prefetches: int = 0
    prefetch_hits: int = 0
    deep_prefetch_hits: int = 0
    redundant_prefetches: int = 0
    evictions: int = 0
    evictions_learned: int = 0
    evictions_lru: int = 0
    demand_fetches: int = 0

    @property
    def accesses(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.accesses, 1)

    def as_dict(self) -> dict:
        """Every counter as a JSON-ready dict (stats-registration lint)."""
        from dataclasses import asdict
        return asdict(self)


class ExpertCache:
    def __init__(self, capacity: int, policy: str = "lru", on_evict=None,
                 on_insert=None, scorer=None, telemetry=None):
        assert capacity >= 1
        assert policy in ("lru", "lfu", "learned")
        assert policy != "learned" or scorer is not None, \
            "policy='learned' needs a ReuseDistanceScorer"
        self.capacity = capacity
        self.policy = policy
        self.scorer = scorer
        # on_evict releases the device slot; with a tiered store behind the
        # slot buffer, the release *demotes* the expert into the store's
        # host-side cache — eviction is a move down the hierarchy, not a
        # drop (serving/expertstore.py)
        self.on_evict = on_evict      # callback(key) -> None (slot release)
        self.on_insert = on_insert    # callback(key) -> None (slot fill)
        # key -> provenance: None for a demand fetch, else the prefetch
        # lookahead distance in MoE layers (0 = next layer; >0 = the
        # horizon-aware deep prefetch of a slow-tier expert)
        self._entries: OrderedDict[Hashable, Optional[int]] = OrderedDict()
        self._freq: dict[Hashable, int] = {}
        self._pins: dict[Hashable, int] = {}   # key -> refcount
        self.stats = CacheStats()
        # optional serving.telemetry.Telemetry: evictions are reported
        # with the victim's provenance + which policy mode chose it (a
        # pure observer — None, the default, records nothing)
        self.tel = telemetry

    def __contains__(self, key) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def reset(self) -> None:
        self._entries.clear()
        self._freq.clear()
        self._pins.clear()
        self.stats = CacheStats()

    # --- pinning: an expert in use by any in-flight request is not evictable
    def pin(self, key) -> None:
        """Refcounted eviction guard; the key must be resident."""
        assert key in self._entries, f"pin of non-resident key {key!r}"
        self._pins[key] = self._pins.get(key, 0) + 1

    def unpin(self, key) -> None:
        n = self._pins.get(key, 0) - 1
        if n <= 0:
            self._pins.pop(key, None)
        else:
            self._pins[key] = n

    def pinned(self, key) -> bool:
        return self._pins.get(key, 0) > 0

    def _evict_one(self) -> None:
        evictable = [k for k in self._entries if not self.pinned(k)]
        if not evictable:
            raise RuntimeError(
                f"ExpertCache thrashing: all {len(self._entries)} resident "
                f"experts are pinned by in-flight requests; capacity "
                f"{self.capacity} is too small for the concurrent working set")
        if self.policy == "lru":
            victim = evictable[0]            # OrderedDict order == LRU order
            mode = "lru"
        elif self.policy == "lfu":           # LRU tie-break via dict order
            victim = min(evictable,
                         key=lambda k: (self._freq.get(k, 0),))
            mode = "lfu"
        else:
            informed = self.stats.evictions_learned
            victim = self._learned_victim(evictable)
            mode = ("learned" if self.stats.evictions_learned > informed
                    else "lru-fallback")
        provenance = self._entries[victim]
        del self._entries[victim]
        if self.on_evict is not None:
            self.on_evict(victim)
        self.stats.evictions += 1
        if self.tel is not None and self.tel.enabled:
            from repro.serving.telemetry import PID_ENGINE
            self.tel.counter("cache.evictions")
            self.tel.instant(
                PID_ENGINE, 1, "evict",
                {"key": str(victim), "mode": mode,
                 "provenance": ("demand" if provenance is None
                                else f"prefetch-d{provenance}")})

    def _learned_victim(self, evictable):
        """The unpinned key predicted furthest from reuse. A key with no
        live prediction counts as infinitely far — the predictor does not
        foresee its use within the horizon window, which makes it the best
        victim. Iteration order is LRU order and strict ``>`` keeps the
        earliest candidate on ties, so equal-distance (and the
        no-predictions-at-all) cases degrade to exact LRU."""
        victim, best = None, -1.0
        informed = False
        for k in evictable:
            d = self.scorer.distance(k)
            if d is None:
                d = float("inf")
            else:
                informed = True
            if d > best:
                victim, best = k, d
        if informed:
            self.stats.evictions_learned += 1
        else:
            self.stats.evictions_lru += 1
        return victim

    def _insert(self, key, provenance: Optional[int]) -> None:
        assert key not in self._entries
        while len(self._entries) >= self.capacity:
            self._evict_one()
        self._entries[key] = provenance
        if self.on_insert is not None:
            self.on_insert(key)

    def prefetch(self, keys: Iterable[Hashable], horizon: int = 0) -> None:
        """Insert predicted keys ahead of use. ``horizon`` is how many MoE
        layers early the prediction was made (0 = next layer); it is
        recorded as provenance so hit stats can attribute wins to the
        horizon-aware deep prefetch of slow-tier experts."""
        for key in keys:
            if key in self._entries:
                # re-prefetch of a resident key is a no-op hit: no insert,
                # no slot traffic, no provenance change — stats.prefetches
                # counts exactly the entries moved. The key's recency IS
                # refreshed (a prefetch declares intent-to-use, and must
                # protect the key from the rest of the same burst's
                # evictions — the oracle's 100% hit rate depends on it).
                self.stats.redundant_prefetches += 1
                self._entries.move_to_end(key)
                continue
            self.stats.prefetches += 1
            self._insert(key, provenance=horizon)

    def access(self, key) -> bool:
        """A compute-time expert use. Miss => demand fetch (inserted)."""
        self._freq[key] = self._freq.get(key, 0) + 1
        if key in self._entries:
            self.stats.hits += 1
            if self._entries[key] is not None:
                self.stats.prefetch_hits += 1
                if self._entries[key] > 0:
                    self.stats.deep_prefetch_hits += 1
            self._entries.move_to_end(key)
            return True
        self.stats.misses += 1
        self.stats.demand_fetches += 1
        self._insert(key, provenance=None)
        return False
