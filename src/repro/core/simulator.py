"""Trace-driven expert-cache simulator (paper §4.1.4).

Each test prompt is replayed token by token. The first ``warm_tokens`` only
warm the LRU expert cache; from then on the policy predicts the upcoming
layer's experts, which are prefetched before the ground truth is revealed.
A *prediction hit* = ground-truth expert was in the predicted set; a *cache
hit* = it was resident when the layer ran. Sweeping the cache capacity
reproduces paper Fig 7.

Beyond the paper: a latency model (per-miss stall = expert_bytes/host_bw)
turns hit rates into estimated per-token decode overhead on the target TPU.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence

import numpy as np

from repro.core.cache import ExpertCache
from repro.core.policies import Policy


@dataclass
class SimConfig:
    num_layers: int                  # MoE layers in the backbone
    num_experts: int                 # routed experts per layer
    capacity_fraction: float = 0.1   # fraction of all experts resident
    warm_tokens: int = 8             # n: cache-warming prefix
    eviction: str = "lru"
    # latency model (TPU-adapted, DESIGN.md §4)
    expert_bytes: float = 2 * 3 * 2048 * 1408   # bf16 SwiGLU expert (DSv2-Lite)
    host_bw: float = 100e9           # host->HBM, B/s
    layer_compute_s: float = 0.0     # overlap credit per layer


@dataclass
class SimResult:
    policy: str
    capacity_fraction: float
    cache_hit_rate: float
    prediction_hit_rate: float
    demand_fetches: int
    prefetches: int
    est_stall_s_per_token: float
    tokens: int

    def row(self) -> str:
        return (f"{self.policy},{self.capacity_fraction:.3f},"
                f"{self.cache_hit_rate:.4f},{self.prediction_hit_rate:.4f},"
                f"{self.est_stall_s_per_token * 1e3:.4f}")


def simulate(traces: Sequence, policy: Policy, sim: SimConfig) -> SimResult:
    capacity = max(1, int(round(sim.capacity_fraction
                                * sim.num_layers * sim.num_experts)))
    pred_hits = pred_total = 0
    hits = misses = 0            # measured from token n+1 only (paper §4.1.4)
    demand = prefetches = 0
    total_tokens = 0
    stall_s = 0.0

    for trace in traces:
        # batch-1 edge device: no cross-request reuse -> fresh cache
        cache = ExpertCache(capacity, sim.eviction)
        policy.begin_prompt(trace)
        t_steps, n_layers, _ = trace.experts.shape
        total_tokens += t_steps
        for t in range(t_steps):
            measured = t >= sim.warm_tokens
            for layer in range(n_layers):
                gt = np.unique(trace.experts[t, layer])
                if measured:
                    pred = np.asarray(policy.predict(t, layer))
                    cache.prefetch((layer, int(e)) for e in pred)
                    pset = set(int(e) for e in pred)
                    pred_hits += sum(1 for e in gt if int(e) in pset)
                    pred_total += len(gt)
                layer_misses = 0
                for e in gt:
                    hit = cache.access((layer, int(e)))
                    if measured:
                        hits += int(hit)
                        misses += int(not hit)
                        layer_misses += int(not hit)
                stall_s += max(0.0, layer_misses * sim.expert_bytes
                               / sim.host_bw - sim.layer_compute_s)
                policy.observe(t, layer, gt,
                               trace.embeddings[t]
                               if trace.embeddings is not None else None)
        demand += cache.stats.demand_fetches
        prefetches += cache.stats.prefetches

    return SimResult(
        policy=policy.name,
        capacity_fraction=sim.capacity_fraction,
        cache_hit_rate=hits / max(hits + misses, 1),
        prediction_hit_rate=pred_hits / max(pred_total, 1),
        demand_fetches=demand,
        prefetches=prefetches,
        est_stall_s_per_token=stall_s / max(total_tokens, 1),
        tokens=total_tokens,
    )


def sweep_capacity(traces, policy_factory, sim_base: SimConfig,
                   fractions: Sequence[float]) -> List[SimResult]:
    """policy_factory() -> fresh Policy per sweep point (stateful policies)."""
    out = []
    for frac in fractions:
        sim = SimConfig(**{**sim_base.__dict__, "capacity_fraction": frac})
        out.append(simulate(traces, policy_factory(), sim))
    return out
