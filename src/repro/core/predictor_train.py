"""Predictor training (paper §3.2.3/§3.2.5): AdamW(β2=.98) with layerwise
LRs, grad-clip 1.0, batch 4, ≤10 epochs, early stopping patience 3, best
model by validation loss. bf16/AMP adaptation per DESIGN.md §4.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PredictorConfig
from repro.core import metrics as M
from repro.core.predictor import (bce_loss, predictor_apply, predictor_init,
                                  predictor_lr_fn)
from repro.data.traces import PredictorDataset
from repro.training.optimizer import make_adamw


@dataclass
class TrainHistory:
    train_loss: List[float] = field(default_factory=list)
    train_acc: List[float] = field(default_factory=list)
    train_f1: List[float] = field(default_factory=list)
    val_loss: List[float] = field(default_factory=list)
    val_acc: List[float] = field(default_factory=list)
    val_exact: List[float] = field(default_factory=list)
    val_f1: List[float] = field(default_factory=list)
    steps: int = 0


def evaluate(params, pcfg: PredictorConfig, ds: PredictorDataset,
             batch_size: int = 8, max_batches: Optional[int] = None
             ) -> Dict[str, float]:
    apply = jax.jit(lambda pr, e, l, m: predictor_apply(pr, pcfg, e, l, m))
    losses, preds, trues, masks = [], [], [], []
    for bi, (emb, lids, mask, tgt) in enumerate(
            ds.batches(batch_size, shuffle=False)):
        if max_batches and bi >= max_batches:
            break
        logits = apply(params, jnp.asarray(emb), jnp.asarray(lids),
                       jnp.asarray(mask))
        losses.append(float(bce_loss(logits, jnp.asarray(tgt),
                                     jnp.asarray(mask))))
        lg = np.asarray(logits)[..., : pcfg.num_experts]
        tg = tgt[..., : pcfg.num_experts]
        preds.append(M.select_experts(lg, pcfg.top_k, pcfg.threshold))
        trues.append(tg > 0.5)
        masks.append(mask)
    pred = np.concatenate(preds)
    true = np.concatenate(trues)
    mask = np.concatenate(masks)
    return {
        "loss": float(np.mean(losses)),
        "acc": M.elementwise_accuracy(pred, true, mask),
        "exact": M.exact_set_accuracy(pred, true, mask),
        "f1": M.macro_f1(pred, true, mask),
    }


def train_predictor(train_traces, val_traces, pcfg: PredictorConfig,
                    epochs: int = 10, batch_size: int = 4,
                    base_lr: float = 1e-4, patience: int = 3,
                    seed: int = 0, log=print, eval_batches: int = 50):
    ds_train = PredictorDataset(train_traces, pcfg)
    ds_val = PredictorDataset(val_traces, pcfg)
    key = jax.random.PRNGKey(seed)
    k_init, k_drop = jax.random.split(key)
    params = predictor_init(k_init, pcfg)
    opt_init, opt_update = make_adamw(
        lr=predictor_lr_fn(base_lr), b1=0.9, b2=0.98, weight_decay=0.01,
        clip=1.0)
    opt_state = opt_init(params)

    @jax.jit
    def train_step(params, opt_state, emb, lids, mask, tgt, rng):
        def loss_fn(p):
            logits = predictor_apply(p, pcfg, emb, lids, mask, train=True,
                                     rng=rng)
            return bce_loss(logits, tgt, mask)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, stats = opt_update(grads, opt_state, params)
        return params, opt_state, loss, stats["grad_norm"]

    hist = TrainHistory()
    best_val = np.inf
    best_params = params
    bad_epochs = 0

    for epoch in range(epochs):
        t0 = time.time()
        ep_losses = []
        for emb, lids, mask, tgt in ds_train.batches(batch_size,
                                                     seed=seed + epoch):
            k_drop, sub = jax.random.split(k_drop)
            params, opt_state, loss, gnorm = train_step(
                params, opt_state, jnp.asarray(emb), jnp.asarray(lids),
                jnp.asarray(mask), jnp.asarray(tgt), sub)
            ep_losses.append(float(loss))
            hist.steps += 1
        tr = evaluate(params, pcfg, ds_train, max_batches=eval_batches)
        va = evaluate(params, pcfg, ds_val, max_batches=eval_batches)
        hist.train_loss.append(float(np.mean(ep_losses)))
        hist.train_acc.append(tr["acc"])
        hist.train_f1.append(tr["f1"])
        hist.val_loss.append(va["loss"])
        hist.val_acc.append(va["acc"])
        hist.val_exact.append(va["exact"])
        hist.val_f1.append(va["f1"])
        log(f"epoch {epoch}: train_loss={np.mean(ep_losses):.4f} "
            f"val_loss={va['loss']:.4f} val_acc={va['acc']:.4f} "
            f"val_f1={va['f1']:.4f} ({time.time() - t0:.1f}s, "
            f"seq-cache hr={ds_train.cache.hits}/{ds_train.cache.hits + ds_train.cache.misses})")
        if va["loss"] < best_val - 1e-5:
            best_val = va["loss"]
            best_params = jax.tree.map(lambda x: x, params)
            bad_epochs = 0
        else:
            bad_epochs += 1
            if bad_epochs >= patience:          # early stopping (paper)
                log(f"early stop at epoch {epoch}")
                break
    return best_params, hist
