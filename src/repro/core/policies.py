"""Prefetch policies evaluated by the cache simulator (paper §3.1/§4.1.3).

Interface: before layer ``l`` of token ``t`` runs, ``predict(t, l)`` names
experts to prefetch; after the layer runs, ``observe(...)`` reveals ground
truth. Policies:

  NoPrefetchPolicy   — reactive LRU/LFU caching only (on-demand fetch)
  NextLayerAllPolicy — DeepSpeed-MoE: eagerly fetch *every* expert [2]
  GlobalFrequencyPolicy — BrainStorm-style workload-popularity counts [4]
  RandomPolicy       — floor baseline
  MoEInfinityPolicy  — rEAM cosine match against a k-means EAMC [1]
  MoEBeyondPolicy    — the paper: learned transformer predictor
  OraclePolicy       — ground truth (upper bound)

:class:`ReuseDistanceScorer` is the bridge from prediction to *eviction*:
it folds the engine's multi-horizon prediction windows into a per-key
predicted-next-use distance on a logical MoE-layer clock, which the
``policy="learned"`` replacement mode of ``core/cache.ExpertCache`` and the
tier-1 cache of ``serving/expertstore.TieredExpertStore`` consult to pick
the victim predicted furthest from reuse.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.eam import EAMC, REAMBuilder, build_ream


class ReuseDistanceScorer:
    """Predicted-next-use distances for learned cache replacement.

    The engine records every multi-horizon prediction window it obtains
    (``record(keys, distance=d)``: these keys are predicted for use ``d``
    MoE layers from now) and ticks a logical clock once per MoE layer
    computed (``tick``). ``distance(key)`` is then the number of MoE layers
    until the key's soonest *still-future* predicted use, or ``None`` when
    no live prediction covers it — stale predictions (their layer already
    ran) expire rather than protect a key forever.

    Shared by both cache layers: tier-0 slot-buffer eviction
    (``ExpertCache(policy="learned")``) and the tier-1 promoted-copy cache
    (``TieredExpertStore``), so one prediction pass drives victim choice
    across the hierarchy.
    """

    #: prune stale entries when the map outgrows this (keys spaces are
    #: n_moe_layers * num_experts, so this is generous)
    PRUNE_AT = 65536

    def __init__(self):
        self.clock = 0                        # MoE layers computed so far
        self._next_use: Dict[Tuple[int, int], int] = {}

    def record(self, keys: Sequence, distance: int) -> None:
        """Keys predicted for use ``distance`` MoE layers from now (0 =
        the next MoE layer). Keeps the soonest live prediction per key."""
        t = self.clock + distance + 1
        for k in keys:
            cur = self._next_use.get(k)
            if cur is None or cur <= self.clock or t < cur:
                self._next_use[k] = t

    def tick(self, n: int = 1) -> None:
        """One (or ``n``) MoE layer(s) of compute completed."""
        self.clock += n
        if len(self._next_use) > self.PRUNE_AT:
            self._next_use = {k: t for k, t in self._next_use.items()
                              if t > self.clock}

    def distance(self, key) -> Optional[int]:
        """MoE layers until the soonest predicted use of ``key``; ``None``
        when no live prediction covers it."""
        t = self._next_use.get(key)
        if t is None or t <= self.clock:
            return None
        return t - self.clock

    def reset(self) -> None:
        self.clock = 0
        self._next_use.clear()


def _sigmoid(logits: np.ndarray) -> np.ndarray:
    """Per-expert confidence — the same sigmoid probability the paper's
    selection rule thresholds (core/metrics.select_experts)."""
    return 1.0 / (1.0 + np.exp(-np.asarray(logits, np.float64)))


class Policy:
    name = "base"

    #: True when predict/observe keep no per-request state, so ONE instance
    #: may be shared verbatim across in-flight requests of a batched engine.
    stateless = False

    def begin_prompt(self, trace) -> None:  # noqa: ARG002
        pass

    def observe(self, t: int, layer: int, experts: Sequence[int],
                embedding: Optional[np.ndarray] = None) -> None:
        pass

    def predict(self, t: int, layer: int) -> np.ndarray:
        """Experts to prefetch for (token t, layer)."""
        return np.empty((0,), np.int64)

    def predict_scored(self, t: int, layer: int):
        """(experts, confidences): per-expert confidence in [0, 1] aligned
        with the prediction array, or ``None`` when the policy has no
        confidence notion (heuristics). Confidence gates *deep* prefetch:
        the engine only fetches a slow-tier key several layers early when
        the predictor is confident enough (``TierConfig.deep_confidence``).
        """
        return self.predict(t, layer), None

    # --- batched API (serving/scheduler.py) -------------------------------
    # Defaults loop over the scalar interface; vectorised policies override.

    def predict_batch(self, ts: Sequence[int], layer: int) -> List[np.ndarray]:
        """Per-request prefetch sets for a batch of (token-step, layer)."""
        return [self.predict(t, layer) for t in ts]

    def observe_batch(self, ts: Sequence[int], layer: int,
                      experts_per_req: Sequence[Sequence[int]],
                      embeddings: Optional[Sequence] = None) -> None:
        for i, t in enumerate(ts):
            emb = embeddings[i] if embeddings is not None else None
            self.observe(t, layer, experts_per_req[i], emb)


class NoPrefetchPolicy(Policy):
    name = "lru-on-demand"
    stateless = True


class RandomPolicy(Policy):
    # NOT stateless: predict() advances the shared rng, so per-request
    # streams would depend on batch interleaving if one instance were
    # shared — batched engines should build one per request.
    name = "random"

    def __init__(self, num_experts: int, width: int, seed: int = 0):
        self.e = num_experts
        self.width = width
        self.rng = np.random.default_rng(seed)

    def predict(self, t, layer):
        return self.rng.choice(self.e, size=min(self.width, self.e),
                               replace=False)


class NextLayerAllPolicy(Policy):
    """DeepSpeed-MoE-style: prefetch the whole next layer (over-fetches)."""
    name = "next-layer-all"
    stateless = True

    def __init__(self, num_experts: int):
        self.e = num_experts

    def predict(self, t, layer):
        return np.arange(self.e)


class GlobalFrequencyPolicy(Policy):
    """BrainStorm-style: retain historically popular experts per layer."""
    name = "global-frequency"
    stateless = True

    def __init__(self, train_traces, num_layers: int, num_experts: int,
                 width: int):
        counts = np.zeros((num_layers, num_experts), np.float64)
        for tr in train_traces:
            counts += build_ream(tr, num_layers, num_experts)
        self.top = np.argsort(-counts, axis=1)[:, :width]

    def predict(self, t, layer):
        return self.top[layer]


class OraclePolicy(Policy):
    name = "oracle"

    def begin_prompt(self, trace):
        self.trace = trace

    def predict(self, t, layer):
        return np.unique(self.trace.experts[t, layer])


class MoEInfinityPolicy(Policy):
    """Paper §4.1.4: partial rEAM -> cosine match vs EAMC -> prefetch the
    matched sketch's expert group for the upcoming layer."""
    name = "moe-infinity"

    def __init__(self, train_traces, num_layers: int, num_experts: int,
                 width: int, eamc_capacity: int = 32, seed: int = 0):
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.width = width
        self.eamc = EAMC(num_layers, num_experts, eamc_capacity)
        reams = [build_ream(tr, num_layers, num_experts)
                 for tr in train_traces]
        if reams:
            self.eamc.fit(reams, seed=seed)
        self.partial: REAMBuilder | None = None

    def begin_prompt(self, trace):  # noqa: ARG002
        self.partial = REAMBuilder(self.num_layers, self.num_experts)

    def observe(self, t, layer, experts, embedding=None):
        self.partial.add(layer, experts)

    def predict(self, t, layer):
        return self.eamc.predict_layer(self.partial.counts, layer,
                                       self.width)


class MoEBeyondPolicy(Policy):
    """The paper's learned predictor.

    For simulation speed the per-layer predictions for a whole trace are
    precomputed in one batched, causally-masked call — position t sees only
    tokens <= t, so this is exactly the online one-layer-look-ahead."""
    name = "moe-beyond"

    def __init__(self, predictor_params, pcfg, width: Optional[int] = None):
        import jax

        from repro.core.predictor import predictor_apply
        self.params = predictor_params
        self.pcfg = pcfg
        self.width = width or pcfg.top_k
        self._apply = jax.jit(
            lambda pr, e, l, m: predictor_apply(pr, pcfg, e, l, m))
        self._pred: Dict[int, np.ndarray] = {}

    def begin_prompt(self, trace):
        import jax.numpy as jnp

        from repro.core.metrics import select_experts
        pc = self.pcfg
        t = min(trace.num_tokens, pc.max_seq)
        emb = jnp.asarray(trace.embeddings[None, :t])
        mask = jnp.ones((1, t), bool)
        n_layers = trace.experts.shape[1]
        self._pred = {}
        for layer in range(n_layers):
            lids = jnp.full((1, t), layer, jnp.int32)
            logits = np.asarray(self._apply(self.params, emb, lids, mask))[0]
            logits = logits[:, : pc.num_experts]          # horizon slot 0
            # prefetch uses pure top-k (threshold only matters for the
            # paper's accuracy metric; an empty prefetch set helps nobody)
            sel = select_experts(logits, self.width, threshold=-1e9)
            self._pred[layer] = [np.nonzero(s)[0] for s in sel]
        self._t_max = t

    def predict(self, t, layer):
        if t >= self._t_max or layer not in self._pred:
            return np.empty((0,), np.int64)
        return self._pred[layer][t]


class CrossLayerPolicy(Policy):
    """Beyond-paper (DESIGN.md §3): exploit the cross-layer gate correlation
    MoE-Infinity ignores — predict layer l's experts from the experts that
    JUST fired at layer l-1 for the same token, via conditional frequencies
    P(e_l | e_{l-1}) estimated from training traces. Zero learned weights;
    complements (and composes with) the request-level rEAM signal."""
    name = "cross-layer"

    def __init__(self, train_traces, num_layers: int, num_experts: int,
                 width: int, alpha: float = 0.5):
        self.width = width
        self.e = num_experts
        # cond[l][a, b] = count(expert b fires at layer l | a fired at l-1)
        self.cond = np.full((num_layers, num_experts, num_experts), alpha)
        self.prior = np.full((num_layers, num_experts), alpha)
        for tr in train_traces:
            t_steps, n_layers, _ = tr.experts.shape
            for t in range(t_steps):
                for layer in range(n_layers):
                    cur = np.unique(tr.experts[t, layer])
                    self.prior[layer, cur] += 1
                    if layer > 0:
                        prev = np.unique(tr.experts[t, layer - 1])
                        for a in prev:
                            self.cond[layer, a, cur] += 1
        self._last: Dict[int, np.ndarray] = {}

    def begin_prompt(self, trace=None):  # noqa: ARG002
        self._last = {}

    def observe(self, t, layer, experts, embedding=None):
        self._last[layer] = np.asarray(experts)

    def predict(self, t, layer):
        if layer == 0 or (layer - 1) not in self._last:
            scores = self.prior[layer]
        else:
            prev = self._last[layer - 1]
            scores = self.cond[layer, prev].sum(axis=0)
        return np.argsort(-scores)[: self.width]


class OnlineMoEBeyondPolicy(Policy):
    """Live-serving variant of MoEBeyondPolicy: accumulates the prompt's
    token embeddings as they are observed and predicts incrementally —
    used by serving/engine.py where no trace exists up front."""
    name = "moe-beyond-online"

    def __init__(self, predictor_params, pcfg, width: Optional[int] = None):
        import jax

        from repro.core.predictor import predictor_apply
        self.params = predictor_params
        self.pcfg = pcfg
        self.width = width or pcfg.top_k
        self._apply = jax.jit(
            lambda pr, e, l, m: predictor_apply(pr, pcfg, e, l, m))
        self._emb: list = []
        self._seen_t = -1

    def begin_prompt(self, trace=None):  # noqa: ARG002
        self._emb = []
        self._seen_t = -1

    def observe(self, t, layer, experts, embedding=None):
        if embedding is not None and t > self._seen_t:
            self._emb.append(np.asarray(embedding, np.float32))
            self._seen_t = t

    def predict(self, t, layer):
        return self.predict_scored(t, layer)[0]

    def predict_scored(self, t, layer):
        import jax.numpy as jnp

        from repro.core.metrics import select_experts
        pc = self.pcfg
        # embeddings observed so far (token t itself is appended by the
        # engine before deeper layers run; fall back to t-1 context)
        n = min(len(self._emb), pc.max_seq)
        if n == 0:
            return np.empty((0,), np.int64), np.empty((0,), np.float64)
        emb = np.zeros((1, n, pc.token_emb_dim), np.float32)
        emb[0] = np.stack(self._emb[-n:])
        logits = np.asarray(self._apply(
            self.params, jnp.asarray(emb),
            jnp.full((1, n), layer, jnp.int32),
            jnp.ones((1, n), bool)))[0, -1, : pc.num_experts]
        sel = select_experts(logits, self.width, threshold=-1e9)
        ids = np.nonzero(sel)[0]
        conf = _sigmoid(logits[ids])
        return ids, conf

    @staticmethod
    def batchable(policies: Sequence["Policy"]) -> bool:
        """True when one vectorised forward can serve every instance: all
        OnlineMoEBeyondPolicy sharing the same predictor weights (the
        per-request-factory pattern closes over one trained predictor)."""
        if not policies:
            return False
        first = policies[0]
        return (isinstance(first, OnlineMoEBeyondPolicy) and
                all(isinstance(p, OnlineMoEBeyondPolicy)
                    and p.params is first.params and p.pcfg == first.pcfg
                    for p in policies))

    @staticmethod
    def predict_many(policies: Sequence["OnlineMoEBeyondPolicy"],
                     layer: int) -> List[np.ndarray]:
        """Cross-request batched prediction: ONE jitted predictor forward
        for all in-flight requests instead of a per-request Python loop.

        Requests are right-padded to a shared power-of-two length bucket
        (bounding recompiles); the causal+padding mask makes position
        ``n_i - 1`` of each row attend to exactly that request's observed
        embeddings, so per-request results match the scalar ``predict``.
        """
        return OnlineMoEBeyondPolicy.predict_many_layers(
            policies, [layer])[layer]

    @staticmethod
    def predict_many_layers(policies: Sequence["OnlineMoEBeyondPolicy"],
                            layers: Sequence[int],
                            with_scores: bool = False,
                            ) -> Dict[int, List]:
        """``predict_many`` across a lookahead window of MoE layers: one
        jitted forward serves every (request, future-layer) pair — the
        layer id is a per-row input, so deeper-horizon predictions ride
        the same batch as next-layer ones instead of multiplying predictor
        calls. Returns {layer: per-request prediction arrays}; per-request
        results match the scalar ``predict(t, layer)`` for each layer.
        With ``with_scores`` each per-request entry is an
        ``(experts, confidences)`` pair instead — the sigmoid probability
        of each selected expert, matching ``predict_scored``."""
        import jax.numpy as jnp

        from repro.core.metrics import select_experts
        pc = policies[0].pcfg
        ns = [min(len(p._emb), pc.max_seq) for p in policies]

        def empty():
            ids = np.empty((0,), np.int64)
            return (ids, np.empty((0,), np.float64)) if with_scores else ids

        out: Dict[int, List] = {
            layer: [empty()] * len(policies) for layer in layers}
        live = [i for i, n in enumerate(ns) if n > 0]
        if not live or not layers:
            return out
        tb = 1
        while tb < max(ns[i] for i in live):         # pow-of-two seq bucket
            tb *= 2
        rows = [(i, layer) for layer in layers for i in live]
        emb = np.zeros((len(rows), tb, pc.token_emb_dim), np.float32)
        mask = np.zeros((len(rows), tb), bool)
        lids = np.zeros((len(rows), tb), np.int32)
        for j, (i, layer) in enumerate(rows):
            emb[j, : ns[i]] = np.stack(policies[i]._emb[-ns[i]:])
            mask[j, : ns[i]] = True
            lids[j] = layer
        logits = np.asarray(policies[0]._apply(
            policies[0].params, jnp.asarray(emb), jnp.asarray(lids),
            jnp.asarray(mask)))
        for j, (i, layer) in enumerate(rows):
            lg = logits[j, ns[i] - 1, : pc.num_experts]
            sel = select_experts(lg, policies[i].width, threshold=-1e9)
            ids = np.nonzero(sel)[0]
            out[layer][i] = (ids, _sigmoid(lg[ids])) if with_scores else ids
        return out


class PerRequestPolicy:
    """Per-request policy state behind the batched predict/observe API.

    The batched engine shares ONE ExpertCache across in-flight requests but
    prediction state (rEAM sketches, observed embeddings, precomputed trace
    predictions) is per request. ``factory()`` builds a fresh Policy for
    every admitted request; a stateless policy instance may be passed
    directly and is then shared across all requests.
    """

    def __init__(self, policy_or_factory, force_shared: bool = False):
        """force_shared: accept a *stateful* instance as shared anyway —
        only sound when at most one request is ever in flight (the batch-1
        OffloadEngine)."""
        if isinstance(policy_or_factory, Policy):
            pol = policy_or_factory
            if not (pol.stateless or force_shared):
                raise ValueError(
                    f"policy {pol.name!r} keeps per-request state; pass a "
                    f"factory (e.g. lambda: {type(pol).__name__}(...)) so "
                    "each request gets its own instance")
            self._shared: Optional[Policy] = pol
            self._factory = None
        else:
            self._shared = None
            self._factory = policy_or_factory
        self._per_req: Dict[int, Policy] = {}

    def _get(self, rid: int) -> Policy:
        if self._shared is not None:
            return self._shared
        return self._per_req[rid]

    def begin_request(self, rid: int, trace=None) -> None:
        if self._shared is None:
            self._per_req[rid] = self._factory()
            self._per_req[rid].begin_prompt(trace)
        else:
            self._shared.begin_prompt(trace)

    def end_request(self, rid: int) -> None:
        self._per_req.pop(rid, None)

    def replay_prefix(self, rid: int, experts_by_layer) -> None:
        """Feed a prefix-cache hit's recorded activations into the request's
        policy as observations — the request skips the prefill that would
        have produced them, so replay is how rEAM-style predictors still see
        the prompt's routing signature. ``experts_by_layer`` maps MoE-layer
        ordinal -> expert-id array (no embeddings exist for skipped tokens,
        so embedding-driven policies simply ignore the replay)."""
        pol = self._get(rid)
        for mi in sorted(experts_by_layer):
            pol.observe(0, mi, np.asarray(experts_by_layer[mi]), None)

    def predict_batch(self, rids: Sequence[int], ts: Sequence[int],
                      layer: int) -> List[np.ndarray]:
        if self._shared is not None:   # shared policy: use its batched path
            return self._shared.predict_batch(ts, layer)
        pols = [self._get(r) for r in rids]
        if len(pols) > 1 and OnlineMoEBeyondPolicy.batchable(pols):
            # one jitted predictor forward across in-flight requests
            return OnlineMoEBeyondPolicy.predict_many(pols, layer)
        return [p.predict(t, layer) for p, t in zip(pols, ts)]

    def predict_batch_multi(self, rids: Sequence[int], ts: Sequence[int],
                            layers: Sequence[int],
                            ) -> Dict[int, List[np.ndarray]]:
        """Per-request prefetch sets for a *lookahead window* of MoE
        layers — the horizon-aware engine asks for layers ``mi .. mi+H-1``
        at once and gates each predicted key on its tier's required
        lookahead depth. Online-predictor policies fuse the whole window
        into one forward; everything else loops ``predict_batch``."""
        pols = [self._get(r) for r in rids]
        if (self._shared is None and len(layers) > 0
                and OnlineMoEBeyondPolicy.batchable(pols)):
            return OnlineMoEBeyondPolicy.predict_many_layers(pols, layers)
        return {layer: self.predict_batch(rids, ts, layer)
                for layer in layers}

    def predict_batch_multi_scored(self, rids: Sequence[int],
                                   ts: Sequence[int],
                                   layers: Sequence[int],
                                   ) -> Dict[int, List[tuple]]:
        """``predict_batch_multi`` with confidences: each per-request entry
        is an ``(experts, confidences)`` pair (confidences ``None`` for
        policies without a confidence notion). The engine's per-horizon
        confidence gate and the ReuseDistanceScorer both consume this —
        one fused forward still serves the whole window for the online
        predictor."""
        pols = [self._get(r) for r in rids]
        if (self._shared is None and len(layers) > 0
                and OnlineMoEBeyondPolicy.batchable(pols)):
            return OnlineMoEBeyondPolicy.predict_many_layers(
                pols, layers, with_scores=True)
        return {layer: [p.predict_scored(t, layer)
                        for p, t in zip(pols, ts)]
                for layer in layers}

    def observe_batch(self, rids: Sequence[int], ts: Sequence[int],
                      layer: int, experts_per_req, embeddings=None) -> None:
        if self._shared is not None:
            self._shared.observe_batch(ts, layer, experts_per_req,
                                       embeddings)
            return
        for i, (r, t) in enumerate(zip(rids, ts)):
            emb = embeddings[i] if embeddings is not None else None
            self._get(r).observe(t, layer, experts_per_req[i], emb)
