"""Expert-activation trace collection (paper Contribution 2).

Runs batch-1 autoregressive decoding on an MoE backbone and records, per
generated token: token id, the backbone's token-embedding vector, and the
routed expert ids at every MoE layer — the paper's trace schema.

Not to be confused with ``repro/serving/telemetry.py``: that module
records *runtime* observability traces (per-request span timelines,
counters, Chrome-trace export) of the serving engine itself, whereas
this one collects the *dataset* the activation predictor is trained on.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import _layer_is_moe, _layer_split


@dataclass
class Trace:
    tokens: np.ndarray       # (T,) i32 — token processed at each step
    embeddings: np.ndarray   # (T, emb_dim) f32 — backbone token embeddings
    experts: np.ndarray      # (T, L_moe, k) i32 — routed experts per layer
    prompt_len: int          # tokens 0..prompt_len-1 came from the prompt

    @property
    def num_tokens(self) -> int:
        return len(self.tokens)


def moe_layer_ids(cfg) -> List[int]:
    return [i for i in range(cfg.num_layers) if _layer_is_moe(cfg, i)]


def extract_step_experts(cfg, extras) -> np.ndarray:
    """Flatten a decode step's extras into (L_moe, k) in layer order
    (batch element 0 — the paper operates at batch size 1)."""
    n_head, n_groups, _ = _layer_split(cfg)
    pat = len(cfg.block_pattern)
    rows = []
    for ex in extras["head"]:
        if "experts" in ex:
            rows.append(np.asarray(ex["experts"])[0, 0])
    for g in range(n_groups):
        for j in range(pat):
            ex = extras["scan"][j]
            if isinstance(ex, dict) and "experts" in ex:
                rows.append(np.asarray(ex["experts"])[g, 0, 0])
    for ex in extras["tail"]:
        if "experts" in ex:
            rows.append(np.asarray(ex["experts"])[0, 0])
    return np.stack(rows) if rows else np.zeros((0, 0), np.int32)


_STEP_FNS: dict = {}


def _traced_step(cfg):
    """One jitted decode step per config (avoids per-trace recompiles)."""
    if cfg not in _STEP_FNS:
        from repro.models import transformer as T

        @jax.jit
        def step_fn(prm, caches, pos, tok):
            logits, caches2, extras, _ = T.lm_apply(
                prm, cfg, tok, None, mode="decode", caches=caches, pos=pos)
            return logits, caches2, extras

        _STEP_FNS[cfg] = step_fn
    return _STEP_FNS[cfg]


def collect_trace(model, params, prompt: Sequence[int], max_new: int,
                  cache_len: int, temperature: float = 0.8,
                  seed: int = 0) -> Trace:
    """Token-by-token batch-1 decode; every token (prompt + generated) passes
    through decode_step so its expert activations are recorded."""
    cfg = model.cfg
    tok_emb = np.asarray(params["tok_emb"], np.float32)
    state = model.init_decode_state(1, cache_len)
    rng = jax.random.PRNGKey(seed)
    step_fn = _traced_step(cfg)

    tokens: List[int] = []
    experts_rows = []
    cur = int(prompt[0])
    n_total = min(len(prompt) + max_new, cache_len)
    for t in range(n_total):
        tok = jnp.full((1, 1), cur, jnp.int32)
        logits, caches, extras = step_fn(params, state["caches"],
                                         state["pos"], tok)
        state = {"pos": state["pos"] + 1, "caches": caches}
        tokens.append(cur)
        experts_rows.append(extract_step_experts(cfg, extras))
        if t + 1 < len(prompt):
            cur = int(prompt[t + 1])
        else:
            rng, sub = jax.random.split(rng)
            lg = logits[0, -1] / max(temperature, 1e-6)
            cur = int(jax.random.categorical(sub, lg))

    toks = np.asarray(tokens, np.int32)
    return Trace(
        tokens=toks,
        embeddings=tok_emb[toks],
        experts=np.stack(experts_rows).astype(np.int32),
        prompt_len=min(len(prompt), n_total),
    )


def collect_traces(model, params, prompts, max_new: int, cache_len: int,
                   temperature: float = 0.8, seed: int = 0) -> List[Trace]:
    return [collect_trace(model, params, p, max_new, cache_len, temperature,
                          seed + i) for i, p in enumerate(prompts)]


# ---------------------------------------------------------------------------
# (De)serialisation

def save_traces(path: str, traces: List[Trace]) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    blob = {}
    for i, tr in enumerate(traces):
        blob[f"t{i}_tokens"] = tr.tokens
        blob[f"t{i}_emb"] = tr.embeddings.astype(np.float16)
        blob[f"t{i}_experts"] = tr.experts
        blob[f"t{i}_plen"] = np.asarray(tr.prompt_len)
    np.savez_compressed(path, n=np.asarray(len(traces)), **blob)


def load_traces(path: str) -> List[Trace]:
    data = np.load(path)
    out = []
    for i in range(int(data["n"])):
        out.append(Trace(
            tokens=data[f"t{i}_tokens"],
            embeddings=data[f"t{i}_emb"].astype(np.float32),
            experts=data[f"t{i}_experts"],
            prompt_len=int(data[f"t{i}_plen"]),
        ))
    return out
