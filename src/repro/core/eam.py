"""Expert Activation Matrices (MoE-Infinity baseline, paper §3.1 / §4.1.4).

iEAM: per-token (L, E) bit matrix of which experts fired.
rEAM: request-level accumulation (an L x E histogram over the prompt).
EAMC: a collection of rEAM sketches compressed by k-means (paper Fig 4);
online, the partial rEAM of the live prompt is cosine-matched against the
collection and the winner's per-layer expert group is prefetched.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


class REAMBuilder:
    """Accumulates iEAMs into a request-level EAM."""

    def __init__(self, num_layers: int, num_experts: int):
        self.counts = np.zeros((num_layers, num_experts), np.float64)

    def add(self, layer: int, experts: Sequence[int]) -> None:
        self.counts[layer, list(experts)] += 1.0

    def flat(self) -> np.ndarray:
        v = self.counts.reshape(-1)
        n = np.linalg.norm(v)
        return v / n if n > 0 else v

    def matrix(self) -> np.ndarray:
        return self.counts


def build_ream(trace, num_layers: int, num_experts: int,
               upto_token: int | None = None) -> np.ndarray:
    """trace.experts: (T, L, k) int -> (L, E) histogram."""
    ex = trace.experts if upto_token is None else trace.experts[:upto_token]
    ream = np.zeros((num_layers, num_experts), np.float64)
    t, l, k = ex.shape
    for li in range(l):
        np.add.at(ream[li], ex[:, li].reshape(-1), 1.0)
    return ream


def kmeans(x: np.ndarray, k: int, iters: int = 25, seed: int = 0):
    """Cosine k-means (unit-normalised -> spherical). x: (N, D)."""
    rng = np.random.default_rng(seed)
    norms = np.linalg.norm(x, axis=1, keepdims=True)
    xn = x / np.maximum(norms, 1e-12)
    k = min(k, len(xn))
    centroids = xn[rng.choice(len(xn), k, replace=False)].copy()
    assign = np.zeros(len(xn), np.int64)
    for _ in range(iters):
        sims = xn @ centroids.T
        new_assign = np.argmax(sims, axis=1)
        if np.array_equal(new_assign, assign):
            break
        assign = new_assign
        for c in range(k):
            members = xn[assign == c]
            if len(members):
                m = members.mean(0)
                centroids[c] = m / max(np.linalg.norm(m), 1e-12)
    return centroids, assign


class EAMC:
    """Expert-Activation-Matrix Collection with k-means compression."""

    def __init__(self, num_layers: int, num_experts: int, capacity: int = 32):
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.capacity = capacity
        self.centroid_matrices: np.ndarray | None = None  # (K, L, E)
        self._centroids_flat: np.ndarray | None = None

    def fit(self, reams: List[np.ndarray], seed: int = 0) -> None:
        """reams: list of (L, E) histograms from past requests."""
        flats = np.stack([r.reshape(-1) for r in reams])
        if len(flats) <= self.capacity:
            norms = np.maximum(np.linalg.norm(flats, axis=1, keepdims=True),
                               1e-12)
            self._centroids_flat = flats / norms
        else:
            self._centroids_flat, _ = kmeans(flats, self.capacity, seed=seed)
        self.centroid_matrices = self._centroids_flat.reshape(
            -1, self.num_layers, self.num_experts)

    def match(self, partial_ream: np.ndarray) -> np.ndarray:
        """Nearest sketch by cosine similarity. Returns its (L, E) matrix."""
        v = partial_ream.reshape(-1)
        n = np.linalg.norm(v)
        if n == 0 or self._centroids_flat is None:
            return np.zeros((self.num_layers, self.num_experts))
        sims = self._centroids_flat @ (v / n)
        return self.centroid_matrices[int(np.argmax(sims))]

    def predict_layer(self, partial_ream: np.ndarray, layer: int,
                      width: int) -> np.ndarray:
        """Top-``width`` experts for ``layer`` from the matched sketch."""
        m = self.match(partial_ream)[layer]
        order = np.argsort(-m)
        return order[: width][m[order[: width]] > 0]
