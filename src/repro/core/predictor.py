"""The MoE-Beyond expert-activation predictor (paper §3.2), in JAX.

Architecture (hyper-parameter-faithful):
  concat(token_emb, layer_emb[layer_id]) -> linear(512) -> 4-layer post-LN
  transformer encoder (8 heads, d_ff 2048, dropout .1) -> 2-layer GELU MLP
  head -> num_experts sigmoid logits (multi-label).

One divergence, documented in DESIGN.md §10: the self-attention mask is
causal *and* padding — the paper only masks padding, but causality is what
makes the online one-layer-look-ahead prefetch legal (position t must not
peek at future tokens), and it lets the simulator batch a whole prompt in
one call while remaining equivalent to online prediction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import PredictorConfig

NEG_INF = -1e30


def _ln(x, g, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * g + b).astype(x.dtype)


def predictor_init(key, pc: PredictorConfig):
    d, ff, e = pc.d_model, pc.d_ff, pc.num_experts
    keys = jax.random.split(key, 3 + pc.num_layers)

    def dense(k, i, o):
        return jax.random.normal(k, (i, o), jnp.float32) * (i ** -0.5)

    enc = []
    for i in range(pc.num_layers):
        ks = jax.random.split(keys[3 + i], 6)
        enc.append({
            "wq": dense(ks[0], d, d), "wk": dense(ks[1], d, d),
            "wv": dense(ks[2], d, d), "wo": dense(ks[3], d, d),
            "ln1_g": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
            "w1": dense(ks[4], d, ff), "b1": jnp.zeros((ff,)),
            "w2": dense(ks[5], ff, d), "b2": jnp.zeros((d,)),
            "ln2_g": jnp.ones((d,)), "ln2_b": jnp.zeros((d,)),
        })
    enc = (jax.tree.map(lambda *xs: jnp.stack(xs), *enc) if enc
           else {})

    k_h1, k_h2 = jax.random.split(keys[2])
    return {
        "layer_emb": jax.random.normal(
            keys[0], (pc.num_model_layers, pc.layer_emb_dim)) * 0.02,
        "in_w": dense(keys[1], pc.token_emb_dim + pc.layer_emb_dim, d),
        "in_b": jnp.zeros((d,)),
        "enc": enc,
        "head_w0": dense(k_h1, d, d), "head_b0": jnp.zeros((d,)),
        "head_w1": dense(k_h2, d, e * pc.horizon),
        "head_b1": jnp.zeros((e * pc.horizon,)),
    }


def _dropout(x, rate, rng, train):
    if not train or rate <= 0.0 or rng is None:
        return x
    keep = jax.random.bernoulli(rng, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)


def predictor_apply(params, pc: PredictorConfig, emb, layer_ids, pad_mask,
                    train: bool = False, rng=None):
    """emb: (B,T,token_emb_dim) f32; layer_ids: (B,T) i32;
    pad_mask: (B,T) bool (True = real token). Returns logits (B,T,E*horizon).
    """
    b, t, _ = emb.shape
    h = pc.num_heads
    dh = pc.d_model // h

    # standardise the backbone embeddings: a trained tok_emb can have tiny
    # scale (~0.02 init), which starves the input projection's gradients
    ef = emb.astype(jnp.float32)
    mu = jnp.mean(ef, -1, keepdims=True)
    sd = jnp.std(ef, -1, keepdims=True) + 1e-6
    ef = (ef - mu) / sd

    le = jnp.take(params["layer_emb"], layer_ids, axis=0)
    x = jnp.concatenate([ef, le], -1)
    x = jnp.einsum("btf,fd->btd", x, params["in_w"]) + params["in_b"]

    causal = jnp.tril(jnp.ones((t, t), bool))
    mask = causal[None] & pad_mask[:, None, :]           # (B,T,T)

    n_drop = pc.num_layers * 2 + 1
    rngs = (jax.random.split(rng, n_drop) if (train and rng is not None)
            else [None] * n_drop)

    for i in range(pc.num_layers):
        lp = jax.tree.map(lambda a: a[i], params["enc"])
        # pre-LN (norm_first): post-LN stalls for many epochs at this data
        # scale without warmup (Xiong et al. 2020) — verified empirically in
        # EXPERIMENTS.md §Paper-validation notes
        xn = _ln(x, lp["ln1_g"], lp["ln1_b"])
        q = jnp.einsum("btd,de->bte", xn, lp["wq"]).reshape(b, t, h, dh)
        k = jnp.einsum("btd,de->bte", xn, lp["wk"]).reshape(b, t, h, dh)
        v = jnp.einsum("btd,de->bte", xn, lp["wv"]).reshape(b, t, h, dh)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * dh ** -0.5
        s = jnp.where(mask[:, None], s, NEG_INF)
        p = jax.nn.softmax(s, -1)
        p = _dropout(p, pc.dropout, rngs[2 * i], train)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, t, -1)
        o = jnp.einsum("bte,ed->btd", o, lp["wo"])
        x = x + o
        xn = _ln(x, lp["ln2_g"], lp["ln2_b"])
        f = jax.nn.gelu(jnp.einsum("btd,df->btf", xn, lp["w1"]) + lp["b1"])
        f = jnp.einsum("btf,fd->btd", f, lp["w2"]) + lp["b2"]
        f = _dropout(f, pc.dropout, rngs[2 * i + 1], train)
        x = x + f

    x = jax.nn.gelu(jnp.einsum("btd,de->bte", x, params["head_w0"])
                    + params["head_b0"])
    x = _dropout(x, pc.dropout, rngs[-1], train)
    return jnp.einsum("btd,de->bte", x, params["head_w1"]) + params["head_b1"]


def bce_loss(logits, targets, mask):
    """Multi-label BCE-with-logits. targets: (B,T,E) in {0,1}; mask (B,T)."""
    z = logits.astype(jnp.float32)
    y = targets.astype(jnp.float32)
    per = jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
    per = jnp.mean(per, -1)                              # over experts
    return jnp.sum(per * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def predictor_lr_fn(base: float = 1e-4):
    """The paper's layerwise LR groups (§3.2.3)."""
    def fn(path: str) -> float:
        if path.startswith("in_") or path.startswith("layer_emb"):
            return base                   # input projection: 1e-4
        if path.startswith("head_"):
            return 0.8 * base             # head: 0.8e-4
        return 0.9 * base                 # encoder: 0.9e-4
    return fn
