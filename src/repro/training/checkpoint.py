"""Flat-npz checkpointing for arbitrary pytrees (orbax is not available)."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:      # npz has no bf16; widen to f32
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def load(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(path)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat_like:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = jnp.asarray(data[key])
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
