from repro.training.optimizer import (  # noqa: F401
    DynamicLossScaler, clip_by_global_norm, cosine_schedule, global_norm,
    make_adamw)
