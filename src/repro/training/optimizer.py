"""AdamW with per-parameter-group learning rates, global-norm clipping and an
optional dynamic loss scaler (optax is not available offline).

The paper's predictor trains with AdamW(β1=.9, β2=.98, wd=.01), layerwise LRs
(input_proj 1e-4, encoder 0.9e-4, head 0.8e-4) and clip 1.0 — expressed here
as an ``lr_fn(path) -> lr`` over parameter paths.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def make_adamw(lr: float | Callable[[str], float] = 1e-4,
               b1: float = 0.9, b2: float = 0.98, eps: float = 1e-8,
               weight_decay: float = 0.01, clip: float = 1.0,
               schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None):
    """Returns (init_fn, update_fn).

    ``lr`` is either a float or a function mapping a "/"-joined param path to
    that parameter's learning rate (the paper's layerwise groups).
    update_fn(grads, state, params) -> (new_params, new_state, stats)
    """
    lr_fn = lr if callable(lr) else (lambda _p: lr)

    def init_fn(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update_fn(grads, state, params):
        if clip:
            grads, gnorm = clip_by_global_norm(grads, clip)
        else:
            gnorm = global_norm(grads)
        step = state["step"] + 1
        sched = schedule(step) if schedule is not None else 1.0
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        flat_g = jax.tree_util.tree_flatten_with_path(grads)[0]
        flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
        flat_mu = jax.tree.leaves(state["mu"])
        flat_nu = jax.tree.leaves(state["nu"])

        new_p, new_mu, new_nu = [], [], []
        for (path, p), (_, g), mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
            gf = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * gf
            nu = b2 * nu + (1 - b2) * gf * gf
            mhat = mu / bc1
            nhat = nu / bc2
            lr_p = lr_fn(_path_str(path)) * sched
            upd = mhat / (jnp.sqrt(nhat) + eps) + \
                weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - lr_p * upd).astype(p.dtype))
            new_mu.append(mu)
            new_nu.append(nu)

        unflat = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
        return (unflat(new_p),
                {"mu": unflat(new_mu), "nu": unflat(new_nu), "step": step},
                {"grad_norm": gnorm})

    return init_fn, update_fn


def cosine_schedule(base: float = 1.0, warmup: int = 100,
                    total: int = 10_000, floor: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base * warm * cos
    return fn


class DynamicLossScaler:
    """fp16-style loss scaling (paper's AMP GradScaler). Identity for bf16 —
    kept for fidelity; see DESIGN.md §4."""

    def __init__(self, init_scale: float = 2.0 ** 15, growth_interval: int = 2000,
                 enabled: bool = False):
        self.scale = init_scale if enabled else 1.0
        self.growth_interval = growth_interval
        self.enabled = enabled
        self._good_steps = 0

    def scale_loss(self, loss):
        return loss * self.scale

    def unscale_and_check(self, grads):
        grads = jax.tree.map(lambda g: g / self.scale, grads)
        finite = jnp.all(jnp.array(
            [jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(grads)]))
        return grads, finite

    def update(self, finite: bool):
        if not self.enabled:
            return
        if finite:
            self._good_steps += 1
            if self._good_steps >= self.growth_interval:
                self.scale *= 2.0
                self._good_steps = 0
        else:
            self.scale = max(self.scale / 2.0, 1.0)
            self._good_steps = 0
