"""Runtime telemetry: per-request span timelines, Chrome-trace export and
a live predictor-quality scoreboard.

This is *runtime* observability for the serving stack — not to be confused
with ``repro/core/tracing.py``, which collects the paper's expert
*activation traces* (the predictor's training data). The two layers meet
only in the scoreboard: the engine reports each MoE layer's predicted vs
actually-routed expert sets here, turning the paper's offline Table
metrics (precision/recall/F1) into per-window time series.

Design contract (pinned by ``tests/test_telemetry.py``):

* **Zero overhead when off.** ``Telemetry(enabled=False)`` (or the shared
  ``NULL_TELEMETRY`` singleton every engine defaults to) turns every
  method into an early return; ``span()`` hands back one module-level null
  context manager — same object identity on every call, nothing recorded,
  no per-call allocation. Emission sites in hot loops additionally guard
  with ``if tel.enabled:`` so argument construction is skipped too.
* **Purely passive when on.** Recording reads the wall clock and appends
  to host-side lists. It never touches engine state, RNG streams or
  jitted programs — token streams and ``EngineStats`` are bit-identical
  with telemetry on or off.
* **Registered metric names only.** Every ``counter``/``gauge``/
  ``histogram`` name must exist in the module-level ``METRICS`` catalogue;
  unknown names raise (and the stats-registration lint flags literal
  unregistered names at the call site), so a typo cannot open a silent
  new series.

Tracks are ``(pid, tid)`` pairs in Chrome ``trace_event`` terms:
``PID_REQUESTS`` holds one thread per request (queue-wait, prefill
chunks, decode steps, preempt/resume, retire — wall clock),
``PID_CHANNELS`` one thread per ``OverlapTracker`` fetch/ship channel
(modeled transfer timeline) and ``PID_ENGINE`` the engine-wide driver
events (prefetch submissions, evictions, stalls). ``to_chrome_trace()``
emits the whole thing as ``trace_event`` JSON that loads directly in
``ui.perfetto.dev``; ``series()``/``scoreboard()`` are the rolling
time-series view (``tools/check_trace.py`` validates both).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

# Chrome-trace process ids — one per timebase/subsystem. Request and
# engine tracks run on the wall clock (``Telemetry.now``); channel tracks
# run on the OverlapTracker's modeled compute/transfer clock, so they get
# their own process rather than interleaving two clocks on one timeline.
PID_REQUESTS = 1
PID_CHANNELS = 2
PID_ENGINE = 3

PROCESS_NAMES = {
    PID_REQUESTS: "requests",
    PID_CHANNELS: "channels",
    PID_ENGINE: "engine",
}

# Central metric catalogue: every name passed to ``counter``/``gauge``/
# ``histogram`` must be registered here. The stats-registration lint
# (analysis/rules.py) cross-checks literal metric names at every call
# site against this dict, so a typo is a lint finding, not a silent new
# series. Keys are "<subsystem>.<metric>"; values document unit/meaning.
METRICS = {
    "predictor.tp": "per-MoE-layer-visit true positives: predicted "
                    "experts that the router actually used",
    "predictor.fp": "predicted experts the router did not use",
    "predictor.fn": "routed experts the predictor missed",
    "cache.hit": "tier-0 ExpertCache hits (demanded key resident)",
    "cache.miss": "tier-0 ExpertCache misses",
    "cache.t01_hit": "demanded keys served from tier 0 or tier 1 "
                     "(device slot hit, or host-DRAM-resident on miss)",
    "cache.t01_miss": "demanded keys that had to come from tier 2+ "
                      "(peer/disk)",
    "cache.evictions": "tier-0 slot evictions (provenance in the "
                       "eviction instant events)",
    "prefetch.submitted": "predicted keys inserted by _submit_prefetch",
    "prefetch.clamps": "lookahead windows truncated by the deep-prefetch "
                       "fit clamp (EngineStats.horizon_clamps mirror)",
    "fetch.bytes": "weight bytes put on a fetch channel (per transfer)",
    "ship.bytes": "activation bytes put on the ship channel",
    "stall.s": "un-overlapped transfer stall charged at a wait (seconds)",
    "kv.blocks_in_use": "KV pool blocks currently allocated (gauge)",
    "prefix.adopted_blocks": "prefix-cache blocks adopted at admission "
                             "or chunk-boundary extension",
    "prefix.evicted_blocks": "prefix-cache blocks evicted under pressure",
    "sched.admitted": "requests admitted to a lane",
    "sched.rejected": "requests rejected (worst case exceeds the pool)",
    "sched.preemptions": "running requests preempted by a more urgent "
                         "waiter",
    "sched.retired": "requests retired (all tokens produced)",
    "store.promotions": "tiered-store fetches that promoted a cold "
                        "expert into the tier-1 host cache",
    "store.demotions": "tier-0 evictions demoted into the tier-1 host "
                       "cache",
    "step.wall_s": "decode-step wall time (histogram, seconds)",
    "prefill.wall_s": "prefill-chunk wall time (histogram, seconds)",
}


@dataclass
class Span:
    """One timed interval on a telemetry track.

    ``pid``/``tid`` name the track (see ``PID_REQUESTS`` etc. and the
    thread names registered via ``ensure_track``), ``name`` the event,
    ``t0_s``/``t1_s`` the interval endpoints in seconds since the
    Telemetry epoch, and ``args`` the free-form payload attached at
    emission. ``spans()`` reconstructs these from the recorded B/E/X
    events; the span context manager also emits them."""
    pid: int
    tid: int
    name: str
    t0_s: float
    t1_s: float
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def dur_s(self) -> float:
        return self.t1_s - self.t0_s


@dataclass
class SeriesPoint:
    """One bucket of ``Telemetry.series(metric, bucket_s)``.

    ``t_s`` is the bucket's start (seconds since the Telemetry epoch,
    aligned to a multiple of ``bucket_s``), ``total`` the sum of values
    recorded in the bucket, ``count`` how many recordings landed in it
    and ``last`` the final value seen (the natural gauge read-out)."""
    t_s: float
    total: float
    count: int
    last: float

    @property
    def mean(self) -> float:
        return self.total / max(self.count, 1)


class _NullSpan:
    """The do-nothing context manager ``span()`` returns when telemetry
    is off — one shared instance, so the off path allocates nothing."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _LiveSpan:
    """Context manager emitting a balanced B/E pair on a track."""
    __slots__ = ("_tel", "_pid", "_tid", "_name", "_args")

    def __init__(self, tel, pid, tid, name, args):
        self._tel, self._pid, self._tid = tel, pid, tid
        self._name, self._args = name, args

    def __enter__(self):
        self._tel.begin(self._pid, self._tid, self._name, self._args)
        return self

    def __exit__(self, *exc):
        self._tel.end(self._pid, self._tid, self._name)
        return False


@dataclass(eq=False)
class Telemetry:
    """The event bus every serving subsystem emits into.

    ``enabled`` is the only configuration: True records counters, gauges,
    histograms and spans (see the module docstring for the contract);
    False turns every method into a no-op — engines default to the shared
    ``NULL_TELEMETRY`` singleton, so an un-instrumented run pays one
    attribute read per guarded site and nothing else."""
    enabled: bool = True

    def __post_init__(self):
        self._t0 = time.perf_counter()
        self._events: List[Dict[str, Any]] = []   # chrome dicts, ts in us
        self._points: Dict[str, List[Tuple[float, float]]] = {}
        self._totals: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._stacks: Dict[Tuple[int, int], List[str]] = {}
        self._procs: Dict[int, str] = {}
        self._threads: Dict[Tuple[int, int], str] = {}
        self._last_us: Dict[Tuple[int, int], float] = {}

    # -- clock ---------------------------------------------------------
    def now(self) -> float:
        """Seconds since this Telemetry was constructed (its epoch)."""
        return time.perf_counter() - self._t0

    def rel(self, t_perf: float) -> float:
        """Convert a raw ``time.perf_counter()`` reading (a timestamp a
        caller captured before/independently of telemetry, e.g. request
        arrival) to epoch seconds."""
        return t_perf - self._t0

    # -- metrics: counters / gauges / histograms -----------------------
    def _record(self, name: str, value: float, t: Optional[float]) -> float:
        if name not in METRICS:
            raise ValueError(
                f"unregistered telemetry metric {name!r}: add it to "
                "repro.serving.telemetry.METRICS")
        t = self.now() if t is None else t
        self._points.setdefault(name, []).append((t, float(value)))
        return t

    def counter(self, name: str, value: float = 1.0,
                t: Optional[float] = None) -> None:
        """Add ``value`` to a monotonic counter (default increment 1)."""
        if not self.enabled:
            return
        self._record(name, value, t)
        self._totals[name] = self._totals.get(name, 0.0) + float(value)

    def gauge(self, name: str, value: float,
              t: Optional[float] = None) -> None:
        """Set a sampled level (last write wins in ``total(name)``)."""
        if not self.enabled:
            return
        self._record(name, value, t)
        self._gauges[name] = float(value)

    def histogram(self, name: str, value: float,
                  t: Optional[float] = None) -> None:
        """Record one observation into a value distribution."""
        if not self.enabled:
            return
        self._record(name, value, t)

    def total(self, name: str) -> float:
        """Counter sum / latest gauge value (0.0 when never recorded)."""
        if name in self._gauges:
            return self._gauges[name]
        return self._totals.get(name, 0.0)

    # -- tracks --------------------------------------------------------
    def ensure_track(self, pid: int, tid: int, name: str) -> None:
        """Register a (pid, tid) track's display name (idempotent)."""
        if not self.enabled:
            return
        self._procs.setdefault(pid, PROCESS_NAMES.get(pid, f"pid {pid}"))
        self._threads.setdefault((pid, tid), name)

    def _emit(self, pid: int, tid: int, ph: str, name: str,
              ts_s: float, args: Optional[Dict[str, Any]] = None,
              **extra) -> None:
        self.ensure_track(pid, tid, f"tid {tid}")
        track = (pid, tid)
        # defensive monotonicity clamp: backdated timestamps (queue-wait
        # spans, coalesced refills) may not step behind the track's last
        # event, or the trace would violate the per-track ordering the
        # validator pins
        us = max(ts_s * 1e6, self._last_us.get(track, 0.0))
        self._last_us[track] = us
        ev = {"name": name, "ph": ph, "pid": pid, "tid": tid, "ts": us}
        if args:
            ev["args"] = dict(args)
        ev.update(extra)
        self._events.append(ev)

    # -- spans / events ------------------------------------------------
    def begin(self, pid: int, tid: int, name: str,
              args: Optional[Dict[str, Any]] = None,
              ts: Optional[float] = None) -> None:
        """Open a nested span on a track (balanced by ``end``)."""
        if not self.enabled:
            return
        self._stacks.setdefault((pid, tid), []).append(name)
        self._emit(pid, tid, "B", name, self.now() if ts is None else ts,
                   args)

    def end(self, pid: int, tid: int, name: str,
            ts: Optional[float] = None) -> None:
        """Close the innermost open span, which must be ``name``."""
        if not self.enabled:
            return
        stack = self._stacks.get((pid, tid), [])
        if not stack or stack[-1] != name:
            raise ValueError(
                f"unbalanced span end: {name!r} on track ({pid}, {tid}) "
                f"but open stack is {stack!r}")
        stack.pop()
        self._emit(pid, tid, "E", name, self.now() if ts is None else ts)

    def span(self, pid: int, tid: int, name: str,
             args: Optional[Dict[str, Any]] = None):
        """``with tel.span(...)``: a balanced B/E pair around the body.
        Off-mode returns the shared ``_NULL_SPAN`` (identity fast-path)."""
        if not self.enabled:
            return _NULL_SPAN
        return _LiveSpan(self, pid, tid, name, args)

    def complete(self, pid: int, tid: int, name: str, ts: float,
                 dur: float,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """A closed interval (chrome "X" event): ``ts`` start seconds,
        ``dur`` length seconds — the one-call span for work already
        timed by the caller (prefill chunks, channel transfers)."""
        if not self.enabled:
            return
        self._emit(pid, tid, "X", name, ts, args,
                   dur=max(0.0, dur) * 1e6)

    def instant(self, pid: int, tid: int, name: str,
                args: Optional[Dict[str, Any]] = None,
                ts: Optional[float] = None) -> None:
        """A point event (preemption, eviction, adoption, rejection)."""
        if not self.enabled:
            return
        self._emit(pid, tid, "i", name, self.now() if ts is None else ts,
                   args, s="t")

    # -- read-out ------------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        """The recorded (non-metadata) chrome events, emission order."""
        return list(self._events)

    def spans(self) -> List[Span]:
        """Reconstruct ``Span`` rows from the recorded B/E/X events
        (open B spans are omitted; X events map 1:1)."""
        out: List[Span] = []
        open_: Dict[Tuple[int, int], List[Tuple[str, float, dict]]] = {}
        for ev in self._events:
            track = (ev["pid"], ev["tid"])
            if ev["ph"] == "B":
                open_.setdefault(track, []).append(
                    (ev["name"], ev["ts"], ev.get("args", {})))
            elif ev["ph"] == "E" and open_.get(track):
                name, t0, args = open_[track].pop()
                out.append(Span(track[0], track[1], name, t0 / 1e6,
                                ev["ts"] / 1e6, args))
            elif ev["ph"] == "X":
                out.append(Span(track[0], track[1], ev["name"],
                                ev["ts"] / 1e6,
                                (ev["ts"] + ev.get("dur", 0.0)) / 1e6,
                                ev.get("args", {})))
        out.sort(key=lambda s: (s.pid, s.tid, s.t0_s))
        return out

    def series(self, metric: str, bucket_s: float) -> List[SeriesPoint]:
        """Rolling time series of one metric, bucketed to ``bucket_s``-
        second windows aligned to the Telemetry epoch."""
        assert bucket_s > 0
        buckets: Dict[int, List[float]] = {}
        for t, v in self._points.get(metric, []):
            b = int(t // bucket_s)
            row = buckets.get(b)
            if row is None:
                buckets[b] = [v, 1, v]
            else:
                row[0] += v
                row[1] += 1
                row[2] = v
        return [SeriesPoint(b * bucket_s, row[0], int(row[1]), row[2])
                for b, row in sorted(buckets.items())]

    def hist(self, metric: str,
             bucket_s: Optional[float] = None) -> List[Dict[str, float]]:
        """Windowed histogram summaries (count/mean/p50/p95/max) of one
        ``histogram`` metric; ``bucket_s=None`` summarises the whole run
        as a single window at ``t_s=0``."""
        from repro.core.metrics import percentile
        pts = self._points.get(metric, [])
        if bucket_s is None:
            groups = {0.0: [v for _, v in pts]} if pts else {}
        else:
            groups = {}
            for t, v in pts:
                groups.setdefault(int(t // bucket_s) * bucket_s,
                                  []).append(v)
        return [{"t_s": t, "count": float(len(vs)),
                 "mean": sum(vs) / len(vs),
                 "p50": percentile(vs, 50), "p95": percentile(vs, 95),
                 "max": max(vs)}
                for t, vs in sorted(groups.items())]

    # -- predictor scoreboard ------------------------------------------
    def predictor_window(self, tp: int, fp: int, fn: int,
                         t: Optional[float] = None) -> None:
        """Report one MoE-layer visit's predicted-vs-routed confusion
        counts (the engine computes them via
        :func:`repro.core.metrics.f1_over_window`)."""
        if not self.enabled:
            return
        self.counter("predictor.tp", tp, t=t)
        self.counter("predictor.fp", fp, t=t)
        self.counter("predictor.fn", fn, t=t)

    def scoreboard(self, bucket_s: float = 0.25) -> Dict[str, Any]:
        """Per-window predictor precision/recall/F1 + tier-0/1 hit rate.

        Windows bucket the ``predictor.*`` and ``cache.t01_*`` series;
        the ``total`` row is computed from the *summed* counts, so the
        per-window rows aggregate exactly to the run-level figures (the
        acceptance pin: micro-averaged F1 composes over count sums,
        unlike averaging per-window F1 values)."""
        from repro.core.metrics import prf_from_counts
        names = ("predictor.tp", "predictor.fp", "predictor.fn",
                 "cache.t01_hit", "cache.t01_miss")
        per: Dict[str, Dict[float, float]] = {}
        keys = set()
        for n in names:
            per[n] = {p.t_s: p.total for p in self.series(n, bucket_s)}
            keys.update(per[n])

        def row(t_s: Optional[float], get) -> Dict[str, float]:
            tp, fp, fn = (get("predictor.tp"), get("predictor.fp"),
                          get("predictor.fn"))
            hits, misses = get("cache.t01_hit"), get("cache.t01_miss")
            precision, recall, f1 = prf_from_counts(tp, fp, fn)
            out = {"tp": tp, "fp": fp, "fn": fn,
                   "precision": precision, "recall": recall, "f1": f1,
                   "t01_hits": hits, "t01_misses": misses,
                   "t01_hit_rate": hits / max(hits + misses, 1)}
            if t_s is not None:
                out["t_s"] = t_s
            return out

        windows = [row(t, lambda n, t=t: per[n].get(t, 0.0))
                   for t in sorted(keys)]
        total = row(None, lambda n: sum(per[n].values()))
        return {"bucket_s": bucket_s, "windows": windows, "total": total}

    # -- exporters -----------------------------------------------------
    def to_chrome_trace(self) -> Dict[str, Any]:
        """Chrome/Perfetto ``trace_event`` JSON (object form). Open B
        spans are closed with synthetic E events in the *export* only —
        recording may continue afterwards. Extra top-level keys (the
        bench attaches ``scoreboard``/``meta``) are ignored by viewers."""
        evs: List[Dict[str, Any]] = []
        for pid, pname in sorted(self._procs.items()):
            evs.append({"name": "process_name", "ph": "M", "pid": pid,
                        "tid": 0, "ts": 0.0, "args": {"name": pname}})
        for (pid, tid), tname in sorted(self._threads.items()):
            evs.append({"name": "thread_name", "ph": "M", "pid": pid,
                        "tid": tid, "ts": 0.0, "args": {"name": tname}})
        evs.extend(dict(ev) for ev in self._events)
        for (pid, tid), stack in self._stacks.items():
            ts = self._last_us.get((pid, tid), 0.0)
            for name in reversed(stack):
                evs.append({"name": name, "ph": "E", "pid": pid,
                            "tid": tid, "ts": ts,
                            "args": {"auto_closed": True}})
        return {"traceEvents": evs, "displayTimeUnit": "ms"}


#: The shared disabled instance: engines without a configured telemetry
#: all point here, so "is telemetry off?" is one identity/attribute check
#: and off-mode runs record nothing, ever.
NULL_TELEMETRY = Telemetry(enabled=False)
