"""Serving-engine configuration shared by the scheduler and DecodeCore.

One place for the knobs that shape the paged serving engine: batching, the
block-paged KV layout, chunked prefill, and — since the paged flash-decode
kernel — which *read path* every paged attention layer compiles to.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Union

from repro.serving.expertstore import TierConfig
from repro.serving.telemetry import Telemetry
from repro.serving.workload import SLO


@dataclass(frozen=True)
class ServeConfig:
    """Knobs for ``BatchedOffloadEngine`` / ``DecodeCore``.

    Batching & KV paging:
      * ``max_batch`` — decode lanes (requests) per step; also sizes the
        scratch row/bucketed jit programs.
      * ``paged`` — True (default) pages KV into blocks and absorbs
        prompts via chunked prefill; False keeps the PR-1 contiguous
        fixed-row engine.
      * ``block_size`` — token positions per KV block.
      * ``kv_blocks`` — pool capacity in blocks, *including* the reserved
        scratch block 0 (None -> worst case: ``max_batch`` full-length
        requests + scratch). Smaller pools admit by block reservation.
      * ``prefill_chunk`` — max prompt tokens per chunked-prefill program
        (clamped so a chunk never pins more than ``capacity`` experts).

    Paged attention read path (``use_kernel`` / ``kernel_backend``):
      * ``use_kernel=False`` — the PR-2 gather route (materialise each
        lane's pages, dense attend): the parity reference / escape hatch.
      * ``use_kernel=True`` (default) — the paged flash-decode kernel.
        ``kernel_backend`` picks its implementation: "tpu" (compiled
        Pallas), "pallas" (interpret-mode Pallas — CI validation), "jnp"
        (the lax.scan flash twin), or None to auto-select "tpu" on TPU and
        "jnp" elsewhere.

    Prefix sharing:
      * ``prefix_cache`` turns on the radix prefix index
        (serving/prefixcache.py): common block-aligned prompt prefixes are
        detected at admission, matched KV blocks are adopted copy-on-write
        instead of re-prefilled, and the prefix's recorded expert
        activations are replayed into the policy / ExpertCache. Needs the
        chunk-prefill-capable paged engine; stacks with ring/recurrent
        layers silently keep the cache off.
      * ``prefix_cache_blocks`` soft-caps how many pool blocks the index
        may keep alive (None -> bounded only by pool pressure; LRU
        zero-extra-ref prefixes are evicted when admission needs their
        blocks either way).

    Expert storage:
      * ``replacement`` — eviction policy for the tier-0 expert slots and
        the tier-1 host cache: "lru" (default), "lfu", or "learned". In
        learned mode a :class:`~repro.core.policies.ReuseDistanceScorer`
        fed by the multi-horizon predictor picks the unpinned key
        predicted furthest from reuse (LRU tiebreak; exact-LRU fallback
        when no prediction covers any candidate). Streams stay
        token-identical across policies — only the miss/stall timeline
        moves.
      * ``tiers`` (a :class:`~repro.serving.expertstore.TierConfig`) swaps
        the single-host expert store for the tiered device/host/peer/disk
        hierarchy: consistent-hash expert->shard placement, per-tier
        bandwidth/latency fetch channels, and horizon-aware prefetch whose
        lookahead depth scales with the tier a predicted expert resides
        in. ``None`` keeps one host's DRAM holding every expert. The
        carried ``TierConfig.dispatch`` mode (``"fetch"``/``"ship"``/
        ``"auto"``) additionally chooses, per (expert, token-count), between
        pulling a peer-resident expert's weights and shipping the token
        group to the peer for remote compute — priced by the
        :class:`~repro.serving.expertstore.DispatchPlanner` roofline;
        streams stay token-identical across modes.
      * ``layer_compute_s`` drives the OverlapTracker's modeled compute
        clock: a float (seconds per layer) is the legacy uniform knob;
        ``"roofline"`` derives per-layer times from the dry-run's analytic
        roofline; ``"measured"`` rescales the roofline shape by measured
        step walltimes.

    Scheduling under load (PR 6):
      * ``preemption`` — allow admission to evict a strictly
        lower-priority running request (its prompt blocks are published to
        the prefix index first, so the re-prefill on resume replays as
        cache hits) when a more urgent request cannot get a lane or a
        block reservation. Preempted streams stay token-identical to
        never-preempted runs. Off by default: FIFO block-granular
        admission, exactly the pre-PR-6 behaviour.
      * ``default_priority`` — priority for requests that don't specify
        one (lower = more urgent; only relative order matters).
      * ``default_slo`` — :class:`~repro.serving.workload.SLO` budgets
        applied to requests that don't carry their own (None = none).

    Observability:
      * ``telemetry`` — a :class:`~repro.serving.telemetry.Telemetry`
        event bus the scheduler, engine, expert store, KV pool, prefix
        cache and overlap tracker emit into (per-request span timelines,
        Chrome-trace export, the predictor scoreboard). ``None``
        (default) routes every emission to the shared no-op
        ``NULL_TELEMETRY`` singleton — zero events recorded, streams and
        stats identical to an un-instrumented build.
    """
    max_batch: int = 4
    paged: bool = True
    block_size: int = 8
    kv_blocks: Optional[int] = None
    prefill_chunk: int = 8
    use_kernel: bool = True
    kernel_backend: Optional[str] = None
    prefix_cache: bool = False
    prefix_cache_blocks: Optional[int] = None
    replacement: str = "lru"
    tiers: Optional[TierConfig] = None
    layer_compute_s: Union[float, str] = 0.0
    preemption: bool = False
    default_priority: int = 0
    default_slo: Optional[SLO] = None
    telemetry: Optional[Telemetry] = None

    def resolve_kernel(self) -> Optional[str]:
        """The backend string the engine threads into jitted attention
        programs — None means the gather reference path."""
        if self.kernel_backend not in (None, "jnp", "pallas", "tpu"):
            raise ValueError(
                f"unknown kernel_backend {self.kernel_backend!r}")
        if not self.use_kernel:
            return None
        if self.kernel_backend is None:
            from repro.kernels.runtime import default_kernel_backend
            return default_kernel_backend()
        return self.kernel_backend
