"""Serving-engine configuration shared by the scheduler and DecodeCore.

One place for the knobs that shape the paged serving engine: batching, the
block-paged KV layout, chunked prefill, and — since the paged flash-decode
kernel — which *read path* every paged attention layer compiles to.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Union

from repro.serving.expertstore import TierConfig


@dataclass(frozen=True)
class ServeConfig:
    """Knobs for ``BatchedOffloadEngine`` / ``DecodeCore``.

    use_kernel / kernel_backend drive the paged attention read path:
      * ``use_kernel=False`` — the PR-2 gather route (materialise each
        lane's pages, dense attend): the parity reference / escape hatch.
      * ``use_kernel=True`` (default) — the paged flash-decode kernel.
        ``kernel_backend`` picks its implementation: "tpu" (compiled
        Pallas), "pallas" (interpret-mode Pallas — CI validation), "jnp"
        (the lax.scan flash twin), or None to auto-select "tpu" on TPU and
        "jnp" elsewhere.

    prefix_cache turns on prefix sharing (serving/prefixcache.py): common
    block-aligned prompt prefixes are detected at admission, matched KV
    blocks are adopted copy-on-write instead of re-prefilled, and the
    prefix's recorded expert activations are replayed into the policy /
    ExpertCache. ``prefix_cache_blocks`` soft-caps how many pool blocks the
    index may keep alive (None -> bounded only by pool pressure; LRU
    zero-extra-ref prefixes are evicted when admission needs their blocks
    either way). Needs the chunk-prefill-capable paged engine; stacks with
    ring/recurrent layers silently keep the cache off.

    tiers (a :class:`~repro.serving.expertstore.TierConfig`) swaps the
    single-host expert store for the tiered device/host/peer/disk
    hierarchy: consistent-hash expert->shard placement, per-tier
    bandwidth/latency fetch channels, and horizon-aware prefetch whose
    lookahead depth scales with the tier a predicted expert resides in.
    ``None`` keeps one host's DRAM holding every expert.

    layer_compute_s drives the OverlapTracker's modeled compute clock: a
    float is the legacy uniform knob; ``"roofline"`` derives per-layer
    times from the dry-run's analytic roofline; ``"measured"`` rescales
    the roofline shape by measured step walltimes.
    """
    max_batch: int = 4
    paged: bool = True
    block_size: int = 8
    kv_blocks: Optional[int] = None
    prefill_chunk: int = 8
    use_kernel: bool = True
    kernel_backend: Optional[str] = None
    prefix_cache: bool = False
    prefix_cache_blocks: Optional[int] = None
    tiers: Optional[TierConfig] = None
    layer_compute_s: Union[float, str] = 0.0

    def resolve_kernel(self) -> Optional[str]:
        """The backend string the engine threads into jitted attention
        programs — None means the gather reference path."""
        if self.kernel_backend not in (None, "jnp", "pallas", "tpu"):
            raise ValueError(
                f"unknown kernel_backend {self.kernel_backend!r}")
        if not self.use_kernel:
            return None
        if self.kernel_backend is None:
            from repro.kernels.runtime import default_kernel_backend
            return default_kernel_backend()
        return self.kernel_backend
