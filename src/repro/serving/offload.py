"""Expert offloading: host-resident expert store + device-resident slot
buffer (the TPU adaptation of the paper's VRAM expert cache, DESIGN.md §4).

HostExpertStore keeps every MoE layer's expert weights as host numpy arrays
(= "host DRAM"). SlotBuffer is a fixed-capacity stack of expert weight slots
living on device (= "HBM"); fetching an expert is a host->device
``device_put`` into a slot. The control plane (which expert sits in which
slot, eviction order, prefetch decisions) is core.cache.ExpertCache.
HostExpertStore is also the single-host degenerate of the expert-store
interface — serving/expertstore.py generalises it to the tiered
device/host/peer/disk hierarchy behind the same ``fetch``/``demote``
calls, which is why SlotBuffer routes through ``store.fetch`` and demotes
on release.

Overlap model: the engines prefetch predicted experts before the layers
that need them run, double-buffering the slot stack — filled slots for
layer i+1 land while layer i computes (slow-tier experts are submitted
additional layers early, see the horizon-aware prefetch in
serving/engine.py). OverlapTracker models one serial async channel *per
storage tier* against a shared compute clock: ``submit`` queues a transfer
on its tier's channel, ``advance`` credits compute time that hides it,
``wait`` charges only the un-overlapped remainder as stall, attributed to
the critical tier (``stall_by_tier``). With zero credited compute the
stall degenerates to the blocking demand-fetch model
(``SlotBuffer.sim_fetch_s``) — tests pin both ends.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.cache import ExpertCache
from repro.serving.telemetry import (NULL_TELEMETRY, PID_CHANNELS,
                                     PID_ENGINE)

Key = Tuple[int, int]  # (moe_layer_index, expert_id)

# Storage tiers, from the serving process's point of view. Tier 0 (the
# device slot buffer) is the ExpertCache/SlotBuffer's business; a *store*
# serves fetches from tier 1 (local host DRAM), tier 2 (a peer host's DRAM
# over the interconnect) or tier 3 (disk/mmap spill). HostExpertStore is
# the degenerate single-host store: everything is tier 1. The full
# hierarchy lives in serving/expertstore.py.
TIER_DEVICE, TIER_HOST, TIER_PEER, TIER_DISK = 0, 1, 2, 3

# Pseudo-tier for the OverlapTracker's *ship* channel: instead of fetching
# a peer-resident expert's weights (tier 2), the engine may ship the token
# activations to the peer, compute the expert FFN there, and pull the
# outputs back (serving/expertstore.DispatchPlanner prices the two paths).
# Ship traffic rides its own serial channel so stall/overlap attribution
# separates "waiting on weights" from "waiting on remote compute".
CHANNEL_SHIP = 4

# Telemetry track names for the per-tier serial channels (the async
# tracks a Chrome-trace export shows under the "channels" process)
CHANNEL_NAMES = {
    TIER_HOST: "tier1 host->device",
    TIER_PEER: "tier2 peer->device",
    TIER_DISK: "tier3 disk->device",
    CHANNEL_SHIP: "ship tokens->peer",
}


@dataclass
class FetchInfo:
    """Where a store served an expert fetch from, and the modeled cost.

    ``duration`` is the modeled transfer time for the whole path into the
    device slot; ``None`` means "use the caller's host-bandwidth model"
    (the single-host back-compat default)."""
    tier: int
    nbytes: int
    duration: Optional[float] = None


class HostExpertStore:
    """Expert FFN weights per MoE layer, host-side.

    Also the reference implementation of the *expert store* interface the
    engines consume (``fetch``/``tier_of``/``demote``/``prefetch_horizon``):
    one host's DRAM holds every expert, so every fetch is a tier-1 hit and
    there is nothing to demote into. ``serving/expertstore.py`` generalises
    this to the device/host/peer/disk hierarchy behind the same interface.
    """

    #: how many MoE layers ahead prefetch needs to look for this store —
    #: one layer of compute is enough to hide a host->device transfer
    max_horizon = 1

    def __init__(self, expert_params_per_layer):
        """expert_params_per_layer: list (per MoE layer) of dicts with
        w_gate/w_up/w_down of shape (E, d, f)/(E, d, f)/(E, f, d)."""
        self.layers = [
            {k: np.asarray(v) for k, v in lp.items()
             if k in ("w_gate", "w_up", "w_down")}
            for lp in expert_params_per_layer
        ]
        self.num_layers = len(self.layers)
        self.num_experts = self.layers[0]["w_gate"].shape[0]
        lp = self.layers[0]
        self.bytes_per_expert = sum(
            lp[k][0].nbytes for k in ("w_gate", "w_up", "w_down"))

    def get(self, key: Key):
        layer, e = key
        lp = self.layers[layer]
        return (lp["w_gate"][e], lp["w_up"][e], lp["w_down"][e])

    # --- store interface --------------------------------------------------
    def fetch(self, key: Key):
        """(weights, FetchInfo): everything lives in local DRAM."""
        w = self.get(key)
        return w, FetchInfo(TIER_HOST, self.bytes_per_expert)

    def tier_of(self, key: Key) -> int:
        return TIER_HOST

    def prefetch_horizon(self, key: Key) -> int:
        return 1

    def demote(self, key: Key) -> None:
        """Tier-0 eviction callback: the DRAM copy already exists."""


class OverlapTracker:
    """Modeled timeline of the async fetch channels against a compute clock.

    ``clock`` is modeled compute time. Each storage *tier* owns one serial
    fetch channel (host->device DMA, the peer interconnect, the disk queue);
    transfers submitted to a tier queue on that tier's ``channel_free``
    while different tiers' transfers overlap each other. A transfer
    submitted at compute time t starts at max(t, channel_free[tier]) and
    completes ``duration`` later. ``wait`` advances the clock to the
    completion time of the latest needed transfer, charging the gap as
    stall — exactly the part of the fetch NOT hidden by compute — and
    attributes that stall to the tier of the transfer that finished last
    (the critical path), so stall reports break down by tier.

    The single-tier default (every ``submit`` at tier 1, duration from
    ``host_bw``) reproduces the original one-serial-channel model exactly.

    Identical pending keys coalesce: when a key is re-submitted while its
    previous transfer is still on the wire (the slot was dropped before
    the modeled completion, then the key was demanded again), the new
    request rides the in-flight transfer instead of queueing a second
    serial one — unless a fresh fetch would land *earlier* (the store may
    now serve the key from a faster tier), in which case the fresh fetch
    wins. ``fetches_deduped`` counts the coalesced submissions.
    """

    def __init__(self, host_bw: float = 100e9, telemetry=None):
        self.host_bw = host_bw
        # telemetry: each real (non-coalesced) submission becomes one
        # "X" event on its tier's channel track, timed on the MODELED
        # clock — serial channels make each track's timestamps monotonic
        self.tel = telemetry if telemetry is not None else NULL_TELEMETRY
        self.clock = 0.0
        self._channel_free: Dict[int, float] = {}  # tier -> busy-until time
        self.pending: Dict[Key, float] = {}   # key -> modeled completion time
        self._dur: Dict[Key, float] = {}      # key -> transfer duration
        self._tier: Dict[Key, int] = {}       # key -> submitting tier
        # key -> (completion, duration, tier) of the latest transfer put on
        # the wire, surviving ``drop``: bytes in flight don't vanish when
        # their slot is released, so a re-submit can ride them
        self._wire: Dict[Key, Tuple[float, float, int]] = {}
        self.fetches_deduped = 0
        self.stall_s = 0.0
        self.overlapped_s = 0.0               # transfer time hidden by compute
        self.stall_by_tier: Dict[int, float] = {}
        self.overlapped_by_tier: Dict[int, float] = {}

    @property
    def channel_free(self) -> float:
        """Latest busy-until time across tier channels (back-compat view
        of the original single-channel attribute)."""
        return max(self._channel_free.values(), default=0.0)

    def submit(self, key: Key, nbytes: int, tier: int = TIER_HOST,
               duration: Optional[float] = None,
               coalesce: bool = True) -> bool:
        """Queue a transfer for ``key``; returns True when it coalesced
        onto an identical transfer already in flight (no new channel time
        or bytes charged). ``coalesce=False`` (the ship channel) neither
        rides nor leaves a wire record: shipped bytes are this step's
        activations/outputs, never re-servable to a later requester the
        way an in-flight weight transfer is."""
        dur = nbytes / self.host_bw if duration is None else duration
        if len(self._wire) > 4 * (len(self.pending) + 8):
            self._prune_wire()
        fresh = max(self.clock, self._channel_free.get(tier, 0.0)) + dur
        if coalesce:
            wire = self._wire.get(key)
            if wire is not None and self.clock < wire[0] <= fresh:
                # same bytes already on the wire and landing no later than
                # a fresh fetch would: ride them
                self.pending[key] = wire[0]
                self._dur[key] = wire[1]
                self._tier[key] = wire[2]
                self.fetches_deduped += 1
                return True
        self._channel_free[tier] = fresh
        self.pending[key] = fresh
        self._dur[key] = dur
        self._tier[key] = tier
        if coalesce:
            self._wire[key] = (fresh, dur, tier)
        if self.tel.enabled:
            self.tel.ensure_track(PID_CHANNELS, tier,
                                  CHANNEL_NAMES.get(tier, f"tier{tier}"))
            name = "ship" if tier == CHANNEL_SHIP else "fetch"
            self.tel.complete(PID_CHANNELS, tier, name, fresh - dur, dur,
                              {"key": str(key), "bytes": int(nbytes),
                               "tier": tier})
            self.tel.counter("ship.bytes" if tier == CHANNEL_SHIP
                             else "fetch.bytes", int(nbytes))
        return False

    def _prune_wire(self) -> None:
        """Drop wire records of transfers that have already landed."""
        self._wire = {k: v for k, v in self._wire.items()
                      if v[0] > self.clock}

    def drop(self, key: Key) -> None:
        """Forget a pending transfer (its slot was released before use).
        The wire record survives: the bytes are still in flight and a
        re-submit may coalesce onto them."""
        self.pending.pop(key, None)
        self._dur.pop(key, None)
        self._tier.pop(key, None)

    def advance(self, compute_s: float) -> None:
        """Compute time that overlaps any in-flight transfers."""
        self.clock += compute_s

    def wait(self, keys: Iterable[Key]) -> float:
        """Block until every needed key's transfer has landed; returns the
        stall charged for this wait."""
        needed = [k for k in keys if k in self.pending]
        if not needed:
            return 0.0
        done = {k: self.pending.pop(k) for k in needed}
        t = max(done.values())
        crit_tier = self._tier.get(max(done, key=done.get), TIER_HOST)
        stall = max(0.0, t - self.clock)
        self.stall_s += stall
        self.stall_by_tier[crit_tier] = (
            self.stall_by_tier.get(crit_tier, 0.0) + stall)
        if self.tel.enabled and stall > 0:
            self.tel.counter("stall.s", stall)
            self.tel.instant(PID_ENGINE, 1, "stall",
                             {"stall_s": stall,
                              "critical_tier": crit_tier})
        # transfer time not hidden by compute is stall; distribute the
        # hidden remainder over tiers, absorbing the stall into the
        # latest-completing transfers first (the critical path)
        remaining = stall
        for k in sorted(needed, key=done.get, reverse=True):
            dur = self._dur.pop(k, 0.0)
            tier = self._tier.pop(k, TIER_HOST)
            absorbed = min(dur, remaining)
            remaining -= absorbed
            self.overlapped_s += dur - absorbed
            self.overlapped_by_tier[tier] = (
                self.overlapped_by_tier.get(tier, 0.0) + dur - absorbed)
        self.clock = max(self.clock, t)
        return stall


class SlotBuffer:
    """Fixed-capacity device buffer of expert slots + host slot table.

    ``store`` is anything implementing the expert-store interface
    (``HostExpertStore`` or ``serving/expertstore.TieredExpertStore``):
    ``fill`` pulls weights through ``store.fetch`` — charging the modeled
    transfer to the source tier's channel — and ``release`` (the tier-0
    eviction callback) *demotes* the expert into the store's host-side
    cache instead of dropping it, so a re-fetch is served from tier 1
    rather than the slow tier it originally came from.

    ``ship_slots`` appends that many *ephemeral* rows past the
    cache-managed ``n_slots``: the compute-dispatch path (``dispatch=
    "ship"``/``"auto"``) stages a peer-resident expert's weights there for
    exactly one expert-FFN program — the rows model the peer's own copy,
    are never registered in ``slot_of``/the ExpertCache, charge no fetch
    bytes, and are overwritten freely by the next step's shipped group.
    Running the shipped experts through the SAME jitted slot-gather
    program as resident ones is what keeps fetch/ship streams bit
    identical."""

    def __init__(self, store: HostExpertStore, n_slots: int,
                 host_bw: float = 100e9,
                 tracker: Optional[OverlapTracker] = None,
                 ship_slots: int = 0):
        lp = store.layers[0]
        e, d, f = lp["w_gate"].shape
        self.store = store
        self.n_slots = n_slots
        self.ship_slots = ship_slots
        self.host_bw = host_bw
        self.tracker = tracker
        rows = n_slots + ship_slots
        self.w_gate = jnp.zeros((rows, d, f), lp["w_gate"].dtype)
        self.w_up = jnp.zeros((rows, d, f), lp["w_up"].dtype)
        self.w_down = jnp.zeros((rows, f, d), lp["w_down"].dtype)
        self.slot_of: Dict[Key, int] = {}
        self._free = list(range(n_slots))
        self.fetch_bytes = 0
        self.fetch_count = 0
        self.fetches_deduped = 0     # fills that rode an in-flight transfer
        self.sim_fetch_s = 0.0       # blocking model: every fetch stalls

    # --- control-plane callbacks wired into ExpertCache -------------------
    def release(self, key: Key) -> None:
        slot = self.slot_of.pop(key)
        self._free.append(slot)
        if self.tracker is not None:
            self.tracker.drop(key)
        self.store.demote(key)

    def fill(self, key: Key) -> None:
        slot = self._free.pop()
        self.slot_of[key] = slot
        (wg, wu, wd), info = self.store.fetch(key)
        self.w_gate = self.w_gate.at[slot].set(jnp.asarray(wg))
        self.w_up = self.w_up.at[slot].set(jnp.asarray(wu))
        self.w_down = self.w_down.at[slot].set(jnp.asarray(wd))
        nbytes = wg.nbytes + wu.nbytes + wd.nbytes
        dur = (info.duration if info.duration is not None
               else nbytes / self.host_bw)
        coalesced = False
        if self.tracker is not None:
            coalesced = self.tracker.submit(key, nbytes, tier=info.tier,
                                            duration=dur)
        if coalesced:
            # the key's bytes were already in flight on this tier's channel
            # (slot released before the modeled transfer completed): no new
            # traffic is charged
            self.fetches_deduped += 1
        else:
            self.fetch_bytes += nbytes
            self.fetch_count += 1
        # the blocking model has no in-flight transfers to ride, so every
        # fetch stalls fully — keep it the upper bound
        self.sim_fetch_s += dur

    def fill_ship(self, idx: int, weights) -> int:
        """Stage shipped-expert weights in ephemeral row ``idx`` (0-based
        within the ship region); returns the absolute slot id to feed the
        expert program. No slot table entry, no fetch accounting — the
        modeled cost of the round trip is the ship channel's business
        (``OverlapTracker.submit`` at ``CHANNEL_SHIP``)."""
        assert 0 <= idx < self.ship_slots, \
            f"ship row {idx} out of range (ship_slots={self.ship_slots})"
        slot = self.n_slots + idx
        wg, wu, wd = weights
        self.w_gate = self.w_gate.at[slot].set(jnp.asarray(wg))
        self.w_up = self.w_up.at[slot].set(jnp.asarray(wu))
        self.w_down = self.w_down.at[slot].set(jnp.asarray(wd))
        return slot

    def gather(self, keys) -> tuple:
        """Return (k, ...) stacked expert weights for resident keys."""
        slots = jnp.asarray([self.slot_of[k] for k in keys], jnp.int32)
        return (jnp.take(self.w_gate, slots, 0),
                jnp.take(self.w_up, slots, 0),
                jnp.take(self.w_down, slots, 0))

    def slot_ids(self, keys) -> np.ndarray:
        """Host-side slot indices for resident keys (batched gather path)."""
        return np.asarray([self.slot_of[k] for k in keys], np.int32)


def make_offload_cache(store: HostExpertStore, capacity: int,
                       eviction: str = "lru", host_bw: float = 100e9,
                       tracker: Optional[OverlapTracker] = None,
                       scorer=None, ship_slots: int = 0, telemetry=None):
    """(ExpertCache, SlotBuffer) wired together. ``scorer`` (a
    ``core.policies.ReuseDistanceScorer``) is required for
    ``eviction="learned"`` — the engine feeds it the multi-horizon
    prediction window so tier-0 eviction picks the key predicted furthest
    from reuse. ``ship_slots`` sizes the buffer's ephemeral
    compute-dispatch rows (see :class:`SlotBuffer`). ``telemetry`` (a
    ``serving.telemetry.Telemetry``) lets the cache report evictions with
    victim provenance."""
    buf = SlotBuffer(store, capacity, host_bw, tracker,
                     ship_slots=ship_slots)
    cache = ExpertCache(capacity, eviction, on_evict=buf.release,
                        on_insert=buf.fill, scorer=scorer,
                        telemetry=telemetry)
    return cache, buf
