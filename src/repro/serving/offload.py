"""Expert offloading: host-resident expert store + device-resident slot
buffer (the TPU adaptation of the paper's VRAM expert cache, DESIGN.md §4).

HostExpertStore keeps every MoE layer's expert weights as host numpy arrays
(= "host DRAM"). SlotBuffer is a fixed-capacity stack of expert weight slots
living on device (= "HBM"); fetching an expert is a host->device
``device_put`` into a slot. The control plane (which expert sits in which
slot, eviction order, prefetch decisions) is core.cache.ExpertCache.

Overlap model: the engines prefetch the *next* MoE layer's predicted experts
before the current layer's attention runs, double-buffering the slot stack —
filled slots for layer i+1 land while layer i computes. OverlapTracker
models the single serial host->device channel against a compute clock:
``submit`` queues a transfer, ``advance`` credits compute time that hides it,
``wait`` charges only the un-overlapped remainder as stall. With zero
credited compute the stall degenerates to the blocking demand-fetch model
(``SlotBuffer.sim_fetch_s``) — tests pin both ends.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.cache import ExpertCache

Key = Tuple[int, int]  # (moe_layer_index, expert_id)


class HostExpertStore:
    """Expert FFN weights per MoE layer, host-side."""

    def __init__(self, expert_params_per_layer):
        """expert_params_per_layer: list (per MoE layer) of dicts with
        w_gate/w_up/w_down of shape (E, d, f)/(E, d, f)/(E, f, d)."""
        self.layers = [
            {k: np.asarray(v) for k, v in lp.items()
             if k in ("w_gate", "w_up", "w_down")}
            for lp in expert_params_per_layer
        ]
        self.num_layers = len(self.layers)
        self.num_experts = self.layers[0]["w_gate"].shape[0]
        lp = self.layers[0]
        self.bytes_per_expert = sum(
            lp[k][0].nbytes for k in ("w_gate", "w_up", "w_down"))

    def get(self, key: Key):
        layer, e = key
        lp = self.layers[layer]
        return (lp["w_gate"][e], lp["w_up"][e], lp["w_down"][e])


class OverlapTracker:
    """Modeled timeline of one serial host->device fetch channel.

    ``clock`` is modeled compute time; transfers queue on ``channel_free``.
    A transfer submitted at compute time t starts at max(t, channel_free)
    and completes transfer_s later. ``wait`` advances the clock to the
    completion time of the latest needed transfer, charging the gap as
    stall — exactly the part of the fetch NOT hidden by compute.
    """

    def __init__(self, host_bw: float = 100e9):
        self.host_bw = host_bw
        self.clock = 0.0
        self.channel_free = 0.0
        self.pending: Dict[Key, float] = {}   # key -> modeled completion time
        self._dur: Dict[Key, float] = {}      # key -> transfer duration
        self.stall_s = 0.0
        self.overlapped_s = 0.0               # transfer time hidden by compute

    def submit(self, key: Key, nbytes: int) -> None:
        start = max(self.clock, self.channel_free)
        dur = nbytes / self.host_bw
        self.channel_free = start + dur
        self.pending[key] = start + dur
        self._dur[key] = dur

    def advance(self, compute_s: float) -> None:
        """Compute time that overlaps any in-flight transfers."""
        self.clock += compute_s

    def wait(self, keys: Iterable[Key]) -> float:
        """Block until every needed key's transfer has landed; returns the
        stall charged for this wait."""
        needed = [k for k in keys if k in self.pending]
        if not needed:
            return 0.0
        t = max(self.pending.pop(k) for k in needed)
        dur = sum(self._dur.pop(k, 0.0) for k in needed)
        stall = max(0.0, t - self.clock)
        self.stall_s += stall
        self.overlapped_s += max(0.0, dur - stall)
        self.clock = max(self.clock, t)
        return stall


class SlotBuffer:
    """Fixed-capacity device buffer of expert slots + host slot table."""

    def __init__(self, store: HostExpertStore, n_slots: int,
                 host_bw: float = 100e9,
                 tracker: Optional[OverlapTracker] = None):
        lp = store.layers[0]
        e, d, f = lp["w_gate"].shape
        self.store = store
        self.n_slots = n_slots
        self.host_bw = host_bw
        self.tracker = tracker
        self.w_gate = jnp.zeros((n_slots, d, f), lp["w_gate"].dtype)
        self.w_up = jnp.zeros((n_slots, d, f), lp["w_up"].dtype)
        self.w_down = jnp.zeros((n_slots, f, d), lp["w_down"].dtype)
        self.slot_of: Dict[Key, int] = {}
        self._free = list(range(n_slots))
        self.fetch_bytes = 0
        self.fetch_count = 0
        self.sim_fetch_s = 0.0       # blocking model: every fetch stalls

    # --- control-plane callbacks wired into ExpertCache -------------------
    def release(self, key: Key) -> None:
        slot = self.slot_of.pop(key)
        self._free.append(slot)
        if self.tracker is not None:
            self.tracker.pending.pop(key, None)
            self.tracker._dur.pop(key, None)

    def fill(self, key: Key) -> None:
        slot = self._free.pop()
        self.slot_of[key] = slot
        wg, wu, wd = self.store.get(key)
        self.w_gate = self.w_gate.at[slot].set(jnp.asarray(wg))
        self.w_up = self.w_up.at[slot].set(jnp.asarray(wu))
        self.w_down = self.w_down.at[slot].set(jnp.asarray(wd))
        nbytes = wg.nbytes + wu.nbytes + wd.nbytes
        self.fetch_bytes += nbytes
        self.fetch_count += 1
        self.sim_fetch_s += nbytes / self.host_bw
        if self.tracker is not None:
            self.tracker.submit(key, nbytes)

    def gather(self, keys) -> tuple:
        """Return (k, ...) stacked expert weights for resident keys."""
        slots = jnp.asarray([self.slot_of[k] for k in keys], jnp.int32)
        return (jnp.take(self.w_gate, slots, 0),
                jnp.take(self.w_up, slots, 0),
                jnp.take(self.w_down, slots, 0))

    def slot_ids(self, keys) -> np.ndarray:
        """Host-side slot indices for resident keys (batched gather path)."""
        return np.asarray([self.slot_of[k] for k in keys], np.int32)


def make_offload_cache(store: HostExpertStore, capacity: int,
                       eviction: str = "lru", host_bw: float = 100e9,
                       tracker: Optional[OverlapTracker] = None):
    """(ExpertCache, SlotBuffer) wired together."""
    buf = SlotBuffer(store, capacity, host_bw, tracker)
    cache = ExpertCache(capacity, eviction, on_evict=buf.release,
                        on_insert=buf.fill)
    return cache, buf
