"""Batch-1 offloaded serving engine — the paper's deployment scenario as a
real decode loop, not just a trace simulator.

The decode step is executed layer-by-layer: attention halves are jitted
device programs; before each MoE layer the policy's prediction for that
layer is prefetched into the device slot buffer; the router then reveals the
truth, misses are demand-fetched (stall accounted), and the expert FFN is
computed *from the slot buffer* via the gather path (kernels/expert_ffn).
With capacity == all experts the engine is bit-identical to the monolithic
``model.decode_step`` — tests assert this.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import Policy
from repro.core.tracing import moe_layer_ids
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import transformer as T
from repro.models.common import ffn_apply, rms_norm
from repro.serving.offload import HostExpertStore, make_offload_cache


def unstack_layers(cfg, params) -> List[dict]:
    """Per-layer params list from the scan-stacked pytree."""
    st = params["stack"]
    n_head, n_groups, n_tail = T._layer_split(cfg)
    pat = len(cfg.block_pattern)
    layers = list(st["head"])
    for g in range(n_groups):
        for j in range(pat):
            layers.append(jax.tree.map(lambda x, g=g: x[g], st["scan"][j]))
    layers.extend(st["tail"])
    return layers


@dataclass
class EngineStats:
    tokens: int = 0
    hits: int = 0
    misses: int = 0
    fetch_bytes: int = 0
    sim_stall_s: float = 0.0

    @property
    def hit_rate(self):
        return self.hits / max(self.hits + self.misses, 1)


class OffloadEngine:
    def __init__(self, model, params, policy: Optional[Policy],
                 capacity: int, eviction: str = "lru",
                 host_bw: float = 100e9, expert_backend: str = "jnp"):
        cfg = model.cfg
        assert cfg.moe is not None, "offload engine needs an MoE backbone"
        self.cfg = cfg
        self.model = model
        self.policy = policy
        self.params = params
        self.layers = unstack_layers(cfg, params)
        self.kinds = cfg.layer_kinds()
        self.moe_layers = moe_layer_ids(cfg)
        self.moe_index = {li: i for i, li in enumerate(self.moe_layers)}
        self.expert_backend = expert_backend

        # host store gets the routed-expert weights; everything else stays
        # in self.layers (device)
        store_layers = [self.layers[li]["moe"] for li in self.moe_layers]
        self.store = HostExpertStore(store_layers)
        self.cache, self.slots = make_offload_cache(
            self.store, capacity, eviction, host_bw)
        self.stats = EngineStats()
        self._build_fns()

    # ------------------------------------------------------------------
    def _build_fns(self):
        cfg = self.cfg

        @jax.jit
        def embed_fn(tok_emb, token):
            return jnp.take(tok_emb, token, axis=0)

        @partial(jax.jit, static_argnames=("kind",))
        def attn_half(lp, x, cache, pos, kind):
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
            if kind == "mla":
                o, nc = mla_mod.mla_apply(lp["attn"], cfg, h, positions,
                                          "decode", cache, pos)
            else:
                o, nc = attn_mod.attn_apply(lp["attn"], cfg, kind, h,
                                            positions, "decode", cache, pos)
            return x + o, nc

        @jax.jit
        def dense_ffn_half(lp, x):
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            return x + ffn_apply(lp["ffn"], h, cfg.ffn_kind)

        @jax.jit
        def router_fn(lp, x):
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            w, idx, probs = moe_mod.route(lp["moe"], cfg, h)
            return h, w, idx

        @jax.jit
        def expert_from_slots(x_norm, weights, wg, wu, wd, shared, x):
            # x_norm: (1,1,D); wg/wu: (k,d,f); wd: (k,f,d); weights: (1,1,k)
            from repro.kernels import ops
            y = ops.expert_ffn(x_norm[0, 0], weights[0, 0], wg, wu, wd,
                               backend=self.expert_backend)
            out = x + y[None, None, :]
            if shared is not None:
                out = out + ffn_apply(shared, x_norm, "swiglu")
            return out

        @jax.jit
        def unembed_fn(params, x):
            logits = T.unembed(params, cfg, x)
            return logits

        self._embed = embed_fn
        self._attn_half = attn_half
        self._dense_ffn = dense_ffn_half
        self._router = router_fn
        self._expert = expert_from_slots
        self._unembed = unembed_fn

    # ------------------------------------------------------------------
    def init_state(self, cache_len: int):
        caches = T.stack_cache_init(self.cfg, 1, cache_len,
                                    jnp.dtype(self.cfg.dtype))
        per_layer = unstack_layers(
            self.cfg, {"stack": {"head": caches["head"],
                                 "scan": caches["scan"],
                                 "tail": caches["tail"]}})
        return {"pos": 0, "caches": per_layer}

    def decode_token(self, state, token: int):
        """One token through all layers; returns (logits, state, experts)."""
        cfg = self.cfg
        x = self._embed(self.params["tok_emb"],
                        jnp.full((1, 1), token, jnp.int32))
        pos = state["pos"]
        experts_per_layer = []
        for li in range(cfg.num_layers):
            lp = self.layers[li]
            kind = self.kinds[li]
            x, state["caches"][li] = self._attn_half(
                lp, x, state["caches"][li], pos, kind=kind)
            if li in self.moe_index:
                mi = self.moe_index[li]
                # 1) prefetch what the policy predicts for THIS layer
                if self.policy is not None:
                    pred = self.policy.predict(pos, mi)
                    self.cache.prefetch((mi, int(e)) for e in pred)
                # 2) router reveals ground truth
                h, w, idx = self._router(lp, x)
                gt = np.unique(np.asarray(idx)[0, 0])
                for e in gt:
                    hit = self.cache.access((mi, int(e)))
                    self.stats.hits += int(hit)
                    self.stats.misses += int(not hit)
                # 3) compute from the slot buffer (order matches idx)
                keys = [(mi, int(e)) for e in np.asarray(idx)[0, 0]]
                wg, wu, wd = self.slots.gather(keys)
                x = self._expert(h, w.astype(x.dtype), wg, wu, wd,
                                 lp["moe"].get("shared"), x)
                if self.policy is not None:
                    emb = np.asarray(self.params["tok_emb"][token],
                                     np.float32)
                    self.policy.observe(pos, mi, gt, emb)
                experts_per_layer.append(gt)
            else:
                x = self._dense_ffn(lp, x)
        logits = self._unembed(self.params, x)
        state["pos"] = pos + 1
        self.stats.tokens += 1
        self.stats.fetch_bytes = self.slots.fetch_bytes
        self.stats.sim_stall_s = self.slots.sim_fetch_s
        return np.asarray(logits)[0, 0], state, experts_per_layer

    def generate(self, prompt, max_new: int, cache_len: int,
                 temperature: float = 0.0, seed: int = 0):
        state = self.init_state(cache_len)
        if self.policy is not None:
            self.policy.begin_prompt(None)
        rng = np.random.default_rng(seed)
        out = list(prompt)
        cur = prompt[0]
        n_total = min(len(prompt) + max_new, cache_len)
        generated = []
        for t in range(n_total):
            logits, state, _ = self.decode_token(state, int(cur))
            if t + 1 < len(prompt):
                cur = prompt[t + 1]
            else:
                if temperature <= 0:
                    cur = int(np.argmax(logits))
                else:
                    p = np.exp((logits - logits.max()) / temperature)
                    cur = int(rng.choice(len(p), p=p / p.sum()))
                generated.append(cur)
        return generated
