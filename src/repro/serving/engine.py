"""Offloaded serving engines — the paper's deployment scenario as a real
decode loop, not just a trace simulator.

The decode step is executed layer-by-layer: attention halves are jitted,
*batched* device programs (per-request KV-cache rows gathered/scattered
around a vmapped single-row core, compiled once per padding bucket); the
policy's prediction for MoE layer i+1 is submitted to the host->device
channel before layer i's attention runs, so prefetch transfers overlap
compute (offload.OverlapTracker charges only the un-overlapped remainder
as stall). At each MoE layer the router reveals the truth, misses are
demand-fetched, every expert needed by any in-flight request is *pinned*
in the ExpertCache for the duration of the expert compute, and the expert
FFN runs from the slot buffer via the gather path (kernels/expert_ffn).

``OffloadEngine`` keeps the original batch-1 API on top of the shared
``DecodeCore``; ``serving/scheduler.py`` builds the multi-request
continuous-batching engine on the same core. With capacity == all experts
both are bit-identical to the monolithic ``model.decode_step`` — tests
assert this.

The core speaks two KV layouts: contiguous per-request rows (the batch-1
fallback and ring-buffer kinds), and the **block-paged** layout of
serving/kvpool.py — ``step(..., tables=)`` scatters K/V through per-request
block tables, and ``prefill_chunk`` absorbs a prompt chunk of one request
through the same paged pools (power-of-two chunk buckets, per-token math
identical to decode, so streams stay token-identical). The paged *read*
path compiles to the paged flash-decode kernel
(kernels/paged_attention.py) selected by ``use_kernel``/``kernel_backend``;
``use_kernel=False`` keeps the PR-2 gather-and-materialise route as the
parity reference.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import LatencyStats, f1_over_window
from repro.core.policies import PerRequestPolicy, Policy
from repro.core.tracing import moe_layer_ids
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import transformer as T
from repro.models.common import ffn_apply, rms_norm
from repro.serving.offload import (CHANNEL_SHIP, TIER_HOST, TIER_PEER,
                                   HostExpertStore, OverlapTracker,
                                   make_offload_cache)
from repro.serving.telemetry import (NULL_TELEMETRY, PID_ENGINE,
                                     PID_REQUESTS)


def unstack_layers(cfg, params) -> List[dict]:
    """Per-layer params list from the scan-stacked pytree."""
    st = params["stack"]
    n_head, n_groups, n_tail = T._layer_split(cfg)
    pat = len(cfg.block_pattern)
    layers = list(st["head"])
    for g in range(n_groups):
        for j in range(pat):
            layers.append(jax.tree.map(lambda x, g=g: x[g], st["scan"][j]))
    layers.extend(st["tail"])
    return layers


def sample_token(logits: np.ndarray, temperature: float,
                 rng: np.random.Generator) -> int:
    """Greedy/temperature sampling shared by the batch-1 and batched
    engines — parity between their token streams depends on this being
    one implementation."""
    if temperature <= 0:
        return int(np.argmax(logits))
    p = np.exp((logits - logits.max()) / temperature)
    return int(rng.choice(len(p), p=p / p.sum()))


def bucket_size(n: int, max_batch: int) -> int:
    """Smallest power of two >= n (capped at max_batch) — the padding
    buckets the jitted halves are compiled for."""
    b = 1
    while b < n:
        b *= 2
    return min(b, max(max_batch, n))


@dataclass
class EngineStats:
    """Counters every engine accumulates across runs (the latency summary
    is replaced per run).

    Token & cache traffic:
      * ``tokens`` — all token positions processed (decode + prefill).
      * ``hits`` / ``misses`` — ExpertCache residency at access time; an
        expert needed by several lanes in one step counts once per lane.
      * ``fetch_bytes`` — bytes moved host->device into expert slots over
        the engine's lifetime (coalesced re-fetches of an in-flight
        transfer are NOT re-counted — see ``fetches_deduped``).

    Modeled fetch timeline (seconds of the OverlapTracker's clock):
      * ``sim_stall_s`` — overlap-aware modeled stall: only the part of
        each transfer NOT hidden behind credited compute.
      * ``blocking_stall_s`` — the every-fetch-stalls model (upper bound);
        with zero credited compute ``sim_stall_s`` degenerates to it.
      * ``overlapped_s`` — transfer seconds hidden behind compute.

    Step & prefill accounting:
      * ``steps`` — batched decode steps executed.
      * ``prefill_tokens`` — prompt tokens absorbed by chunked prefill.
      * ``prefill_chunks`` — chunked-prefill programs executed.
      * ``fallback_prefill_tokens`` — prompt tokens that had to stream
        token-by-token through decode because the stack can't
        chunk-prefill (ring/recurrent kinds) or paging is off; excludes
        each prompt's final token (decode runs it on every path to
        produce the first sampled logits).

    Admission & scheduling:
      * ``rejected_requests`` — requests refused at admission because
        their worst case exceeds the whole pool (they retire immediately
        with an empty result instead of aborting the run).
      * ``preemptions`` — evict-and-resume events: a running request's KV
        blocks were released (after publishing to the prefix index) to
        make room for a more urgent waiter; it re-admits later with its
        stream intact.

    Tier breakdowns (tiered expert store; single-host engines report
    everything under tier 1; keys are storage tiers: 1 = local host DRAM,
    2 = peer-host shard over the interconnect, 3 = disk/mmap, 4 = the
    compute-dispatch *ship* channel — token round trips to peer-resident
    experts, so "waiting on remote compute" is attributed separately from
    "waiting on weights"):
      * ``stall_by_tier`` — un-overlapped modeled stall seconds attributed
        to the tier whose transfer finished last (the critical path).
      * ``overlapped_by_tier`` — hidden transfer seconds per tier.
      * ``fetches_by_tier`` / ``fetch_bytes_by_tier`` — fetch counts and
        bytes served per source tier.
      * ``deep_prefetch_hits`` — expert uses served by an entry prefetched
        more than one MoE layer ahead (horizon-aware deep prefetch of
        slow-tier experts).
      * ``fetches_deduped`` — re-fetches coalesced onto a transfer already
        in flight on the same tier channel (the slot was released before
        the modeled transfer completed, then the key was demanded again):
        no second transfer is queued and no bytes are re-charged.

    Compute dispatch (``TierConfig.dispatch`` = ``"ship"``/``"auto"``;
    zero in fetch-only engines):
      * ``ships`` — expert groups computed remotely: the token batch was
        shipped to the peer shard holding the expert instead of the
        expert's weights being fetched (no tier-0 insert, no cache churn).
      * ``ship_bytes`` — activation bytes shipped over the interconnect
        (tokens out + FFN outputs back; compare ``fetch_bytes``).
      * ``ship_tokens`` — tokens computed remotely across all ships.

    Learned replacement & horizon control:
      * ``evictions_learned`` / ``evictions_lru`` — with
        ``replacement="learned"``, tier-0 slot evictions whose victim
        choice was prediction-informed vs the pure-LRU fallback (mirrors
        :class:`~repro.core.cache.CacheStats`; the store's tier-1 cache
        keeps its own split in StoreStats).
      * ``horizon_clamps`` — deep-prefetch submissions cut short because a
        distance's new keys would not fit the tier-0 slots left over after
        the distance-0 working set and the in-flight pins — the
        anti-thrash guard for admission-minimum capacity.

    Per-run latency:
      * ``latency`` — the latest run's :class:`~repro.core.metrics
        .LatencyStats` (TTFT/per-token percentiles, preemption counts,
        goodput under SLO), or None before any run completes.
    """
    tokens: int = 0
    hits: int = 0
    misses: int = 0
    fetch_bytes: int = 0
    sim_stall_s: float = 0.0
    blocking_stall_s: float = 0.0
    overlapped_s: float = 0.0
    steps: int = 0
    prefill_tokens: int = 0
    prefill_chunks: int = 0
    fallback_prefill_tokens: int = 0
    rejected_requests: int = 0
    preemptions: int = 0
    stall_by_tier: Dict[int, float] = field(default_factory=dict)
    overlapped_by_tier: Dict[int, float] = field(default_factory=dict)
    fetches_by_tier: Dict[int, int] = field(default_factory=dict)
    fetch_bytes_by_tier: Dict[int, int] = field(default_factory=dict)
    deep_prefetch_hits: int = 0
    fetches_deduped: int = 0
    evictions_learned: int = 0
    evictions_lru: int = 0
    horizon_clamps: int = 0
    ships: int = 0
    ship_bytes: int = 0
    ship_tokens: int = 0
    latency: Optional[LatencyStats] = None

    @property
    def hit_rate(self):
        return self.hits / max(self.hits + self.misses, 1)

    @property
    def mean_batch(self):
        """Mean decode lanes per decode step (prefill excluded)."""
        return (self.tokens - self.prefill_tokens) / max(self.steps, 1)

    def as_dict(self) -> dict:
        """Every field as a JSON-ready dict (``latency`` nested or None);
        the blanket serialization the stats-registration lint pins."""
        from dataclasses import asdict
        return asdict(self)


class DecodeCore:
    """Shared batched decode machinery: jitted layer halves, the expert
    cache/slot-buffer control plane, and the per-step host driver.

    KV caches carry ``max_batch + 1`` rows; row ``max_batch`` is a scratch
    row that padding lanes read/write so every bucket's scatter is
    deterministic. Engines own request bookkeeping; the core owns device
    state transforms and stall/hit accounting.
    """

    def __init__(self, model, params, capacity: int, eviction: str = "lru",
                 host_bw: float = 100e9, expert_backend: str = "jnp",
                 max_batch: int = 1,
                 layer_compute_s: Union[float, str] = 0.0,
                 max_prefill_chunk: int = 8,
                 kernel: Optional[str] = "auto", tiers=None,
                 telemetry=None):
        cfg = model.cfg
        assert cfg.moe is not None, "offload engine needs an MoE backbone"
        self.cfg = cfg
        self.model = model
        self.params = params
        self.layers = unstack_layers(cfg, params)
        self.kinds = cfg.layer_kinds()
        self.moe_layers = moe_layer_ids(cfg)
        self.moe_index = {li: i for i, li in enumerate(self.moe_layers)}
        self.expert_backend = expert_backend
        self.max_batch = max_batch
        self.scratch_row = max_batch
        self.max_prefill_chunk = max_prefill_chunk
        # paged attention read path: a kernel backend string threaded into
        # the jitted paged programs, None for the gather parity route, or
        # "auto" for the backend-appropriate default (ServeConfig holds the
        # same rule at the scheduler level and passes the resolved value)
        from repro.kernels.runtime import default_kernel_backend
        self.kernel = default_kernel_backend() if kernel == "auto" else kernel
        # telemetry: a pure observer every subsystem below shares. The
        # default is the module-wide no-op singleton, so un-instrumented
        # engines pay one attribute read per guarded site — and the
        # scoreboard capture (_submit_prefetch/_moe_units) is skipped
        # entirely, keeping streams and stats bit-identical either way.
        self.tel = telemetry if telemetry is not None else NULL_TELEMETRY
        self.tel.ensure_track(PID_ENGINE, 1, "decode driver")
        # raw (pre-gating, pre-clamp) distance-0 predicted key sets per
        # MoE ordinal, consumed by _moe_units for the predictor scoreboard
        self._pred_d0: Dict[int, set] = {}

        # host store gets the routed-expert weights; everything else stays
        # in self.layers (device). ``tiers`` (a TierConfig) swaps the
        # single-host store for the device/host/peer/disk hierarchy.
        store_layers = [self.layers[li]["moe"] for li in self.moe_layers]
        # replacement="learned": one ReuseDistanceScorer shared by the
        # tier-0 slot cache and the store's tier-1 cache. _submit_prefetch
        # feeds it the raw (pre-gating) multi-horizon predictions and
        # _moe_units ticks its clock once per MoE layer computed.
        if eviction == "learned":
            from repro.core.policies import ReuseDistanceScorer
            self.scorer = ReuseDistanceScorer()
        else:
            self.scorer = None
        self._conf_threshold = (tiers.deep_confidence
                                if tiers is not None else None)
        if tiers is not None:
            from repro.serving.expertstore import TieredExpertStore
            self.store = TieredExpertStore(store_layers, tiers,
                                           scorer=self.scorer,
                                           telemetry=self.tel)
        else:
            self.store = HostExpertStore(store_layers)
        # compute dispatch (TierConfig.dispatch = "ship"/"auto"): price
        # fetch-vs-ship per (expert, token-count) off the same roofline
        # constants the compute clock uses. weight_bytes is the WIRE size
        # of a peer fetch — the quantized cold size under int8 cold tiers,
        # where a ship runs against the dequantized peer copy instead.
        self.planner = None
        if tiers is not None and tiers.dispatch != "fetch":
            from repro.launch.dryrun import expert_ffn_roofline
            from repro.serving.expertstore import DispatchPlanner
            per_tok_s, base_s = expert_ffn_roofline(cfg)
            wire_w = (self.store.cold_bytes_per_expert
                      if tiers.cold_dtype is not None
                      else self.store.bytes_per_expert)
            self.planner = DispatchPlanner(
                weight_bytes=wire_w,
                act_bytes_per_token=2 * cfg.d_model
                * jnp.dtype(cfg.dtype).itemsize,
                ffn_s_per_token=per_tok_s, ffn_s_base=base_s,
                peer_latency_s=tiers.peer_latency_s,
                peer_bw=tiers.peer_bw, mode=tiers.dispatch)
        # how many MoE layers ahead predictions are asked for: the store's
        # deepest tier decides (single host -> 1, the original behaviour)
        self.max_horizon = self.store.max_horizon
        self.tracker = OverlapTracker(host_bw, telemetry=self.tel)
        # a step's units can route to at most units*top_k distinct experts,
        # which bounds how many ephemeral ship rows one program may stage
        ship_slots = (max(max_batch, max_prefill_chunk) * cfg.moe.top_k
                      if self.planner is not None else 0)
        self.cache, self.slots = make_offload_cache(
            self.store, capacity, eviction, host_bw, tracker=self.tracker,
            scorer=self.scorer, ship_slots=ship_slots,
            telemetry=self.tel)
        self.stats = EngineStats()
        self._init_layer_compute(layer_compute_s)
        self._tok_emb_np = np.asarray(params["tok_emb"], np.float32)
        self._build_fns()

    # ------------------------------------------------------------------
    def _init_layer_compute(self, layer_compute_s: Union[float, str]):
        """The OverlapTracker's compute clock per layer half.

        Every layer advances its attention half after the attention
        program and its FFN half after the dense/expert FFN. A float is
        the legacy uniform knob. ``"roofline"`` derives
        per-layer ``(attn_s, ffn_s)`` from the dry-run's analytic roofline
        (launch/dryrun.decode_layer_roofline) so stall/overlap reports are
        calibrated to the architecture instead of a guess. ``"measured"``
        starts from the roofline shape and rescales it by an EWMA of each
        decode step's real wall clock over its modeled total, so the
        modeled clock tracks this machine's actual speed."""
        self.layer_compute_s = layer_compute_s
        self._calib = 1.0
        self._measure = False
        if isinstance(layer_compute_s, str):
            if layer_compute_s not in ("roofline", "measured"):
                raise ValueError(
                    f"layer_compute_s must be a float, 'roofline' or "
                    f"'measured', got {layer_compute_s!r}")
            from repro.launch.dryrun import decode_layer_roofline
            self._layer_s = decode_layer_roofline(self.cfg,
                                                  batch=self.max_batch)
            self._measure = layer_compute_s == "measured"
        else:
            self._layer_s = [(layer_compute_s, layer_compute_s)
                             ] * self.cfg.num_layers
        self._step_advanced = 0.0

    def _advance(self, li: int, half: int) -> None:
        dt = self._layer_s[li][half] * self._calib
        self.tracker.advance(dt)
        self._step_advanced += dt

    def _calibrate(self, wall_s: float) -> None:
        """Measured-walltime override: rescale the roofline terms so one
        step's modeled compute tracks the real wall clock (EWMA)."""
        if not self._measure or self._step_advanced <= 0:
            return
        target = self._calib * wall_s / self._step_advanced
        self._calib = 0.7 * self._calib + 0.3 * target

    # ------------------------------------------------------------------
    def _build_fns(self):
        cfg = self.cfg
        # bound as a local so no jitted closure reads mutable engine state
        # (tracer-purity): the compiled programs are rebuilt with the core,
        # never silently stale against a reassigned attribute
        expert_backend = self.expert_backend

        @jax.jit
        def embed_fn(tok_emb, tokens):
            # tokens: (N,) -> (N, 1, D)
            return jnp.take(tok_emb, tokens, axis=0)[:, None, :]

        @jax.jit
        def embed_seq_fn(tok_emb, tokens):
            # tokens: (C,) -> (1, C, D), one request's prompt chunk
            return jnp.take(tok_emb, tokens, axis=0)[None, :, :]

        def attn_row(lp, x_row, cache_row, pos, *, kind):
            # one request: x_row (D,), unbatched cache row, scalar pos
            x = x_row[None, None, :]
            cache = jax.tree.map(lambda c: c[None], cache_row)
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            positions = jnp.full((1, 1), pos, jnp.int32)
            if kind == "mla":
                o, nc = mla_mod.mla_apply(lp["attn"], cfg, h, positions,
                                          "decode", cache, pos)
            else:
                o, nc = attn_mod.attn_apply(lp["attn"], cfg, kind, h,
                                            positions, "decode", cache, pos)
            return (x + o)[0, 0], jax.tree.map(lambda c: c[0], nc)

        @partial(jax.jit, static_argnames=("kind",))
        def attn_batched(lp, x, caches, rows, pos, kind):
            # x: (N,1,D); caches: full (max_batch+1, ...); rows/pos: (N,)
            sub = jax.tree.map(lambda c: jnp.take(c, rows, axis=0), caches)
            y, nsub = jax.vmap(partial(attn_row, kind=kind),
                               in_axes=(None, 0, 0, 0))(lp, x[:, 0, :],
                                                        sub, pos)
            new = jax.tree.map(lambda c, n: c.at[rows].set(n), caches, nsub)
            return y[:, None, :], new

        @partial(jax.jit, static_argnames=("kind", "kernel"))
        def paged_attn_step(lp, x, cache, tables, pos, kind, kernel):
            # x: (N,1,D); cache: block pool; tables: (N,W); pos: (N,)
            return T.block_paged_decode(lp, cfg, kind, x, cache, tables,
                                        pos, kernel=kernel)

        @partial(jax.jit, static_argnames=("kind", "kernel"))
        def paged_prefill_step(lp, x, cache, table, t0, n_valid, kind,
                               kernel):
            # x: (1,C,D) chunk of ONE request; table: (W,); t0/n_valid scalar
            return T.block_paged_prefill(lp, cfg, kind, x, cache, table, t0,
                                         n_valid, kernel=kernel)

        @partial(jax.jit, static_argnames=("kind",))
        def paged_copy_fn(cache, src, dst, kind):
            # one pool page src -> dst (copy-on-write for shared blocks)
            return T.block_paged_copy(cfg, kind, cache, src, dst)

        @jax.jit
        def dense_ffn_half(lp, x):
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            return x + ffn_apply(lp["ffn"], h, cfg.ffn_kind)

        @jax.jit
        def router_fn(lp, x):
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            w, idx, probs = moe_mod.route(lp["moe"], cfg, h)
            return h, w, idx

        @jax.jit
        def expert_from_slots(x_norm, weights, slot_idx, wg_buf, wu_buf,
                              wd_buf, shared, x):
            # x_norm/x: (N,1,D); weights: (N,1,k); slot_idx: (N,k)
            from repro.kernels import ops
            n, k = slot_idx.shape
            flat = slot_idx.reshape(-1)
            wg = jnp.take(wg_buf, flat, 0).reshape((n, k) + wg_buf.shape[1:])
            wu = jnp.take(wu_buf, flat, 0).reshape((n, k) + wu_buf.shape[1:])
            wd = jnp.take(wd_buf, flat, 0).reshape((n, k) + wd_buf.shape[1:])

            def row(hr, wr, g, u, d):
                return ops.expert_ffn(hr, wr, g, u, d,
                                      backend=expert_backend)

            y = jax.vmap(row)(x_norm[:, 0, :], weights[:, 0, :], wg, wu, wd)
            out = x + y[:, None, :]
            if shared is not None:
                out = out + ffn_apply(shared, x_norm, "swiglu")
            return out

        @jax.jit
        def unembed_fn(params, x):
            return T.unembed(params, cfg, x)

        self._embed = embed_fn
        self._embed_seq = embed_seq_fn
        self._attn = attn_batched
        self._paged_attn = paged_attn_step
        self._paged_prefill = paged_prefill_step
        self._paged_copy = paged_copy_fn
        self._dense_ffn = dense_ffn_half
        self._router = router_fn
        self._expert = expert_from_slots
        self._unembed = unembed_fn

    # ------------------------------------------------------------------
    def alloc_caches(self, cache_len: int) -> List[dict]:
        """Per-layer list of batched (max_batch+1 rows) decode caches."""
        caches = T.stack_cache_init(self.cfg, self.max_batch + 1, cache_len,
                                    jnp.dtype(self.cfg.dtype))
        return unstack_layers(
            self.cfg, {"stack": {"head": caches["head"],
                                 "scan": caches["scan"],
                                 "tail": caches["tail"]}})

    def alloc_paged_caches(self, num_blocks: int,
                           block_size: int) -> List[dict]:
        """Per-layer paged decode caches: layers whose KV grows with the
        sequence get (num_blocks, block_size, ...) pools sharing ONE block-id
        space (serving/kvpool.py); bounded kinds keep max_batch+1 rows."""
        return [T.block_paged_cache_init(self.cfg, self.kinds[li], num_blocks,
                                         block_size, self.max_batch + 1,
                                         jnp.dtype(self.cfg.dtype))
                for li in range(self.cfg.num_layers)]

    @property
    def paged_ok(self) -> bool:
        """Every layer kind is decodable by the paged step (paged pools for
        growing KV, bounded rows for ring buffers)."""
        return all(k in T.PAGED_KINDS + ("local", "chunked")
                   for k in self.kinds)

    @property
    def chunk_prefill_ok(self) -> bool:
        """Chunked prefill needs every layer's state reachable through block
        tables — ring/recurrent kinds fall back to token-by-token prompts."""
        return all(k in T.PAGED_KINDS for k in self.kinds)

    def copy_block(self, caches, src: int, dst: int):
        """Copy pool page ``src -> dst`` in every paged layer — the device
        half of copy-on-write. The scheduler calls this right after
        ``BlockTable.make_private`` swaps a shared block for a private one,
        so the private block starts as a bit-identical copy."""
        src_j = jnp.asarray(src, jnp.int32)
        dst_j = jnp.asarray(dst, jnp.int32)
        for li in range(self.cfg.num_layers):
            if self.kinds[li] in T.PAGED_KINDS:
                caches[li] = self._paged_copy(caches[li], src_j, dst_j,
                                              kind=self.kinds[li])
        return caches

    def paged_block_bytes(self, caches) -> int:
        """Device bytes ONE pool block occupies summed across paged layers —
        the unit the memory high-water scales in."""
        total = 0
        for li, c in enumerate(caches):
            if self.kinds[li] in T.PAGED_KINDS:
                total += sum(v.nbytes // v.shape[0] for v in c.values())
        return total

    def _moe_window(self, li: int) -> List[int]:
        """MoE ordinals of the next ``max_horizon`` MoE layers at/after
        layer ``li`` — the lookahead window horizon-aware prefetch fills."""
        out = []
        for lj in self.moe_layers:
            if lj >= li:
                out.append(self.moe_index[lj])
                if len(out) == self.max_horizon:
                    break
        return out

    def _submit_prefetch(self, policy, rids, ts, li_from: int):
        """Submit predicted experts for the lookahead window starting at
        layer ``li_from``. Distance-0 predictions (the next MoE layer) are
        always prefetched — the original single-layer double-buffer. At
        distance d > 0 a predicted key is prefetched only when

        * the tier it currently resides in needs that much lead time
          (``store.prefetch_horizon(key) > d``): a tier-3 expert is
          requested layers earlier than a tier-1 one, whose prediction can
          wait for the more accurate next-layer pass;
        * its prediction clears ``TierConfig.deep_confidence`` (when set
          and the policy reports confidences): deep lead time is spent
          only on keys the predictor is sure about, pruning wasted
          slow-tier fetches;
        * the keys *fit*: once a distance's not-yet-resident keys exceed
          the tier-0 slots left over after the distance-0 working set and
          the in-flight pins, that distance and everything deeper is
          dropped and ``horizon_clamps`` counts it — the anti-thrash
          guard that stops deep prefetch from churning the next layer's
          own working set at admission-minimum capacity.

        With learned replacement the raw (pre-gating) predictions also
        feed the ReuseDistanceScorer: every predicted (key, distance)
        doubles as a predicted-next-use estimate for eviction.

        With compute dispatch active ("ship"/"auto") a predicted
        peer-resident key the planner prices cheaper to *ship* (estimated
        token count = how many prediction rows name it) is not prefetched
        at any distance: pulling its weights would be exactly the cache
        thrash the ship path exists to avoid. The demand-time decision in
        ``_moe_units`` stays authoritative — if the router sends more
        tokens than predicted, the planner re-prices and may fetch."""
        if policy is None:
            return
        mis = self._moe_window(li_from)
        if not mis:
            return
        scored = (self.scorer is not None
                  or self._conf_threshold is not None)
        if scored:
            preds = policy.predict_batch_multi_scored(rids, ts, mis)
        elif len(mis) == 1:
            preds = {mis[0]: policy.predict_batch(rids, ts, mis[0])}
        else:
            preds = policy.predict_batch_multi(rids, ts, mis)
        # pass 1: record the WHOLE window into the scorer and decide what
        # fits, before any insertion — the d0 prefetch's evictions must see
        # the deeper layers' predicted distances, not last cycle's stale
        # ones. Records are information and are never clamped; only the
        # insertions are.
        plan = []
        deep_budget, clamped = 0, False
        tel_on = self.tel.enabled
        raw0: set = set()
        for d, mi in enumerate(mis):
            rows = []
            if self.planner is not None:
                mult: Dict = {}
                for pred in preds[mi]:
                    for e in (pred[0] if scored else pred):
                        k = (mi, int(e))
                        mult[k] = mult.get(k, 0) + 1
            for pred in preds[mi]:
                conf = None
                if scored:
                    pred, conf = pred
                keys = [(mi, int(e)) for e in pred]
                if tel_on and d == 0:
                    # scoreboard capture: the RAW next-layer prediction,
                    # before the planner/horizon/fit filters prune what
                    # actually gets prefetched — predictor quality is
                    # about what the model said, not what fit
                    raw0.update(keys)
                if self.scorer is not None and keys:
                    self.scorer.record(keys, distance=d)
                if self.planner is not None:
                    keep = [i for i, k in enumerate(keys)
                            if k in self.cache
                            or self.store.tier_of(k) != TIER_PEER
                            or self.planner.choose(mult[k]) != "ship"]
                    keys = [keys[i] for i in keep]
                    if conf is not None:
                        conf = [conf[i] for i in keep]
                if d > 0:
                    kept = []
                    for i, k in enumerate(keys):
                        if self.store.prefetch_horizon(k) <= d:
                            continue
                        if (self._conf_threshold is not None
                                and conf is not None
                                and conf[i] < self._conf_threshold):
                            continue
                        kept.append(k)
                    keys = kept
                rows.append(keys)
            if d == 0:
                d0 = {k for keys in rows for k in keys}
                deep_budget = max(0, self.cache.capacity - len(d0)
                                  - len(self.cache._pins))
                plan.append((d, rows))
            elif not clamped:
                new = {k for keys in rows for k in keys
                       if k not in self.cache}
                if len(new) > deep_budget:
                    self.stats.horizon_clamps += 1
                    clamped = True      # this distance and deeper dropped
                else:
                    deep_budget -= len(new)
                    plan.append((d, rows))
        # pass 2: submit what fits
        for d, rows in plan:
            for keys in rows:
                if keys:
                    self.cache.prefetch(keys, horizon=d)
        if tel_on:
            self._pred_d0[mis[0]] = raw0
            submitted = sum(len(keys) for _, rows in plan for keys in rows)
            self.tel.counter("prefetch.submitted", submitted)
            if clamped:
                self.tel.counter("prefetch.clamps")
            self.tel.instant(PID_ENGINE, 1, "prefetch",
                             {"li_from": li_from, "window": len(mis),
                              "submitted": submitted, "clamped": clamped,
                              "confidence_gated":
                                  self._conf_threshold is not None})

    # ------------------------------------------------------------------
    def _moe_units(self, mi: int, lp, h, w, x, idx_np: np.ndarray,
                   n_real: int):
        """Expert half shared by decode steps and prefill chunks.

        A "unit" is one token needing top-k experts: decode lanes, or the
        tokens of one prefill chunk. h/w/x: (U,1,...) device arrays (pad
        units included); idx_np: (U,k); only the first n_real units touch
        the cache. Returns (x_out, per-live-unit ground-truth sets).

        Compute dispatch: with a DispatchPlanner active, each demanded
        expert that is neither tier-0 resident nor findable locally —
        i.e. would be a peer fetch — is priced fetch-vs-ship on its token
        count. Shipped experts bypass the ExpertCache entirely (no
        access, no insert, no pin): their weights are staged in ephemeral
        slot rows modeling the peer's copy, the round trip is charged to
        the ship channel, and the SAME jitted slot-gather program computes
        them — so streams stay token-identical while tier 0 is untouched.
        """
        ship_slot: Dict = {}
        if self.planner is not None:
            tok_count: Dict = {}
            for i in range(n_real):
                for e in np.unique(idx_np[i]):
                    key = (mi, int(e))
                    tok_count[key] = tok_count.get(key, 0) + 1
            for key, n_tok in sorted(tok_count.items()):
                if key in self.cache:
                    continue            # tier-0 resident: just compute
                if self.store.tier_of(key) != TIER_PEER:
                    continue            # local/disk: fetch path owns it
                if self.planner.choose(n_tok) != "ship":
                    continue
                wire = self.planner.ship_bytes(n_tok)
                peer_w = self.store.ship(key, n_tok, wire)
                ship_slot[key] = self.slots.fill_ship(len(ship_slot),
                                                      peer_w)
                self.tracker.submit(key, wire, tier=CHANNEL_SHIP,
                                    duration=self.planner.ship_s(n_tok),
                                    coalesce=False)
        tel_on = self.tel.enabled
        miss_tier: Dict = {}
        t01_hit = t01_miss = n_hit = n_miss = 0
        if tel_on:
            # predictor scoreboard: the raw distance-0 prediction captured
            # by _submit_prefetch vs the experts the router actually used
            # this layer visit (both as key sets, micro-counted)
            actual = {(mi, int(e)) for i in range(n_real)
                      for e in np.unique(idx_np[i])}
            pred = self._pred_d0.pop(mi, None)
            if pred is not None:
                pw = f1_over_window([{e for _, e in pred}],
                                    [{e for _, e in actual}])
                self.tel.predictor_window(pw.tp, pw.fp, pw.fn)
            # tier-of read-out BEFORE any access mutates residency: a
            # miss served from the store's tier-1 host cache still counts
            # toward the paper's tier-0/1 hit rate
            miss_tier = {k: self.store.tier_of(k) for k in actual
                         if k not in self.cache and k not in ship_slot}
        gts, pinned = [], []
        for i in range(n_real):                   # live units only
            gt = np.unique(idx_np[i])
            gts.append(gt)
            for e in gt:
                key = (mi, int(e))
                if key in ship_slot:
                    continue            # computed remotely this step
                hit = self.cache.access(key)
                self.stats.hits += int(hit)
                self.stats.misses += int(not hit)
                if tel_on:
                    n_hit += int(hit)
                    n_miss += int(not hit)
                    if hit or miss_tier.get(key) == TIER_HOST:
                        t01_hit += 1
                    else:
                        t01_miss += 1
                # pin immediately: a later unit's demand fetch must not
                # evict an expert this step still computes with
                self.cache.pin(key)
                pinned.append(key)
        if tel_on:
            if n_hit:
                self.tel.counter("cache.hit", n_hit)
            if n_miss:
                self.tel.counter("cache.miss", n_miss)
            if t01_hit:
                self.tel.counter("cache.t01_hit", t01_hit)
            if t01_miss:
                self.tel.counter("cache.t01_miss", t01_miss)
        self.tracker.wait({(mi, int(e)) for gt in gts for e in gt})
        slot_idx = np.zeros(idx_np.shape, np.int32)
        slot_table = self.slots.slot_of
        for i in range(n_real):
            slot_idx[i] = [
                ship_slot[key] if key in ship_slot else slot_table[key]
                for key in ((mi, int(e)) for e in idx_np[i])]
        x = self._expert(h, w, jnp.asarray(slot_idx), self.slots.w_gate,
                         self.slots.w_up, self.slots.w_down,
                         lp["moe"].get("shared"), x)
        for key in pinned:
            self.cache.unpin(key)
        self._advance(self.moe_layers[mi], 1)     # the expert-FFN half
        if self.scorer is not None:
            self.scorer.tick()    # one MoE layer computed == one clock unit
        return x, gts

    def _sync_stats(self):
        self.stats.fetch_bytes = self.slots.fetch_bytes
        self.stats.sim_stall_s = self.tracker.stall_s
        self.stats.blocking_stall_s = self.slots.sim_fetch_s
        self.stats.overlapped_s = self.tracker.overlapped_s
        self.stats.stall_by_tier = dict(self.tracker.stall_by_tier)
        self.stats.overlapped_by_tier = dict(self.tracker.overlapped_by_tier)
        self.stats.deep_prefetch_hits = self.cache.stats.deep_prefetch_hits
        self.stats.fetches_deduped = self.tracker.fetches_deduped
        self.stats.evictions_learned = self.cache.stats.evictions_learned
        self.stats.evictions_lru = self.cache.stats.evictions_lru
        st = getattr(self.store, "stats", None)
        if st is not None:
            self.stats.fetches_by_tier = dict(st.fetches_by_tier)
            self.stats.fetch_bytes_by_tier = dict(st.bytes_by_tier)
            self.stats.ships = st.ships
            self.stats.ship_bytes = st.ship_bytes
            self.stats.ship_tokens = st.ship_tokens
        elif self.slots.fetch_count:
            self.stats.fetches_by_tier = {TIER_HOST: self.slots.fetch_count}
            self.stats.fetch_bytes_by_tier = {TIER_HOST:
                                              self.slots.fetch_bytes}

    def step(self, caches, rows: Sequence[int], pos: Sequence[int],
             tokens: Sequence[int], policy: Optional[PerRequestPolicy],
             rids: Sequence[int], tables: Optional[np.ndarray] = None):
        """One decode step for N active requests (N <= max_batch).

        rows: KV-cache row per request; pos: per-request positions; tokens:
        token fed per request. With ``tables`` (N, W) int32 block tables,
        layers whose KV grows run through the paged pools (``tables`` row i
        must already cover position ``pos[i]``) while ring-buffer kinds keep
        using ``rows``; without it every layer uses contiguous rows. Returns
        (logits (N, V) f32, new caches, per-request per-MoE-layer
        ground-truth sets).
        """
        cfg = self.cfg
        n = len(rows)
        ts = list(pos)
        nb = bucket_size(n, self.max_batch)
        pad = nb - n
        rows_p = jnp.asarray(list(rows) + [self.scratch_row] * pad, jnp.int32)
        pos_p = jnp.asarray(list(pos) + [0] * pad, jnp.int32)
        toks_p = jnp.asarray(list(tokens) + [0] * pad, jnp.int32)
        embeddings = self._tok_emb_np[np.asarray(tokens, np.int64)]
        if tables is not None:
            # pad lanes get all-scratch tables: their scatters land in the
            # scratch block, never a live request's pages
            tab_p = np.zeros((nb, tables.shape[1]), np.int32)
            tab_p[:n] = tables
            tab_p = jnp.asarray(tab_p)

        t_wall = time.perf_counter()
        self._step_advanced = 0.0
        x = self._embed(self.params["tok_emb"], toks_p)
        experts_out = [[] for _ in range(n)]
        # double-buffer: predictions for the lookahead window starting at
        # the first MoE layer go onto the channels now, hiding behind the
        # dense/attention layers below it
        self._submit_prefetch(policy, rids, ts, 0)
        for li in range(cfg.num_layers):
            lp = self.layers[li]
            kind = self.kinds[li]
            if tables is not None and kind in T.PAGED_KINDS:
                x, caches[li] = self._paged_attn(lp, x, caches[li], tab_p,
                                                 pos_p, kind=kind,
                                                 kernel=self.kernel)
            else:
                x, caches[li] = self._attn(lp, x, caches[li], rows_p, pos_p,
                                           kind=kind)
            self._advance(li, 0)
            if li in self.moe_index:
                mi = self.moe_index[li]
                h, w, idx = self._router(lp, x)
                idx_np = np.asarray(idx)[:, 0, :]               # (nb, k)
                x, gts = self._moe_units(mi, lp, h, w.astype(x.dtype), x,
                                         idx_np, n)
                if policy is not None:
                    policy.observe_batch(rids, ts, mi, gts, embeddings)
                for i in range(n):
                    experts_out[i].append(gts[i])
                # double-buffer the NEXT MoE layers' predicted experts
                self._submit_prefetch(policy, rids, ts, li + 1)
            elif "ffn" in lp:
                x = self._dense_ffn(lp, x)
                self._advance(li, 1)
        logits = np.asarray(self._unembed(self.params, x))[:n, 0]
        self.stats.tokens += n
        self.stats.steps += 1
        wall = time.perf_counter() - t_wall
        self._calibrate(wall)
        self._sync_stats()
        if self.tel.enabled:
            t0_s = self.tel.rel(t_wall)
            self.tel.complete(PID_ENGINE, 1, "decode_step", t0_s, wall,
                              {"batch": n})
            self.tel.histogram("step.wall_s", wall)
            for rid, p in zip(rids, pos):
                tid = int(rid) + 1
                self.tel.ensure_track(PID_REQUESTS, tid, f"req {rid}")
                self.tel.complete(PID_REQUESTS, tid, "decode", t0_s, wall,
                                  {"pos": int(p)})
        return logits, caches, experts_out

    # ------------------------------------------------------------------
    def prefill_chunk(self, caches, table: np.ndarray, t0: int,
                      tokens: Sequence[int],
                      policy: Optional[PerRequestPolicy], rid: int):
        """One prompt chunk of a single request through the paged stack.

        tokens sit at absolute positions t0..t0+len(tokens)-1; ``table``
        (W,) int32 must already cover the last of them. The chunk is padded
        to a power-of-two bucket (compiled once per bucket, like decode
        padding buckets); per-token math is identical to feeding the same
        tokens one-by-one through the decode path, so chunked prefill keeps
        token-identical streams. ``t0`` may be nonzero with earlier
        positions' KV already in the table's blocks (later chunks, or a
        prefix-cache match skipping straight past the shared prefix).
        Returns (logits (len(tokens), V) f32, caches, experts) — experts is
        a per-MoE-layer list of per-token ground-truth expert-id arrays,
        the raw material the prefix cache records for activation replay.
        """
        assert self.chunk_prefill_ok, \
            "chunked prefill needs a global/mla-only stack"
        cfg = self.cfg
        n = len(tokens)
        assert 0 < n <= self.max_prefill_chunk
        cb = bucket_size(n, self.max_prefill_chunk)
        ts = list(range(t0, t0 + n))
        toks_p = jnp.asarray(list(tokens) + [0] * (cb - n), jnp.int32)
        tab = jnp.asarray(table, jnp.int32)
        embeddings = self._tok_emb_np[np.asarray(tokens, np.int64)]

        t_wall = time.perf_counter()
        self._step_advanced = 0.0
        x = self._embed_seq(self.params["tok_emb"], toks_p)      # (1,cb,D)
        experts_out: List[List[np.ndarray]] = []
        self._submit_prefetch(policy, [rid], [t0], 0)
        for li in range(cfg.num_layers):
            lp = self.layers[li]
            # lint: disable=bucket-discipline -- t0/n_valid trace as shape-()
            # weak-typed scalars (one compile covers every value); the chunk
            # array itself is padded to a pow-2 bucket via bucket_size above
            x, caches[li] = self._paged_prefill(lp, x, caches[li], tab, t0,
                                                n, kind=self.kinds[li],
                                                kernel=self.kernel)
            self._advance(li, 0)
            if li in self.moe_index:
                mi = self.moe_index[li]
                h, w, idx = self._router(lp, x)                 # (1,cb,...)
                idx_np = np.asarray(idx)[0]                     # (cb, k)
                # chunk tokens become the expert units: same gather path,
                # same pinning discipline as decode lanes
                hu = h[0][:, None, :]
                wu = w[0][:, None, :].astype(x.dtype)
                xu = x[0][:, None, :]
                xu, gts = self._moe_units(mi, lp, hu, wu, xu, idx_np, n)
                x = xu[:, 0, :][None]
                experts_out.append(gts)
                if policy is not None:
                    policy.observe_batch([rid] * n, ts, mi, gts, embeddings)
                self._submit_prefetch(policy, [rid], [t0 + n - 1], li + 1)
            elif "ffn" in lp:
                x = self._dense_ffn(lp, x)
                self._advance(li, 1)
        logits = np.asarray(self._unembed(self.params, x))[0, :n]
        self.stats.tokens += n
        self.stats.prefill_tokens += n
        self.stats.prefill_chunks += 1
        wall = time.perf_counter() - t_wall
        self._calibrate(wall)
        self._sync_stats()
        if self.tel.enabled:
            t0_s = self.tel.rel(t_wall)
            tid = int(rid) + 1
            self.tel.ensure_track(PID_REQUESTS, tid, f"req {rid}")
            self.tel.complete(PID_REQUESTS, tid, "prefill", t0_s, wall,
                              {"t0": int(t0), "n": n})
            self.tel.complete(PID_ENGINE, 1, "prefill_chunk", t0_s, wall,
                              {"rid": int(rid), "n": n})
            self.tel.histogram("prefill.wall_s", wall)
        return logits, caches, experts_out


class OffloadEngine:
    """Batch-1 engine: the original public API on the shared DecodeCore."""

    def __init__(self, model, params, policy: Optional[Policy],
                 capacity: int, eviction: str = "lru",
                 host_bw: float = 100e9, expert_backend: str = "jnp",
                 layer_compute_s: Union[float, str] = 0.0, tiers=None,
                 telemetry=None):
        self.core = DecodeCore(model, params, capacity, eviction, host_bw,
                               expert_backend, max_batch=1,
                               layer_compute_s=layer_compute_s, tiers=tiers,
                               telemetry=telemetry)
        self.cfg = self.core.cfg
        self.model = model
        self.params = params
        self.policy = policy
        # a single in-flight request may share one stateful instance
        self._prp = (None if policy is None
                     else PerRequestPolicy(policy, force_shared=True))

    @property
    def stats(self) -> EngineStats:
        return self.core.stats

    @property
    def cache(self):
        return self.core.cache

    @property
    def slots(self):
        return self.core.slots

    @property
    def store(self):
        return self.core.store

    @property
    def layers(self):
        return self.core.layers

    def init_state(self, cache_len: int):
        return {"pos": 0, "caches": self.core.alloc_caches(cache_len)}

    def decode_token(self, state, token: int):
        """One token through all layers; returns (logits, state, experts)."""
        logits, caches, experts = self.core.step(
            state["caches"], rows=[0], pos=[state["pos"]],
            tokens=[int(token)], policy=self._prp, rids=[0])
        state["caches"] = caches
        state["pos"] = state["pos"] + 1
        return logits[0], state, experts[0]

    def generate(self, prompt, max_new: int, cache_len: int,
                 temperature: float = 0.0, seed: int = 0):
        if len(prompt) == 0:
            raise ValueError(
                "empty prompt: generation needs at least one token to seed "
                "the decode loop")
        if max_new < 0:
            raise ValueError(f"max_new must be >= 0, got {max_new}")
        state = self.init_state(cache_len)
        if self._prp is not None:
            self._prp.begin_request(0)
        rng = np.random.default_rng(seed)
        cur = prompt[0]
        n_total = min(len(prompt) + max_new, cache_len)
        generated = []
        for t in range(n_total):
            logits, state, _ = self.decode_token(state, int(cur))
            if t + 1 < len(prompt):
                cur = prompt[t + 1]
            else:
                cur = sample_token(logits, temperature, rng)
                generated.append(cur)
        return generated
