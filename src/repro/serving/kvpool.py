"""Paged KV-cache block pool (vLLM-style) for the serving engines.

The KV cache is carved into fixed-size blocks of ``block_size`` token
positions; every attention layer's pool tensor shares ONE block-id space, so
allocating block ``b`` for a request reserves position storage in *every*
layer at once. A request owns an ordered :class:`BlockTable` — logical block
``i`` of the table covers absolute positions ``[i*block_size,
(i+1)*block_size)`` — and grows it lazily as its sequence advances, so device
memory high-water scales with the *sum of actual sequence lengths* rather
than ``batch × cache_len``.

Block id 0 is reserved as the **scratch block**: padding lanes of a bucketed
decode/prefill step scatter their (discarded) K/V there, exactly like the
scratch KV row of the contiguous path, so jitted scatters stay shape-stable
and never touch a live request's blocks.

Admission control is reservation-based: the scheduler calls
:meth:`KVBlockPool.try_reserve` with a request's worst-case block count
before admitting it, which guarantees that lazy growth during decode can
never fail mid-request (no preemption needed). ``PoolStats`` tracks
allocation traffic, the high-water mark, and admission failures — the
fragmentation/memory numbers ``benchmarks/engine_bench.py --mixed`` reports.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

SCRATCH_BLOCK = 0


def blocks_for(num_positions: int, block_size: int) -> int:
    """Blocks needed to cover ``num_positions`` token positions."""
    return max(0, -(-num_positions // block_size))


@dataclass
class PoolStats:
    """Alloc/free traffic with sharing-aware symmetry.

    Once blocks are shared (prefix cache), "free" is ambiguous: dropping a
    reference and returning a block to the free list are different events
    that only coincide at refcount 1. The counters keep two exact
    invariants, checked by ``KVBlockPool.check_leaks``:

      ``allocs - releases  == blocks currently allocated``
      ``allocs + retains - ref_drops == sum of current refcounts``

    All counters are block *events* since pool construction:

      * ``allocs`` — blocks handed out (each starts at refcount 1).
      * ``retains`` — extra references taken (prefix sharing / adoption).
      * ``ref_drops`` — ``free()`` calls: references dropped.
      * ``releases`` — blocks actually returned to the free list (the
        refcount-zero subset of ``ref_drops``).
      * ``cow_copies`` — shared blocks privatised before a write
        (copy-on-write swaps).
      * ``failed_reserves`` — admission attempts refused for lack of
        blocks (the request waits or triggers prefix eviction /
        preemption).
      * ``preempt_ref_drops`` — references dropped by preemption: a
        victim's table released mid-request to re-admit later (its
        index-retained blocks survive — only the table's references go).
      * ``high_water`` — max blocks simultaneously in use (sizes
        ``kv_high_water_bytes``).
    """
    allocs: int = 0
    retains: int = 0
    ref_drops: int = 0
    releases: int = 0
    cow_copies: int = 0
    failed_reserves: int = 0
    preempt_ref_drops: int = 0
    high_water: int = 0

    @property
    def frees(self) -> int:
        """Back-compat alias for ``releases`` (pre-sharing name)."""
        return self.releases

    def utilization(self, num_blocks: int) -> float:
        """Peak fraction of allocatable blocks ever in use."""
        return self.high_water / max(num_blocks, 1)

    def as_dict(self) -> dict:
        """Every counter as a JSON-ready dict (stats-registration lint)."""
        from dataclasses import asdict
        return asdict(self)


class KVBlockPool:
    """Fixed-capacity pool of KV blocks with refcounts and reservations.

    ``num_blocks`` counts every block including the reserved scratch block 0,
    so ``num_blocks - 1`` blocks are allocatable. Refcounts support sharing a
    block between requests (e.g. a common prompt prefix); ``free`` drops one
    reference and only returns the block to the free list at zero.
    """

    def __init__(self, num_blocks: int, block_size: int, telemetry=None):
        assert num_blocks >= 2, "need at least one block beyond scratch"
        assert block_size >= 1
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list: recently-freed blocks are reused first, which keeps
        # the touched pool region small under steady-state churn
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._ref: dict[int, int] = {}
        self._reserved = 0
        self.stats = PoolStats()
        # optional serving.telemetry.Telemetry: alloc/release report the
        # live-block level as a gauge (pure observer; None records nothing)
        self.tel = telemetry

    # -- capacity ----------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return len(self._ref)

    @property
    def reserved(self) -> int:
        return self._reserved

    @property
    def available(self) -> int:
        """Blocks neither allocated nor promised to an admitted request."""
        return self.num_free - self._reserved

    def try_reserve(self, n: int) -> bool:
        """Promise ``n`` future allocations (admission control). Reserved
        blocks are drawn down by ``alloc(reserved=True)`` as the request's
        table grows and returned by ``unreserve`` on retire."""
        if n < 0:
            raise ValueError(f"cannot reserve {n} blocks")
        if self.available < n:
            self.stats.failed_reserves += 1
            return False
        self._reserved += n
        return True

    def unreserve(self, n: int) -> None:
        if n > self._reserved:
            raise RuntimeError(
                f"unreserve({n}) exceeds outstanding reservation "
                f"{self._reserved}")
        self._reserved -= n

    # -- alloc/free --------------------------------------------------------
    def alloc(self, reserved: bool = False) -> int:
        """Allocate one block (refcount 1). ``reserved=True`` consumes one
        unit of a prior reservation instead of the open capacity."""
        if reserved:
            if self._reserved <= 0:
                raise RuntimeError("alloc(reserved=True) with no reservation")
            self._reserved -= 1
        elif self.available <= 0:
            raise RuntimeError(
                f"KV block pool exhausted: {self.num_blocks - 1} blocks, "
                f"{self._reserved} reserved — admit fewer requests or grow "
                "the pool")
        if not self._free:
            raise RuntimeError("KV block pool exhausted")
        bid = self._free.pop()
        self._ref[bid] = 1
        self.stats.allocs += 1
        self.stats.high_water = max(self.stats.high_water, len(self._ref))
        if self.tel is not None and self.tel.enabled:
            self.tel.gauge("kv.blocks_in_use", len(self._ref))
        return bid

    def retain(self, bid: int) -> None:
        """Add a reference to an allocated block (prefix sharing)."""
        if bid not in self._ref:
            raise RuntimeError(f"retain of unallocated block {bid}")
        self._ref[bid] += 1
        self.stats.retains += 1

    def ref_count(self, bid: int) -> int:
        """Current reference count (0 for free/unallocated blocks)."""
        return self._ref.get(bid, 0)

    def is_shared(self, bid: int) -> bool:
        """More than one holder — writes must copy-on-write first."""
        return self._ref.get(bid, 0) > 1

    def free(self, bid: int) -> None:
        """Drop one reference; the block returns to the free list at zero.
        Freeing an unallocated block raises (double-free guard)."""
        if bid not in self._ref:
            raise RuntimeError(f"double free of block {bid}")
        self._ref[bid] -= 1
        self.stats.ref_drops += 1
        if self._ref[bid] == 0:
            del self._ref[bid]
            self._free.append(bid)
            self.stats.releases += 1
            if self.tel is not None and self.tel.enabled:
                self.tel.gauge("kv.blocks_in_use", len(self._ref))

    def check_leaks(self, expected_in_use: int | None = None) -> None:
        """Invariant check: every block is either free or refcounted, scratch
        is never handed out, and the stats counters balance the live state.

        ``expected_in_use`` pins how many blocks may legitimately still be
        allocated — e.g. the blocks a prefix cache retains after every
        request has retired. ``None`` skips that check (mid-run callers)."""
        assert SCRATCH_BLOCK not in self._ref
        assert SCRATCH_BLOCK not in self._free
        overlap = set(self._free) & set(self._ref)
        assert not overlap, f"blocks both free and in use: {overlap}"
        total = len(self._free) + len(self._ref)
        assert total == self.num_blocks - 1, (
            f"leak: {self.num_blocks - 1 - total} blocks unaccounted for")
        assert 0 <= self._reserved <= self.num_free
        s = self.stats
        assert s.allocs - s.releases == len(self._ref), (
            f"alloc/release asymmetry: {s.allocs} allocs, {s.releases} "
            f"releases, {len(self._ref)} blocks live")
        assert s.allocs + s.retains - s.ref_drops == \
            sum(self._ref.values()), "refcount ledger out of balance"
        if expected_in_use is not None:
            assert len(self._ref) == expected_in_use, (
                f"{len(self._ref)} blocks still allocated, expected "
                f"{expected_in_use}")


class BlockTable:
    """Ordered per-request block list; logical block ``i`` covers positions
    ``[i*block_size, (i+1)*block_size)``. Grows lazily via :meth:`ensure`,
    drawing on the request's admission reservation first.

    Blocks adopted from a prefix cache (:meth:`adopt`) are **shared and
    read-only**: before scattering K/V into one, the engine must call
    :meth:`make_private`, which swaps in a freshly-allocated block
    (copy-on-write) so a writer can never corrupt a sibling's KV."""

    def __init__(self, pool: KVBlockPool, reserved_blocks: int = 0):
        self.pool = pool
        self.ids: List[int] = []
        self._reserved = reserved_blocks
        self._shared: set[int] = set()       # logical indices, read-only

    def __len__(self) -> int:
        return len(self.ids)

    @property
    def num_positions(self) -> int:
        return len(self.ids) * self.pool.block_size

    @property
    def reserved(self) -> int:
        """Blocks still promised to this request but not yet allocated."""
        return self._reserved

    def ensure(self, pos: int) -> None:
        """Grow the table to cover absolute position ``pos``."""
        need = pos // self.pool.block_size + 1
        while len(self.ids) < need:
            use_res = self._reserved > 0
            self.ids.append(self.pool.alloc(reserved=use_res))
            if use_res:
                self._reserved -= 1

    def return_reservation(self, n: int = 1) -> None:
        """Hand back up to ``n`` still-unused promised blocks (e.g. after
        adopting a shared block this table will now never allocate)."""
        n = min(n, self._reserved)
        if n > 0:
            self.pool.unreserve(n)
            self._reserved -= n

    # -- prefix sharing ----------------------------------------------------
    def adopt(self, bids) -> None:
        """Append already-allocated blocks (a matched prompt prefix) to the
        table, taking one reference each. Adopted blocks are marked shared
        (read-only) until :meth:`make_private` copies them."""
        for bid in bids:
            self.pool.retain(bid)
            self._shared.add(len(self.ids))
            self.ids.append(bid)

    def is_shared(self, idx: int) -> bool:
        """True when logical block ``idx`` is adopted and still read-only."""
        return idx in self._shared

    def make_private(self, idx: int):
        """Copy-on-write: give logical block ``idx`` a private block id
        before a write lands in it.

        Returns ``(old_bid, new_bid)`` when the caller must copy the device
        page ``old_bid -> new_bid``, or ``None`` when no copy is needed (the
        block is not shared, or every other holder has since let go — then
        this table simply takes exclusive ownership)."""
        if idx not in self._shared:
            return None
        self._shared.discard(idx)
        old = self.ids[idx]
        if not self.pool.is_shared(old):
            return None                       # sole holder: already private
        use_res = self._reserved > 0
        new = self.pool.alloc(reserved=use_res)
        if use_res:
            self._reserved -= 1
        self.ids[idx] = new
        self.pool.free(old)                   # drop our shared reference
        self.pool.stats.cow_copies += 1
        return old, new

    def padded(self, width: int):
        """int32 array of ``width`` block ids, scratch-padded — the shape-
        stable table row jitted paged attention consumes."""
        import numpy as np
        if len(self.ids) > width:
            raise ValueError(
                f"table has {len(self.ids)} blocks > padded width {width}")
        out = np.full((width,), SCRATCH_BLOCK, np.int32)
        out[: len(self.ids)] = self.ids
        return out

    def release(self) -> None:
        """Free all blocks (shared ones just drop this table's reference)
        and return any unused reservation."""
        for bid in self.ids:
            self.pool.free(bid)
        self.ids = []
        self._shared.clear()
        if self._reserved:
            self.pool.unreserve(self._reserved)
            self._reserved = 0
