"""Paged KV-cache block pool (vLLM-style) for the serving engines.

The KV cache is carved into fixed-size blocks of ``block_size`` token
positions; every attention layer's pool tensor shares ONE block-id space, so
allocating block ``b`` for a request reserves position storage in *every*
layer at once. A request owns an ordered :class:`BlockTable` — logical block
``i`` of the table covers absolute positions ``[i*block_size,
(i+1)*block_size)`` — and grows it lazily as its sequence advances, so device
memory high-water scales with the *sum of actual sequence lengths* rather
than ``batch × cache_len``.

Block id 0 is reserved as the **scratch block**: padding lanes of a bucketed
decode/prefill step scatter their (discarded) K/V there, exactly like the
scratch KV row of the contiguous path, so jitted scatters stay shape-stable
and never touch a live request's blocks.

Admission control is reservation-based: the scheduler calls
:meth:`KVBlockPool.try_reserve` with a request's worst-case block count
before admitting it, which guarantees that lazy growth during decode can
never fail mid-request (no preemption needed). ``PoolStats`` tracks
allocation traffic, the high-water mark, and admission failures — the
fragmentation/memory numbers ``benchmarks/engine_bench.py --mixed`` reports.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

SCRATCH_BLOCK = 0


def blocks_for(num_positions: int, block_size: int) -> int:
    """Blocks needed to cover ``num_positions`` token positions."""
    return max(0, -(-num_positions // block_size))


@dataclass
class PoolStats:
    allocs: int = 0
    frees: int = 0
    failed_reserves: int = 0     # admission attempts refused for lack of blocks
    high_water: int = 0          # max blocks simultaneously in use

    def utilization(self, num_blocks: int) -> float:
        """Peak fraction of allocatable blocks ever in use."""
        return self.high_water / max(num_blocks, 1)


class KVBlockPool:
    """Fixed-capacity pool of KV blocks with refcounts and reservations.

    ``num_blocks`` counts every block including the reserved scratch block 0,
    so ``num_blocks - 1`` blocks are allocatable. Refcounts support sharing a
    block between requests (e.g. a common prompt prefix); ``free`` drops one
    reference and only returns the block to the free list at zero.
    """

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks >= 2, "need at least one block beyond scratch"
        assert block_size >= 1
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list: recently-freed blocks are reused first, which keeps
        # the touched pool region small under steady-state churn
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._ref: dict[int, int] = {}
        self._reserved = 0
        self.stats = PoolStats()

    # -- capacity ----------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return len(self._ref)

    @property
    def reserved(self) -> int:
        return self._reserved

    @property
    def available(self) -> int:
        """Blocks neither allocated nor promised to an admitted request."""
        return self.num_free - self._reserved

    def try_reserve(self, n: int) -> bool:
        """Promise ``n`` future allocations (admission control). Reserved
        blocks are drawn down by ``alloc(reserved=True)`` as the request's
        table grows and returned by ``unreserve`` on retire."""
        if n < 0:
            raise ValueError(f"cannot reserve {n} blocks")
        if self.available < n:
            self.stats.failed_reserves += 1
            return False
        self._reserved += n
        return True

    def unreserve(self, n: int) -> None:
        if n > self._reserved:
            raise RuntimeError(
                f"unreserve({n}) exceeds outstanding reservation "
                f"{self._reserved}")
        self._reserved -= n

    # -- alloc/free --------------------------------------------------------
    def alloc(self, reserved: bool = False) -> int:
        """Allocate one block (refcount 1). ``reserved=True`` consumes one
        unit of a prior reservation instead of the open capacity."""
        if reserved:
            if self._reserved <= 0:
                raise RuntimeError("alloc(reserved=True) with no reservation")
            self._reserved -= 1
        elif self.available <= 0:
            raise RuntimeError(
                f"KV block pool exhausted: {self.num_blocks - 1} blocks, "
                f"{self._reserved} reserved — admit fewer requests or grow "
                "the pool")
        if not self._free:
            raise RuntimeError("KV block pool exhausted")
        bid = self._free.pop()
        self._ref[bid] = 1
        self.stats.allocs += 1
        self.stats.high_water = max(self.stats.high_water, len(self._ref))
        return bid

    def retain(self, bid: int) -> None:
        """Add a reference to an allocated block (prefix sharing)."""
        if bid not in self._ref:
            raise RuntimeError(f"retain of unallocated block {bid}")
        self._ref[bid] += 1

    def free(self, bid: int) -> None:
        """Drop one reference; the block returns to the free list at zero.
        Freeing an unallocated block raises (double-free guard)."""
        if bid not in self._ref:
            raise RuntimeError(f"double free of block {bid}")
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            del self._ref[bid]
            self._free.append(bid)
            self.stats.frees += 1

    def check_leaks(self) -> None:
        """Invariant check: every block is either free or refcounted, and
        scratch is never handed out."""
        assert SCRATCH_BLOCK not in self._ref
        assert SCRATCH_BLOCK not in self._free
        overlap = set(self._free) & set(self._ref)
        assert not overlap, f"blocks both free and in use: {overlap}"
        total = len(self._free) + len(self._ref)
        assert total == self.num_blocks - 1, (
            f"leak: {self.num_blocks - 1 - total} blocks unaccounted for")
        assert 0 <= self._reserved <= self.num_free


class BlockTable:
    """Ordered per-request block list; logical block ``i`` covers positions
    ``[i*block_size, (i+1)*block_size)``. Grows lazily via :meth:`ensure`,
    drawing on the request's admission reservation first."""

    def __init__(self, pool: KVBlockPool, reserved_blocks: int = 0):
        self.pool = pool
        self.ids: List[int] = []
        self._reserved = reserved_blocks

    def __len__(self) -> int:
        return len(self.ids)

    @property
    def num_positions(self) -> int:
        return len(self.ids) * self.pool.block_size

    @property
    def reserved(self) -> int:
        """Blocks still promised to this request but not yet allocated."""
        return self._reserved

    def ensure(self, pos: int) -> None:
        """Grow the table to cover absolute position ``pos``."""
        need = pos // self.pool.block_size + 1
        while len(self.ids) < need:
            use_res = self._reserved > 0
            self.ids.append(self.pool.alloc(reserved=use_res))
            if use_res:
                self._reserved -= 1

    def padded(self, width: int):
        """int32 array of ``width`` block ids, scratch-padded — the shape-
        stable table row jitted paged attention consumes."""
        import numpy as np
        if len(self.ids) > width:
            raise ValueError(
                f"table has {len(self.ids)} blocks > padded width {width}")
        out = np.full((width,), SCRATCH_BLOCK, np.int32)
        out[: len(self.ids)] = self.ids
        return out

    def release(self) -> None:
        """Free all blocks and return any unused reservation."""
        for bid in self.ids:
            self.pool.free(bid)
        self.ids = []
        if self._reserved:
            self.pool.unreserve(self._reserved)
            self._reserved = 0
