"""Tiered sharded expert store: device/host/peer/disk parameter hierarchy.

The paper's premise is that the expert set does not fit where compute
happens; ``HostExpertStore`` assumed the opposite one level up — every
expert in one host's DRAM. This module generalises it into an explicit
tier hierarchy, simulated multi-host in one process:

  tier 0  device slot buffer          (serving/offload.SlotBuffer + the
                                       ExpertCache control plane)
  tier 1  local host DRAM             (this shard's home experts + an LRU
                                       cache of promoted copies)
  tier 2  peer-host DRAM shards       (modeled interconnect: latency + bw)
  tier 3  disk / mmap spill           (a real ``np.memmap`` round-trip for
                                       experts past a shard's DRAM budget)

**Placement** is consistent-hash: every ``(moe_layer, expert)`` key hashes
onto a ring of shard virtual nodes, so its *authoritative home* is stable
under shard add/remove (only keys adjacent to the changed shard move). A
shard's home experts live in its DRAM up to ``shard_dram_experts``; the
overflow spills to a memory-mapped file — fetched through real file I/O so
the tier-3 path is exercised, not just modeled.

**Residency** is a ledger: an expert is findable in exactly one
authoritative home plus any number of cached tiers; promotion on access
inserts a tier-1 cached copy (LRU, ``cache_experts`` capacity), demotion
from tier 0 (slot-buffer eviction) refreshes that copy instead of dropping
the bytes, and pinned entries are unevictable at every tier. The ledger
asserts the invariants — tests interleave fetch/promote/demote/evict/pin
and check nothing is ever lost, double-resident in one tier, or evicted
while pinned.

**Fetch accounting** reuses the OverlapTracker model (serving/offload.py):
each tier is one serial async channel, a fetch's modeled duration is
``latency + nbytes/bandwidth`` of its source tier, and stall reports break
down by tier.

**Horizon-aware prefetch**: the store tells the engine how many MoE layers
ahead a key must be requested (``prefetch_horizon``) based on the tier it
currently resides in — a tier-3 expert is requested layers earlier than a
tier-1 one, because slower tiers just need a longer prediction horizon to
hide behind compute.
"""
from __future__ import annotations

import bisect
import hashlib
import os
import tempfile
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.serving.offload import (TIER_DISK, TIER_HOST, TIER_PEER,
                                   FetchInfo, HostExpertStore, Key)


@dataclass(frozen=True)
class TierConfig:
    """Shard/tier knobs for :class:`TieredExpertStore`.

      * ``num_shards`` — hosts sharing the expert set over the consistent-
        hash ring.
      * ``local_shard`` — which shard id is the serving process.
      * ``shard_dram_experts`` — cap on home experts a shard keeps in DRAM;
        the overflow spills to disk (tier 3). ``None`` disables spill.
      * ``cache_experts`` — slots in the local tier-1 LRU cache of promoted
        peer/disk experts (0 disables promotion caching).
      * ``host_bw`` — tier-1 host-to-device bandwidth, bytes/s.
      * ``peer_bw`` / ``peer_latency_s`` — tier-2 interconnect bandwidth
        (bytes/s) and per-fetch latency (seconds).
      * ``disk_bw`` / ``disk_latency_s`` — tier-3 read bandwidth (bytes/s)
        and per-fetch latency (seconds).
      * ``vnodes`` — virtual nodes each shard contributes to the hash ring
        (placement smoothness vs ring size).
      * ``seed`` — ring hash seed (placement is deterministic in it).
      * ``horizons`` — ``horizons[t]`` is how many MoE layers ahead a
        tier-``t`` expert is prefetched; the default scales lookahead with
        tier depth and ``(1, 1, 1, 1)`` is the fixed-horizon baseline the
        benchmark compares against.
      * ``deep_confidence`` — per-key learned gate on deep prefetch: a key
        predicted more than one MoE layer ahead is only fetched early when
        the predictor's confidence (sigmoid probability) for it is at
        least this threshold, pruning wasted deep fetches while keeping
        the stall hiding. ``None`` (default) keeps the purely tier-static
        gate; policies without a confidence notion pass the gate
        unchanged. Applies *on top of* the per-tier ``horizons`` depth.
      * ``cold_dtype`` — storage dtype of the cold tiers (2: peer shards,
        3: disk spill). ``"int8"`` stores/ships cold experts quantized
        (per-output-channel absmax scales, quantize at placement/demote,
        dequantize on promote), shrinking the spill memmap and cutting
        peer/disk fetch bytes and modeled transfer time — at the cost of
        bit-exactness: token streams may diverge from the full-precision
        reference, so it is opt-in. ``None`` (default) keeps every tier
        bit-exact and stream-parity-pinned.
      * ``dispatch`` — how the engine satisfies a peer-resident (tier-2)
        expert: ``"fetch"`` (default) always pulls the weights through the
        interconnect; ``"ship"`` always sends the token activations to the
        peer, computes the expert FFN there, and returns the outputs;
        ``"auto"`` picks the cheaper path per (expert, token-count) from
        the :class:`DispatchPlanner` roofline. Streams are token-identical
        across all three modes: a ship computes with the same weights the
        peer would have served, through the same jitted expert program.
        With ``cold_dtype="int8"`` a ship runs against the peer's
        *dequantized* cold copy — the exact bytes a fetch would deliver —
        so the int8 logit deviation is identical whichever path ``auto``
        picks.
    """
    num_shards: int = 1
    local_shard: int = 0
    shard_dram_experts: Optional[int] = None
    cache_experts: int = 0
    host_bw: float = 100e9
    peer_bw: float = 25e9
    peer_latency_s: float = 20e-6
    disk_bw: float = 3e9
    disk_latency_s: float = 100e-6
    vnodes: int = 64
    seed: int = 0
    horizons: Tuple[int, int, int, int] = (1, 1, 2, 3)
    deep_confidence: Optional[float] = None
    cold_dtype: Optional[str] = None
    dispatch: str = "fetch"

    def tier_duration(self, tier: int, nbytes: int) -> Optional[float]:
        """Modeled transfer time for an ``nbytes`` fetch from ``tier`` into
        a device slot (None for tier 1: the SlotBuffer's own host-bandwidth
        model keeps the single-host numbers bit-identical)."""
        if tier == TIER_HOST:
            return None
        if tier == TIER_PEER:
            return self.peer_latency_s + nbytes / self.peer_bw
        return self.disk_latency_s + nbytes / self.disk_bw


@dataclass(frozen=True)
class DispatchPlanner:
    """Roofline cost model behind the per-(expert, token-count) fetch-vs-
    ship decision (``TierConfig.dispatch``).

    Both paths pay the interconnect latency once. Beyond that:

      * ``fetch`` moves the expert's weights — ``weight_bytes`` over
        ``peer_bw`` (``weight_bytes`` is the *wire* size: the quantized
        cold size when ``cold_dtype`` is set);
      * ``ship`` moves ``tokens * act_bytes_per_token`` activation bytes
        (the token vectors out plus the FFN outputs back: 2 * d_model *
        itemsize each token) and buys the peer's expert-FFN compute —
        ``ffn_s_base`` (the peer streaming the expert's weights from its
        own DRAM once) plus ``tokens * ffn_s_per_token`` (matvec flops),
        terms produced by :func:`repro.launch.dryrun.expert_ffn_roofline`.

    Fields:
      * ``weight_bytes`` — wire bytes of one expert fetch from the peer.
      * ``act_bytes_per_token`` — round-trip activation bytes per token.
      * ``ffn_s_per_token`` — remote per-token expert-FFN compute seconds.
      * ``ffn_s_base`` — remote token-independent seconds (weight read).
      * ``peer_latency_s`` / ``peer_bw`` — the tier-2 interconnect model
        (same numbers :meth:`TierConfig.tier_duration` charges a fetch).
      * ``mode`` — ``"fetch"``/``"ship"`` force their path; ``"auto"``
        takes the cheaper one, preferring ship on exact ties (a ship
        leaves tier 0 untouched, so the tie costs no cache churn).

    ``fetch_s`` is constant in tokens and strictly increasing in
    ``weight_bytes``; ``ship_s`` is strictly increasing in tokens — so
    ``auto`` has a single breakeven token count per expert, below which
    tokens travel and above which weights do. Property tests pin the
    monotonicity and that ``choose`` never returns the strictly more
    expensive path.
    """
    weight_bytes: int
    act_bytes_per_token: int
    ffn_s_per_token: float
    ffn_s_base: float
    peer_latency_s: float
    peer_bw: float
    mode: str = "auto"

    def fetch_s(self) -> float:
        """Modeled seconds to pull the expert's weights from the peer."""
        return self.peer_latency_s + self.weight_bytes / self.peer_bw

    def ship_s(self, tokens: int) -> float:
        """Modeled seconds to ship ``tokens`` to the peer, compute the
        expert FFN there, and return the outputs."""
        return (self.peer_latency_s
                + tokens * self.act_bytes_per_token / self.peer_bw
                + self.ffn_s_base + tokens * self.ffn_s_per_token)

    def ship_bytes(self, tokens: int) -> int:
        """Wire bytes a ship of ``tokens`` puts on the interconnect."""
        return tokens * self.act_bytes_per_token

    def choose(self, tokens: int) -> str:
        """``"fetch"`` or ``"ship"`` for a group of ``tokens`` tokens."""
        if self.mode != "auto":
            return self.mode
        return "ship" if self.ship_s(tokens) <= self.fetch_s() else "fetch"


def _hash64(*parts) -> int:
    """Deterministic 64-bit hash (process-hash randomisation immune)."""
    h = hashlib.blake2b(repr(parts).encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big")


class ConsistentHashRing:
    """Consistent-hash placement of keys onto shards.

    Each shard contributes ``vnodes`` points on a 64-bit ring; a key is
    homed on the shard owning the first point clockwise of the key's hash.
    Adding or removing a shard only re-homes the keys whose clockwise walk
    now lands on (or used to land on) that shard's points — placement of
    everything else is stable, which is what makes re-sharding a live
    store feasible.
    """

    def __init__(self, shards: Sequence[int], vnodes: int = 64,
                 seed: int = 0):
        self.vnodes = vnodes
        self.seed = seed
        self._shards: Set[int] = set()
        self._points: List[Tuple[int, int]] = []   # (hash, shard) sorted
        for s in shards:
            self.add_shard(s)

    @property
    def shards(self) -> Set[int]:
        return set(self._shards)

    def _shard_points(self, shard: int) -> List[Tuple[int, int]]:
        return [(_hash64("vnode", self.seed, shard, v), shard)
                for v in range(self.vnodes)]

    def add_shard(self, shard: int) -> None:
        assert shard not in self._shards, f"shard {shard} already on ring"
        self._shards.add(shard)
        for p in self._shard_points(shard):
            bisect.insort(self._points, p)

    def remove_shard(self, shard: int) -> None:
        assert shard in self._shards, f"shard {shard} not on ring"
        self._shards.discard(shard)
        self._points = [p for p in self._points if p[1] != shard]

    def lookup(self, key) -> int:
        assert self._points, "empty ring"
        h = _hash64("key", self.seed, key)
        i = bisect.bisect_right(self._points, (h, 2**64))
        return self._points[i % len(self._points)][1]


@dataclass
class StoreStats:
    """Per-tier fetch traffic + residency churn.

      * ``fetches_by_tier`` / ``bytes_by_tier`` — fetch counts and bytes
        served per source tier (cold-tier bytes are the quantized wire
        size when ``cold_dtype`` is set).
      * ``promotions`` — tier-1 cached copies inserted on access.
      * ``demotions`` — tier-0 evictions absorbed into tier 1.
      * ``cache_evictions`` — tier-1 cached copies dropped (home remains).
      * ``cache_evictions_learned`` — tier-1 evictions whose victim choice
        was informed by a live reuse-distance prediction (learned
        replacement active and at least one candidate scored).
      * ``cache_evictions_lru`` — tier-1 evictions that fell back to pure
        LRU order under learned replacement (no candidate had a
        prediction).
      * ``quantized_fetches`` — fetches served from int8 cold storage
        (dequantized on the way up).
      * ``spilled_experts`` — experts homed on disk at placement time.
      * ``ships`` — compute-dispatch round trips: token groups sent to a
        peer-resident expert instead of fetching its weights.
      * ``ship_bytes`` — activation bytes those round trips put on the
        interconnect (tokens out + outputs back; no weight bytes move).
      * ``ship_tokens`` — tokens computed remotely across all ships.
    """
    fetches_by_tier: Dict[int, int] = field(default_factory=dict)
    bytes_by_tier: Dict[int, int] = field(default_factory=dict)
    promotions: int = 0
    demotions: int = 0
    cache_evictions: int = 0
    cache_evictions_learned: int = 0
    cache_evictions_lru: int = 0
    quantized_fetches: int = 0
    spilled_experts: int = 0
    ships: int = 0
    ship_bytes: int = 0
    ship_tokens: int = 0

    def count(self, tier: int, nbytes: int) -> None:
        self.fetches_by_tier[tier] = self.fetches_by_tier.get(tier, 0) + 1
        self.bytes_by_tier[tier] = self.bytes_by_tier.get(tier, 0) + nbytes

    def as_dict(self) -> dict:
        """Every counter as a JSON-ready dict (stats-registration lint)."""
        from dataclasses import asdict
        return asdict(self)


class ResidencyLedger:
    """Where every expert lives: one authoritative home + cached copies.

    Invariants (asserted by mutators and :meth:`check`):

    * every registered key has exactly ONE authoritative home, set once at
      placement and never dropped — an expert can never be lost;
    * a key is resident at most once per tier: the home tier holds the
      authoritative copy, so a cached copy may not shadow it, and a tier
      holds at most one cached copy;
    * a pinned key's copies are unevictable at every tier
      (:meth:`drop_copy` refuses while the pin refcount is nonzero).
    """

    def __init__(self):
        self._home: Dict[Key, Tuple[int, int]] = {}   # key -> (shard, tier)
        self._cached: Dict[Key, Set[int]] = {}        # key -> cached tiers
        self._pins: Dict[Key, int] = {}
        self._accesses: Dict[Key, int] = {}           # placement signal

    def place(self, key: Key, shard: int, tier: int) -> None:
        assert key not in self._home, f"{key!r} already has a home"
        self._home[key] = (shard, tier)

    def home(self, key: Key) -> Tuple[int, int]:
        return self._home[key]

    def rehome(self, key: Key, shard: int, tier: int) -> None:
        """Move the authoritative copy (re-sharding); cached copies at the
        new home tier would now be double-resident, so they must be gone."""
        assert key in self._home, f"{key!r} has no home to move"
        assert tier not in self._cached.get(key, ()), (
            f"rehome of {key!r} onto tier {tier} would double-res a cache")
        self._home[key] = (shard, tier)

    def cached_tiers(self, key: Key) -> Set[int]:
        return set(self._cached.get(key, ()))

    def add_copy(self, key: Key, tier: int) -> None:
        assert key in self._home, f"copy of unplaced key {key!r}"
        assert tier != self._home[key][1], (
            f"{key!r}: cached copy would double-res home tier {tier}")
        tiers = self._cached.setdefault(key, set())
        assert tier not in tiers, f"{key!r} double-resident in tier {tier}"
        tiers.add(tier)

    def drop_copy(self, key: Key, tier: int) -> None:
        assert not self.pinned(key), f"evicting pinned {key!r}"
        tiers = self._cached.get(key, set())
        assert tier in tiers, f"{key!r} has no copy in tier {tier}"
        tiers.discard(tier)
        if not tiers:
            self._cached.pop(key, None)

    def tier_of(self, key: Key) -> int:
        """Fastest tier the key is findable in (home or cached copy)."""
        return min(self._cached.get(key, set()) | {self._home[key][1]})

    # -- access accounting -------------------------------------------------
    def note_access(self, key: Key) -> None:
        """Record a use of ``key`` for placement/promotion decisions.
        Shipped computations call this too: a remote compute IS demand for
        the expert even though no bytes moved and no tier gained a copy —
        future placement (rebalance, promotion heuristics) should see it."""
        assert key in self._home, f"access of unplaced key {key!r}"
        self._accesses[key] = self._accesses.get(key, 0) + 1

    def accesses(self, key: Key) -> int:
        return self._accesses.get(key, 0)

    # -- pinning -----------------------------------------------------------
    def pin(self, key: Key) -> None:
        assert key in self._home, f"pin of unplaced key {key!r}"
        self._pins[key] = self._pins.get(key, 0) + 1

    def unpin(self, key: Key) -> None:
        n = self._pins.get(key, 0) - 1
        if n <= 0:
            self._pins.pop(key, None)
        else:
            self._pins[key] = n

    def pinned(self, key: Key) -> bool:
        return self._pins.get(key, 0) > 0

    # -- invariants --------------------------------------------------------
    def check(self, keys: Optional[Sequence[Key]] = None) -> None:
        """Full-ledger invariant sweep (tests call this after every op)."""
        for key in (keys if keys is not None else self._home):
            assert key in self._home, f"{key!r} lost: no authoritative home"
            home_tier = self._home[key][1]
            cached = self._cached.get(key, set())
            assert home_tier not in cached, (
                f"{key!r} double-resident in home tier {home_tier}")
            # set membership enforces one-copy-per-tier; tiers are sane
            assert all(t in (TIER_HOST, TIER_PEER, TIER_DISK)
                       for t in cached | {home_tier})
        for key in self._cached:
            assert key in self._home, f"cached copy of unplaced {key!r}"


class TieredExpertStore:
    """Device/host/peer/disk expert parameter hierarchy behind the
    ``HostExpertStore`` interface (``fetch``/``get``/``tier_of``/
    ``demote``/``prefetch_horizon``), so the engines' slot buffer and
    ExpertCache run unchanged on top of it.

    Multi-host is simulated in one process: ``num_shards`` shard views over
    one parameter set, a consistent-hash ring assigning every key a home
    shard, per-tier bandwidth/latency models for the fetch channels, and a
    real ``np.memmap`` file for the disk tier. Weights returned are
    bit-identical to ``HostExpertStore.get`` regardless of tier — streams
    stay token-identical; only the modeled timeline changes.
    """

    def __init__(self, expert_params_per_layer, tc: TierConfig,
                 spill_dir: Optional[str] = None, scorer=None,
                 telemetry=None):
        assert tc.num_shards >= 1
        assert 0 <= tc.local_shard < tc.num_shards
        assert len(tc.horizons) == 4 and min(tc.horizons) >= 1
        assert tc.cold_dtype in (None, "int8"), \
            f"unsupported cold_dtype {tc.cold_dtype!r}"
        assert tc.dispatch in ("fetch", "ship", "auto"), \
            f"unsupported dispatch {tc.dispatch!r}"
        self.base = HostExpertStore(expert_params_per_layer)
        self.tc = tc
        # learned tier-1 replacement: when a ReuseDistanceScorer is wired
        # in, cache eviction picks the copy predicted furthest from reuse
        # (LRU as tiebreak/fallback) instead of pure recency order
        self.scorer = scorer
        self.num_layers = self.base.num_layers
        self.num_experts = self.base.num_experts
        self.bytes_per_expert = self.base.bytes_per_expert
        # wire/storage size of one cold-tier expert: int8 payload plus one
        # f32 absmax scale per output channel of each of the 3 matrices
        lp = self.base.layers[0]
        self.cold_bytes_per_expert = sum(
            int(np.prod(lp[k][0].shape)) + lp[k][0].shape[-1] * 4
            for k in ("w_gate", "w_up", "w_down"))
        self._wdtype = lp["w_gate"].dtype
        self.max_horizon = max(tc.horizons)
        self.ring = ConsistentHashRing(range(tc.num_shards), tc.vnodes,
                                       tc.seed)
        self.ledger = ResidencyLedger()
        self.stats = StoreStats()
        # optional serving.telemetry.Telemetry: tier-1 promotions and
        # demotions are counted (pure observer; None records nothing)
        self.tel = telemetry
        # tier-1 LRU cache of promoted peer/disk experts (weights tuples)
        self._cache: "OrderedDict[Key, tuple]" = OrderedDict()
        # weights currently up in a device slot (fetch .. demote bracket):
        # demotion reuses these bytes instead of re-reading the spill file
        self._on_device: Dict[Key, tuple] = {}
        self._spill_dir = spill_dir
        self._place_all(spill_dir)

    # -- placement ---------------------------------------------------------
    def _place_all(self, spill_dir: Optional[str]) -> None:
        """Home every key on the ring; spill each shard's DRAM overflow to
        the memmap file (real file I/O on tier-3 fetches)."""
        by_shard: Dict[int, List[Key]] = {}
        for layer in range(self.num_layers):
            for e in range(self.num_experts):
                key = (layer, e)
                by_shard.setdefault(self.ring.lookup(key), []).append(key)
        self.home_shard: Dict[Key, int] = {}
        spilled: List[Key] = []
        cap = self.tc.shard_dram_experts
        for shard, keys in sorted(by_shard.items()):
            for i, key in enumerate(keys):
                self.home_shard[key] = shard
                if cap is not None and i >= cap:
                    spilled.append(key)
        self._spill_row: Dict[Key, int] = {k: i
                                           for i, k in enumerate(spilled)}
        # quantized copies of peer-homed experts, built lazily on first
        # fetch (the peer "stores" them int8; the transfer ships int8)
        self._cold: Dict[Key, tuple] = {}
        self._spill = self._build_spill(spilled, spill_dir)
        for key, shard in self.home_shard.items():
            if key in self._spill_row:
                tier = TIER_DISK
            elif shard == self.tc.local_shard:
                tier = TIER_HOST
            else:
                tier = TIER_PEER
            self.ledger.place(key, shard, tier)
        self.stats.spilled_experts = len(spilled)

    def _build_spill(self, spilled: Sequence[Key],
                     spill_dir: Optional[str]):
        self._spill_scales: Dict[Key, tuple] = {}
        if not spilled:
            self._spill_path = None
            return None
        wg0, wu0, wd0 = self.base.get(spilled[0])
        self._shapes = (wg0.shape, wu0.shape, wd0.shape)
        sizes = [int(np.prod(s)) for s in self._shapes]
        self._offsets = np.cumsum([0] + sizes)
        fd, path = tempfile.mkstemp(suffix=".expertspill",
                                    dir=spill_dir, prefix="tier3_")
        os.close(fd)
        self._spill_path = path
        cold = self.tc.cold_dtype is not None
        # int8 cold storage: the memmap holds quantized rows (1 byte per
        # element instead of the weight dtype's width); the tiny per-channel
        # scale vectors stay in RAM — quantize-on-demote to disk happens
        # here, at placement, since placement IS the demotion to tier 3
        mm = np.memmap(path, dtype=np.int8 if cold else wg0.dtype,
                       mode="w+",
                       shape=(len(spilled), int(self._offsets[-1])))
        for i, key in enumerate(spilled):
            ws = self.base.get(key)
            if cold:
                ws, scales = self._quantize(ws)
                self._spill_scales[key] = scales
            for j, w in enumerate(ws):
                mm[i, self._offsets[j]: self._offsets[j + 1]] = w.reshape(-1)
        mm.flush()
        return mm

    # -- cold-tier quantization --------------------------------------------
    def _quantize(self, ws):
        """Symmetric int8 with one absmax scale per output channel of each
        matrix (axis 0 reduced — per ``f`` channel for w_gate/w_up, per
        ``d`` channel for w_down)."""
        qs, scales = [], []
        for w in ws:
            w = np.asarray(w, np.float32)
            s = np.max(np.abs(w), axis=0) / 127.0
            s = np.where(s > 0, s, 1.0).astype(np.float32)
            qs.append(np.clip(np.rint(w / s), -127, 127).astype(np.int8))
            scales.append(s)
        return tuple(qs), tuple(scales)

    def _dequantize(self, qs, scales):
        return tuple((q.astype(np.float32) * s).astype(self._wdtype)
                     for q, s in zip(qs, scales))

    def _cold_copy(self, key: Key):
        """The int8 form a peer shard stores (and ships) for ``key`` —
        quantized once, cached, so repeat fetches are value-identical."""
        ent = self._cold.get(key)
        if ent is None:
            ent = self._quantize(self.base.get(key))
            self._cold[key] = ent
        return ent

    def close(self) -> None:
        """Release the spill memmap and unlink its file."""
        if self._spill is not None:
            self._spill = None
            try:
                os.unlink(self._spill_path)
            except OSError:
                pass
            self._spill_path = None

    def __del__(self):  # best-effort temp-file cleanup
        try:
            self.close()
        except Exception:
            pass

    def _read_spill(self, key: Key):
        """Tier-3 read: pull the expert's rows out of the memmap (copies —
        this is the actual disk -> DRAM transfer), dequantizing when the
        cold tiers store int8."""
        row = self._spill[self._spill_row[key]]
        parts = tuple(
            np.array(row[self._offsets[j]: self._offsets[j + 1]]
                     ).reshape(self._shapes[j])
            for j in range(3))
        if self.tc.cold_dtype is not None:
            return self._dequantize(parts, self._spill_scales[key])
        return parts

    def _is_cold(self, key: Key, tier: int) -> bool:
        """True when a fetch from ``tier`` ships the quantized form."""
        return (self.tc.cold_dtype is not None
                and tier in (TIER_PEER, TIER_DISK))

    def _materialize(self, key: Key):
        """The authoritative bytes, wherever home is (no modeled cost).
        With int8 cold tiers the authoritative form of a cold-homed key IS
        the quantized one — dequantizing here keeps every path that can
        serve a key value-identical."""
        if key in self._spill_row:
            return self._read_spill(key)
        if self._is_cold(key, self.ledger.home(key)[1]):
            return self._dequantize(*self._cold_copy(key))
        return self.base.get(key)

    # -- store interface ---------------------------------------------------
    @property
    def layers(self):
        """Per-layer weight dicts (HostExpertStore parity: the SlotBuffer
        reads shapes/dtypes from here)."""
        return self.base.layers

    def tier_of(self, key: Key) -> int:
        """Fastest tier a fetch of ``key`` would be served from."""
        if key in self._cache:
            return TIER_HOST
        return self.ledger.tier_of(key)

    def prefetch_horizon(self, key: Key) -> int:
        """MoE layers of lookahead this key needs: deeper tiers are
        requested earlier so their longer fetch hides behind more
        compute."""
        return self.tc.horizons[self.tier_of(key)]

    def fetch(self, key: Key):
        """(weights, FetchInfo): serve from the fastest resident tier,
        promoting peer/disk fetches into the tier-1 cache on the way.
        With ``cold_dtype="int8"`` a cold-tier fetch moves the quantized
        bytes (plus scales) and dequantizes on promote — the tier-1 cached
        copy and the device slot always hold the dequantized form."""
        nbytes = self.bytes_per_expert
        if key in self._cache:
            self._cache.move_to_end(key)
            w = self._cache[key]
            tier = TIER_HOST
        else:
            tier = self.ledger.tier_of(key)
            if tier == TIER_DISK:
                w = self._read_spill(key)
            elif self._is_cold(key, tier):
                w = self._dequantize(*self._cold_copy(key))
            else:
                w = self.base.get(key)
            if self._is_cold(key, tier):
                nbytes = self.cold_bytes_per_expert
                self.stats.quantized_fetches += 1
            if tier != TIER_HOST and self.tc.cache_experts > 0:
                self._promote(key, w)
                self.stats.promotions += 1
                if self.tel is not None and self.tel.enabled:
                    self.tel.counter("store.promotions")
        self._on_device[key] = w
        self.ledger.note_access(key)
        self.stats.count(tier, nbytes)
        return w, FetchInfo(tier, nbytes, self.tc.tier_duration(tier, nbytes))

    def get(self, key: Key):
        """Weights only (HostExpertStore parity API)."""
        return self.fetch(key)[0]

    def ship(self, key: Key, tokens: int, wire_bytes: int):
        """Compute-dispatch access: the peer computes the expert FFN on a
        shipped token group instead of the weights being fetched. Returns
        the weights the peer would compute with — ``base`` bytes, or the
        deterministic *dequantized cold copy* when ``cold_dtype`` is set,
        i.e. exactly what a fetch would have delivered, so fetch/ship
        streams match even on quantized tiers. Accounting only: counts
        the ship, refreshes the recency of any existing tier-1 cached copy
        and notes the access in the ledger — NO tier-0/tier-1 insert and
        no weight bytes move (the anti-thrash half of the design: a
        one-off cold expert serves its few tokens without evicting the
        warm working set)."""
        assert self.ledger.home(key)[1] == TIER_PEER, \
            f"ship of non-peer-homed key {key!r}"
        if self._is_cold(key, TIER_PEER):
            w = self._dequantize(*self._cold_copy(key))
        else:
            w = self.base.get(key)
        if key in self._cache:          # refresh, never insert
            self._cache.move_to_end(key)
        self.ledger.note_access(key)
        self.stats.ships += 1
        self.stats.ship_bytes += wire_bytes
        self.stats.ship_tokens += tokens
        return w

    def demote(self, key: Key) -> None:
        """Tier-0 eviction callback: keep the bytes one tier down instead
        of dropping them — refresh (or insert) the tier-1 cached copy so a
        re-fetch is a host fetch, not a peer/disk one. The bytes come from
        the fetch that filled the slot (``_on_device``), not a fresh
        slow-tier read — demotion is a move down, never new I/O."""
        w = self._on_device.pop(key, None)
        if self.tc.cache_experts <= 0:
            return                      # no tier-1 cache to demote into
        if self.ledger.home(key)[1] == TIER_HOST:
            return                      # home IS local DRAM: nothing to do
        if key in self._cache:
            self._cache.move_to_end(key)
            return
        self._promote(key, w if w is not None else self._materialize(key))
        self.stats.demotions += 1
        if self.tel is not None and self.tel.enabled:
            self.tel.counter("store.demotions")

    # -- tier-1 cache ------------------------------------------------------
    def _promote(self, key: Key, weights) -> None:
        if self.tc.cache_experts <= 0:
            return
        self._cache[key] = weights
        self._cache.move_to_end(key)
        self.ledger.add_copy(key, TIER_HOST)
        self._shrink_cache()

    def _shrink_cache(self) -> None:
        """Evict unpinned cached copies back to capacity. Pinned entries
        are skipped — the cache may transiently exceed its cap while every
        resident copy is pinned. Default order is LRU; with a
        ReuseDistanceScorer wired in (learned replacement) the victims are
        the copies predicted furthest from reuse — unscored copies count
        as infinitely far, LRU order breaks ties, so without predictions
        the choice degrades to exact LRU."""
        over = len(self._cache) - self.tc.cache_experts
        if over <= 0:
            return
        evictable = [k for k in self._cache
                     if not self.ledger.pinned(k)]
        if self.scorer is not None:
            scored, informed = [], False
            for i, k in enumerate(evictable):
                d = self.scorer.distance(k)
                if d is None:
                    d = float("inf")
                else:
                    informed = True
                scored.append((-d, i, k))       # furthest first, LRU ties
            scored.sort()
            victims = [k for _, _, k in scored[:over]]
            if informed:
                self.stats.cache_evictions_learned += len(victims)
            else:
                self.stats.cache_evictions_lru += len(victims)
        else:
            victims = evictable[:over]
        for key in victims:
            del self._cache[key]
            self.ledger.drop_copy(key, TIER_HOST)
            self.stats.cache_evictions += 1

    # -- pinning -----------------------------------------------------------
    def pin(self, key: Key) -> None:
        """Refcounted guard: pinned keys' copies are unevictable at every
        tier (the home copy is never evictable anyway)."""
        self.ledger.pin(key)

    def unpin(self, key: Key) -> None:
        self.ledger.unpin(key)
        self._shrink_cache()            # deferred evictions apply now

    # -- re-sharding -------------------------------------------------------
    def rebalance(self, num_shards: int) -> int:
        """Re-home every key onto a ring of ``num_shards`` shards (grow or
        shrink); returns how many keys moved shard. Consistent hashing
        keeps the move count near ``moved/total ~ changed_shards/total``;
        a unit test pins stability. DRAM/disk split per shard is
        recomputed and the spill file rebuilt; the ring (not the original
        ``TierConfig.num_shards``) is authoritative afterwards. Pin
        refcounts and tier-1 cached copies survive the move."""
        assert num_shards > self.tc.local_shard, \
            "cannot remove the local shard"
        old = dict(self.home_shard)
        for s in set(self.ring.shards):
            if s >= num_shards:
                self.ring.remove_shard(s)
        for s in range(num_shards):
            if s not in self.ring.shards:
                self.ring.add_shard(s)
        self.close()
        # rebuild placement from scratch, carrying pins over (a pinned
        # expert stays pinned through a re-shard). Cached copies survive
        # too: they are tier-1 copies whatever the new home is — unless
        # the new home IS tier 1, which would double-res; drop those.
        pins = dict(self.ledger._pins)
        self.ledger = ResidencyLedger()
        self._place_all(self._spill_dir)
        self.ledger._pins = pins
        for key in list(self._cache):
            if self.ledger.home(key)[1] == TIER_HOST:
                del self._cache[key]
            else:
                self.ledger.add_copy(key, TIER_HOST)
        return sum(1 for k, s in self.home_shard.items() if old.get(k) != s)
