"""Prefix-sharing KV cache: a radix index over block-aligned token runs.

Heavy traffic shares prompt structure — system prompts, few-shot templates,
chat history. This module lets the paged scheduler detect that sharing and
map it onto the refcounted blocks ``serving/kvpool.py`` already supports:

* The index is a **trie keyed on whole blocks of tokens**: a node at depth
  ``d`` is identified by the path ``tokens[0 : (d+1)*block_size]`` and holds
  the pool block id whose KV covers positions ``[d*bs, (d+1)*bs)`` of that
  token run, plus the per-MoE-layer expert sets observed when those
  positions were originally prefilled (the **expert-activation replay**
  payload — a prefix hit warms the ExpertCache without running the
  predictor, reuse complementing prediction).
* The cache holds **one reference** per indexed block. Requests that match
  a prefix ``retain`` the blocks into their own ``BlockTable`` (via
  ``BlockTable.adopt``), so an indexed block is pinned while any request
  reads it and survives the request's retirement.
* Shared blocks are **read-only**; a matched request that must write into a
  partially-used shared block (its prompt ends mid-block) copies it first —
  ``BlockTable.make_private`` plus the engine's device-page copy.
* **Eviction** under pool pressure walks least-recently-used *leaves* whose
  block has no holder besides the cache itself (``ref_count == 1``); inner
  nodes are never evicted before their children, so a cached path always
  proves token equality for every block above a match.

The index stores ids, not tensors — the KV bytes live in the pool either
way, so a cached prefix costs nothing beyond the blocks it keeps alive.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.kvpool import KVBlockPool, blocks_for


@dataclass
class PrefixStats:
    hits: int = 0                # admissions that matched >= 1 block
    misses: int = 0              # admissions that matched nothing
    hit_tokens: int = 0          # prompt positions whose prefill was skipped
    extensions: int = 0          # blocks adopted at a mid-prefill boundary
    inserted_blocks: int = 0     # blocks newly indexed (incl. tails)
    inserted_tails: int = 0      # partial tail blocks newly indexed
    evicted_blocks: int = 0      # indexed blocks freed under pool pressure

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.hits + self.misses, 1)


class _Node:
    """One cached block: trie child key is the block's token tuple.

    ``children`` holds whole-block continuations; ``tails`` holds
    *partial* tail blocks (< block_size prompt tokens, always leaves) —
    the sub-block index. A tail node's ``n`` is how many prompt positions
    of its block are valid; whole-block nodes have ``n == block_size``."""

    __slots__ = ("bid", "experts", "children", "tails", "parent", "tick",
                 "n")

    def __init__(self, bid: int, experts: Dict[int, np.ndarray],
                 parent: Optional["_Node"], n: int = 0):
        self.bid = bid
        self.experts = experts          # moe-layer ordinal -> expert ids
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.tails: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.tick = 0
        self.n = n                      # valid prompt positions in the block


@dataclass
class PrefixMatch:
    """Admission-time match result: ``bids`` cover prompt positions
    ``[0, tokens)`` (the last block possibly only partially — the adopter
    COWs it before writing); ``experts`` is the union of the matched nodes'
    recorded activations, keyed by MoE-layer ordinal."""
    bids: List[int] = field(default_factory=list)
    tokens: int = 0
    experts: Dict[int, np.ndarray] = field(default_factory=dict)

    def __bool__(self) -> bool:
        return self.tokens > 0


def _merge_experts(dsts: Dict[int, set], src: Dict[int, np.ndarray]) -> None:
    for mi, ids in src.items():
        dsts.setdefault(mi, set()).update(int(e) for e in ids)


class PrefixCache:
    """Radix index from block-aligned prompt prefixes to retained block ids.

    ``max_blocks`` soft-caps how many blocks the index may keep alive:
    after an insert pushes past it, LRU zero-extra-ref leaves are evicted
    back to the cap (blocks other requests still hold stay indexed, so the
    cap can be transiently exceeded while holders are live).
    """

    def __init__(self, pool: KVBlockPool,
                 max_blocks: Optional[int] = None, telemetry=None):
        self.pool = pool
        self.bs = pool.block_size
        self.max_blocks = max_blocks
        self.root = _Node(-1, {}, None)
        self._nodes = 0
        self._tick = 0
        self.stats = PrefixStats()
        # optional serving.telemetry.Telemetry: pressure evictions are
        # reported per freed block (pure observer; None records nothing)
        self.tel = telemetry

    @property
    def cached_blocks(self) -> int:
        """Blocks the index currently keeps a reference to."""
        return self._nodes

    # ------------------------------------------------------------------
    def _key(self, tokens: Sequence[int], d: int) -> Tuple[int, ...]:
        return tuple(tokens[d * self.bs: (d + 1) * self.bs])

    def walk(self, tokens: Sequence[int], max_blocks: int) -> List[_Node]:
        """Longest indexed path along ``tokens``: nodes for blocks
        ``0..len(result)-1``, stopping at the first un-indexed block or at
        ``max_blocks``. Only whole blocks participate (the trie is keyed on
        full ``block_size`` runs)."""
        out: List[_Node] = []
        node = self.root
        whole = len(tokens) // self.bs
        for d in range(min(max_blocks, whole)):
            node = node.children.get(self._key(tokens, d))
            if node is None:
                break
            out.append(node)
        return out

    def _touch(self, node: _Node) -> None:
        self._tick += 1
        node.tick = self._tick

    # ------------------------------------------------------------------
    def match(self, tokens: Sequence[int], limit: int) -> PrefixMatch:
        """Admission-time lookup: the longest indexed prefix of ``tokens``,
        capped at ``limit`` positions (the scheduler passes the last
        position the request must still process itself, so a full-prompt
        hit never swallows the token whose logits seed decoding).

        Matched nodes are LRU-touched. The caller adopts ``bids`` into the
        request's table (which takes the references) — nothing here can
        evict between match and adopt because the scheduler is
        single-threaded. Hit/miss/token stats are the *scheduler's* to
        count (at successful admission): a request can be matched many
        times while it waits for block reservations."""
        if limit <= 0:
            return PrefixMatch()
        nodes = self.walk(tokens, blocks_for(limit, self.bs))
        m = min(len(nodes) * self.bs, limit)
        nodes = nodes[:blocks_for(m, self.bs)]
        tail = None
        if m == len(nodes) * self.bs and m < limit:
            # sub-block matching: the first un-indexed whole block may
            # still be covered by an indexed partial tail — COW already
            # makes partial *use* of an adopted block safe (the adopter
            # privatises it before writing position m+p), so any common
            # prefix of a cached tail is usable KV
            parent = nodes[-1] if nodes else self.root
            tail, p = self._best_tail(parent, tokens[m:limit])
            if tail is not None:
                self._touch(tail)
                m += p
        if not nodes and tail is None:
            return PrefixMatch()
        merged: Dict[int, set] = {}
        for node in nodes:
            self._touch(node)
            _merge_experts(merged, node.experts)
        bids = [n.bid for n in nodes]
        if tail is not None:
            _merge_experts(merged, tail.experts)
            bids.append(tail.bid)
        return PrefixMatch(
            bids=bids, tokens=m,
            experts={mi: np.array(sorted(s), np.int64)
                     for mi, s in merged.items()})

    @staticmethod
    def _best_tail(parent: _Node, rem: Sequence[int]):
        """The cached partial tail sharing the longest common prefix with
        ``rem`` (the prompt's next un-indexed positions). Only the common
        prefix is usable — the adopter overwrites the block from there."""
        best, best_p = None, 0
        for key, node in parent.tails.items():
            p = 0
            for a, b in zip(key[:len(rem)], rem):
                if a != b:
                    break
                p += 1
            if p > best_p:
                best, best_p = node, p
        return best, best_p

    def extend(self, tokens: Sequence[int], depth: int) -> Optional[_Node]:
        """Mid-prefill extension: the node for block ``depth`` of
        ``tokens``, if the whole path to it is indexed — lets a request
        that missed at admission adopt blocks a sibling publishes while
        both are in flight (same-wave sharing). LRU-touches the node."""
        nodes = self.walk(tokens, depth + 1)
        if len(nodes) <= depth:
            return None
        self._touch(nodes[depth])
        self.stats.extensions += 1
        return nodes[depth]

    # ------------------------------------------------------------------
    def insert(self, tokens: Sequence[int], n_blocks: int,
               bids: Sequence[int],
               experts_by_block: Dict[int, Dict[int, set]],
               tail_len: int = 0) -> int:
        """Index blocks ``0..n_blocks-1`` of ``tokens`` (each must be a
        whole block of *prompt* positions whose KV ``bids`` holds). Blocks
        already indexed are kept (first writer wins — their KV is
        identical by construction); new nodes retain their block. With
        ``tail_len > 0``, block ``n_blocks`` is additionally indexed as a
        *partial tail* whose first ``tail_len`` positions are final prompt
        KV (the owner may keep decoding into the block's remainder — an
        adopter only ever uses the tail's prompt positions, copy-on-write).
        Returns the number of blocks newly indexed. Idempotent."""
        node = self.root
        added = 0
        for d in range(n_blocks):
            key = self._key(tokens, d)
            child = node.children.get(key)
            if child is None:
                bid = bids[d]
                self.pool.retain(bid)
                exp = {mi: np.array(sorted(s), np.int64)
                       for mi, s in experts_by_block.get(d, {}).items()}
                child = _Node(bid, exp, node, n=self.bs)
                node.children[key] = child
                self._nodes += 1
                added += 1
                self.stats.inserted_blocks += 1
            self._touch(child)
            node = child
        if tail_len > 0:
            assert tail_len < self.bs, "a full tail is a whole block"
            start = n_blocks * self.bs
            key = tuple(tokens[start: start + tail_len])
            tail = node.tails.get(key)
            if tail is None:
                bid = bids[n_blocks]
                self.pool.retain(bid)
                exp = {mi: np.array(sorted(s), np.int64)
                       for mi, s in
                       experts_by_block.get(n_blocks, {}).items()}
                tail = _Node(bid, exp, node, n=tail_len)
                node.tails[key] = tail
                self._nodes += 1
                added += 1
                self.stats.inserted_blocks += 1
                self.stats.inserted_tails += 1
            self._touch(tail)
        self.enforce_cap()
        return added

    def enforce_cap(self) -> None:
        """Evict back down to ``max_blocks``. Called after inserts and after
        a holder releases its references — insert-time enforcement alone
        could never shed blocks the inserting request itself still held."""
        if self.max_blocks is not None and self._nodes > self.max_blocks:
            self.evict(self._nodes - self.max_blocks)

    # ------------------------------------------------------------------
    def _evictable(self, exclude):
        """LRU-ordered leaves whose block has no holder but the cache.
        A node with live tail children is not a leaf — inner nodes are
        never evicted before anything hanging off them."""
        out = []
        stack = [self.root]
        while stack:
            node = stack.pop()
            for store, key, child in (
                    [("children", k, c) for k, c in node.children.items()]
                    + [("tails", k, c) for k, c in node.tails.items()]):
                if child.children or child.tails:
                    stack.append(child)
                elif (self.pool.ref_count(child.bid) == 1
                      and child.bid not in exclude):
                    out.append((store, key, child))
        out.sort(key=lambda kv: kv[2].tick)
        return out

    def evict(self, n_blocks: int, exclude=()) -> int:
        """Free up to ``n_blocks`` indexed blocks (LRU leaves first,
        re-walking as parents become leaves). Blocks other requests still
        reference are skipped — evicting them would free nothing anyway.
        ``exclude`` protects block ids a caller has matched but not yet
        adopted (their only reference is the index's, so nothing else
        marks them live). Returns how many blocks actually went back to
        the pool."""
        exclude = set(exclude)
        freed = 0
        while freed < n_blocks:
            victims = self._evictable(exclude)
            if not victims:
                break
            for store, key, node in victims:
                if freed >= n_blocks:
                    break
                getattr(node.parent, store).pop(key)
                self.pool.free(node.bid)
                self._nodes -= 1
                freed += 1
                self.stats.evicted_blocks += 1
        if freed and self.tel is not None and self.tel.enabled:
            self.tel.counter("prefix.evicted_blocks", freed)
        return freed
