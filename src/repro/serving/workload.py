"""Open-loop traffic generation for the serving engine.

A *workload* is a time-ordered list of :class:`WorkloadRequest`s: each one
arrives at an absolute offset from the start of the run (open loop — the
generator does not wait for the engine, so queueing delay is measured, not
hidden), carries a priority class, and optionally declares per-request SLO
budgets (:class:`SLO`). ``BatchedOffloadEngine.run_workload`` replays a
workload against the real clock; ``benchmarks/engine_bench.py --slo``
sweeps arrival rates built here and reports TTFT percentiles and
goodput-under-SLO with preemption on vs off.

Two constructors:

  * :func:`poisson_workload` — Poisson arrivals (exponential inter-arrival
    gaps at ``rate_rps``) with requests drawn from a weighted mix of
    :class:`PriorityClass`es, fully determined by ``seed``.
  * :func:`trace_workload` — replay explicit ``(arrival_s, prompt, ...)``
    rows, e.g. from a production trace.

Everything here is plain data — no engine imports — so workloads can be
built, serialised, and rescaled (:func:`scale_rate`) independently of the
serving stack.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np


@dataclass(frozen=True)
class SLO:
    """Per-request latency budgets, in seconds. ``None`` disables that axis.

      * ``ttft_s`` — arrival-to-first-sampled-token budget (queueing delay
        counts: the clock starts at the workload arrival offset).
      * ``per_token_s`` — mean time-per-output-token budget over the
        decode tail.
    """
    ttft_s: Optional[float] = None
    per_token_s: Optional[float] = None


@dataclass(frozen=True)
class PriorityClass:
    """One stratum of a synthetic workload mix.

      * ``name`` — label carried into benchmark reports.
      * ``priority`` — scheduler priority (lower = more urgent; an
        admitted request can only be preempted by a strictly more urgent
        waiter).
      * ``weight`` — relative share of generated requests.
      * ``prompt_len`` — prompt length in tokens, or an inclusive
        ``(lo, hi)`` range sampled uniformly.
      * ``max_new`` — decode budget in tokens, or an inclusive range.
      * ``slo`` — the class's latency budgets (None = best-effort).
      * ``temperature`` — sampling temperature for the class's requests.
    """
    name: str
    priority: int = 0
    weight: float = 1.0
    prompt_len: Union[int, Tuple[int, int]] = 8
    max_new: Union[int, Tuple[int, int]] = 8
    slo: Optional[SLO] = None
    temperature: float = 0.0


@dataclass
class WorkloadRequest:
    """One request of an open-loop workload.

      * ``arrival_s`` — seconds after run start at which the request
        becomes visible to the scheduler.
      * ``prompt`` — token ids (non-empty).
      * ``max_new`` — decode budget in tokens.
      * ``priority`` — scheduler priority (lower = more urgent).
      * ``slo`` — latency budgets, or None for best-effort.
      * ``temperature`` / ``seed`` — sampling knobs (seed feeds the
        request's private RNG so streams are reproducible).
      * ``cls`` — originating :class:`PriorityClass` name ("" for traces).
    """
    arrival_s: float
    prompt: List[int]
    max_new: int
    priority: int = 0
    slo: Optional[SLO] = None
    temperature: float = 0.0
    seed: int = 0
    cls: str = ""


Workload = List[WorkloadRequest]


def _draw(rng: np.random.Generator,
          spec: Union[int, Tuple[int, int]]) -> int:
    if isinstance(spec, tuple):
        lo, hi = spec
        return int(rng.integers(lo, hi + 1))
    return int(spec)


def poisson_workload(n_requests: int, rate_rps: float,
                     classes: Sequence[PriorityClass],
                     vocab_size: int = 256,
                     sample_prompt: Optional[
                         Callable[[np.random.Generator, int],
                                  Sequence[int]]] = None,
                     seed: int = 0) -> Workload:
    """Poisson arrivals at ``rate_rps`` with a weighted class mix.

    Inter-arrival gaps are Exponential(rate); each request's class is drawn
    by ``weight``; prompts come from ``sample_prompt(rng, length)`` (default:
    uniform tokens over ``vocab_size``). The result is sorted by arrival
    and fully determined by ``seed``."""
    if n_requests <= 0:
        return []
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    if not classes:
        raise ValueError("need at least one PriorityClass")
    rng = np.random.default_rng(seed)
    weights = np.asarray([c.weight for c in classes], np.float64)
    weights = weights / weights.sum()
    if sample_prompt is None:
        def sample_prompt(r, n):
            return r.integers(0, vocab_size, size=n).tolist()
    out: Workload = []
    t = 0.0
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        c = classes[int(rng.choice(len(classes), p=weights))]
        plen = max(1, _draw(rng, c.prompt_len))
        out.append(WorkloadRequest(
            arrival_s=t,
            prompt=[int(x) for x in sample_prompt(rng, plen)],
            max_new=_draw(rng, c.max_new),
            priority=c.priority,
            slo=c.slo,
            temperature=c.temperature,
            seed=seed * 100003 + i,
            cls=c.name))
    return out


def trace_workload(rows: Sequence[dict]) -> Workload:
    """Replay explicit trace rows. Each row is a dict with at least
    ``arrival_s`` and ``prompt``; ``max_new``/``priority``/``slo``/
    ``temperature``/``seed``/``cls`` are optional with the
    :class:`WorkloadRequest` defaults. Rows are sorted by arrival."""
    out: Workload = []
    for i, row in enumerate(rows):
        slo = row.get("slo")
        if isinstance(slo, dict):
            slo = SLO(**slo)
        out.append(WorkloadRequest(
            arrival_s=float(row["arrival_s"]),
            prompt=[int(x) for x in row["prompt"]],
            max_new=int(row.get("max_new", 8)),
            priority=int(row.get("priority", 0)),
            slo=slo,
            temperature=float(row.get("temperature", 0.0)),
            seed=int(row.get("seed", i)),
            cls=str(row.get("cls", ""))))
    out.sort(key=lambda r: r.arrival_s)
    return out


def scale_rate(workload: Workload, factor: float) -> Workload:
    """A copy of ``workload`` with arrivals compressed by ``factor``
    (factor 2.0 = twice the offered load, same requests)."""
    if factor <= 0:
        raise ValueError(f"factor must be > 0, got {factor}")
    return [replace_arrival(r, r.arrival_s / factor) for r in workload]


def replace_arrival(req: WorkloadRequest, arrival_s: float) -> WorkloadRequest:
    """Copy of ``req`` at a different arrival offset."""
    out = WorkloadRequest(**{f: getattr(req, f) for f in (
        "arrival_s", "prompt", "max_new", "priority", "slo", "temperature",
        "seed", "cls")})
    out.arrival_s = arrival_s
    out.prompt = list(req.prompt)
    return out
