"""Continuous-batching scheduler + multi-request async-prefetch engine.

``BatchedOffloadEngine`` decodes up to ``max_batch`` requests per step
through the shared ``DecodeCore`` (serving/engine.py): one ExpertCache /
slot buffer serves every in-flight request, prediction state is per
request (core.policies.PerRequestPolicy), and each step's needed experts
are pinned so one lane's demand fetch can never evict another lane's
in-use expert. Admission is greedy: a finished request frees its KV-cache
row and the next queued request takes it on the following step, so the
batch stays full under load (the ROADMAP's heavy-traffic serving shape).

Per-request token streams are identical to the batch-1 ``OffloadEngine``
— tests pin batched-vs-batch-1 parity at full capacity.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.policies import PerRequestPolicy, Policy
from repro.serving.engine import DecodeCore, EngineStats, sample_token


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    temperature: float = 0.0
    seed: int = 0
    # runtime state
    t: int = 0                 # decode steps completed == position
    cur: int = 0               # token to feed on the next step
    n_total: int = 0           # total steps this request will run
    generated: List[int] = field(default_factory=list)
    rng: Optional[np.random.Generator] = None

    def start(self, cache_len: int) -> None:
        self.t = 0
        self.cur = int(self.prompt[0])
        self.n_total = min(len(self.prompt) + self.max_new, cache_len)
        self.generated = []
        self.rng = np.random.default_rng(self.seed)

    def feed_result(self, logits: np.ndarray) -> None:
        """Consume one step's logits; mirrors OffloadEngine.generate."""
        t = self.t
        self.t = t + 1
        if t + 1 < len(self.prompt):
            self.cur = int(self.prompt[t + 1])
        else:
            self.cur = sample_token(logits, self.temperature, self.rng)
            self.generated.append(self.cur)

    @property
    def done(self) -> bool:
        return self.t >= self.n_total


PolicySpec = Union[None, Policy, Callable[[], Policy]]


class BatchedOffloadEngine:
    """Multi-request offloaded decode with async prefetch overlap.

    policy: None, a *stateless* Policy shared across requests, or a
    zero-arg factory building one Policy per admitted request.
    """

    def __init__(self, model, params, policy: PolicySpec, capacity: int,
                 eviction: str = "lru", host_bw: float = 100e9,
                 expert_backend: str = "jnp", max_batch: int = 4,
                 layer_compute_s: float = 0.0):
        need = max_batch * model.cfg.moe.top_k
        if capacity < need:
            raise ValueError(
                f"capacity {capacity} < max_batch*top_k = {need}: a single "
                "step could pin more experts than the cache holds")
        self.core = DecodeCore(model, params, capacity, eviction, host_bw,
                               expert_backend, max_batch=max_batch,
                               layer_compute_s=layer_compute_s)
        self.cfg = self.core.cfg
        self.max_batch = max_batch
        self._policy = None if policy is None else PerRequestPolicy(policy)
        self._queue: deque[Request] = deque()
        self._next_rid = 0

    @property
    def stats(self) -> EngineStats:
        return self.core.stats

    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new: int,
               temperature: float = 0.0, seed: int = 0) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, [int(p) for p in prompt], max_new,
                                   temperature, seed))
        return rid

    def run(self, cache_len: int) -> Dict[int, List[int]]:
        """Drain the queue: admit up to max_batch requests, decode one
        batched step, retire finished requests into freed rows."""
        caches = self.core.alloc_caches(cache_len)
        rows: List[Optional[Request]] = [None] * self.max_batch
        results: Dict[int, List[int]] = {}
        while self._queue or any(r is not None for r in rows):
            for s in range(self.max_batch):          # admission
                if rows[s] is None and self._queue:
                    req = self._queue.popleft()
                    req.start(cache_len)
                    rows[s] = req
                    if self._policy is not None:
                        self._policy.begin_request(req.rid)
            active = [(s, r) for s, r in enumerate(rows) if r is not None]
            logits, caches, _ = self.core.step(
                caches,
                rows=[s for s, _ in active],
                pos=[r.t for _, r in active],
                tokens=[r.cur for _, r in active],
                policy=self._policy,
                rids=[r.rid for _, r in active])
            for (s, r), lg in zip(active, logits):   # retire
                r.feed_result(lg)
                if r.done:
                    results[r.rid] = r.generated
                    rows[s] = None
                    if self._policy is not None:
                        self._policy.end_request(r.rid)
        return results

    def generate(self, prompts: Sequence[Sequence[int]], max_new: int,
                 cache_len: int, temperature: float = 0.0,
                 seeds: Optional[Sequence[int]] = None) -> List[List[int]]:
        """Decode a batch of prompts; returns per-prompt generated tokens
        in submission order."""
        rids = [self.submit(p, max_new, temperature,
                            seeds[i] if seeds is not None else 0)
                for i, p in enumerate(prompts)]
        results = self.run(cache_len)
        return [results[r] for r in rids]
