"""Continuous-batching scheduler + multi-request paged serving engine.

``BatchedOffloadEngine`` decodes up to ``max_batch`` requests per step
through the shared ``DecodeCore`` (serving/engine.py): one ExpertCache /
slot buffer serves every in-flight request, prediction state is per request
(core.policies.PerRequestPolicy), and each step's needed experts are pinned
so one lane's demand fetch can never evict another lane's in-use expert.

The decode path is built around **block tables** (serving/kvpool.py): KV
lives in a shared block-paged pool, a request is admitted when enough
*blocks* can be reserved for its worst case (not a whole ``cache_len`` row),
its table grows lazily as it decodes, and its blocks return to the pool on
retire — so KV memory high-water scales with the sum of actual sequence
lengths. Prompts are absorbed by **chunked prefill**: power-of-two-bucketed
chunks run through the jitted prefill program interleaved with decode steps,
and the policy's predictions during prefill warm the ExpertCache before the
first decode token. ``paged=False`` keeps the PR-1 row path (fixed-length
KV rows, prompts streamed token-by-token through decode) as the contiguous
fallback and benchmark baseline.

With ``prefix_cache=True`` admission also walks a radix index of
block-aligned prompt prefixes (serving/prefixcache.py): matched KV blocks
are ``retain``-ed into the new request's table copy-on-write instead of
re-prefilled (chunked prefill starts at the first unmatched position), the
prefix's recorded expert activations are replayed to warm the ExpertCache,
and requests still mid-prefill adopt blocks a sibling publishes at every
chunk boundary — so even a same-wave burst of identical system prompts
prefills the shared prefix exactly once.

Scheduling under load: requests carry a priority (lower = more urgent) and
optional SLO budgets (serving/workload.py), the queue admits in priority
order (FIFO within a class), and with ``ServeConfig.preemption`` admission
may evict a strictly lower-priority running request when a more urgent
waiter can't get a lane or a block reservation. A preempted request's
prompt blocks are published to the prefix index *before* its table is
released, its sampled tail is folded into the teacher-forced prompt, and
the re-admission replays the folded prompt — through the prefix index as
cache hits when it's on — reproducing the identical token stream.
``run_workload`` replays an open-loop workload against the real clock and
``EngineStats.latency`` summarises TTFT/per-token percentiles, preemption
counts, and goodput under SLO.

Per-request token streams are identical to the batch-1 ``OffloadEngine``
— tests pin paged-vs-batch-1 parity across ragged prompt lengths, with the
prefix cache on and off, and across forced preemption storms.
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.metrics import RequestLatency, latency_stats
from repro.core.policies import PerRequestPolicy, Policy
from repro.serving.config import ServeConfig
from repro.serving.engine import DecodeCore, EngineStats, sample_token
from repro.serving.kvpool import BlockTable, KVBlockPool, blocks_for
from repro.serving.prefixcache import PrefixCache, PrefixMatch
from repro.serving.telemetry import PID_ENGINE, PID_REQUESTS
from repro.serving.workload import SLO, WorkloadRequest


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    temperature: float = 0.0
    seed: int = 0
    priority: int = 0          # lower = more urgent
    slo: Optional[SLO] = None  # per-request latency budgets
    # runtime state
    t: int = 0                 # decode steps completed == position
    cur: int = 0               # token to feed on the next step
    n_total: int = 0           # total steps this request will run
    prefill_end: int = 0       # positions absorbed by chunked prefill
    generated: List[int] = field(default_factory=list)
    rng: Optional[np.random.Generator] = None
    table: Optional[BlockTable] = None
    lane: int = -1             # row for bounded per-row state
    seq: int = -1              # admission-order tiebreak within a priority
    arrival_s: float = 0.0     # perf_counter when request became visible
    queued_s: float = 0.0      # perf_counter of the latest (re)queue —
    #                            arrival, or the preemption that re-queued
    #                            it (telemetry's queue-wait span start)
    admit_s: float = 0.0       # perf_counter at admission
    first_token_s: float = -1.0  # perf_counter at first sampled token
    preemptions: int = 0       # times evicted and re-admitted
    base_len: int = 0          # original prompt length (pre-fold)
    # per-block expert activations observed while processing prompt
    # positions (block index -> MoE-layer ordinal -> expert ids) — what the
    # prefix cache stores for activation replay on a future hit
    block_experts: Dict[int, Dict[int, set]] = field(default_factory=dict)

    def __post_init__(self):
        self.base_len = len(self.prompt)

    def start(self, cache_len: int) -> None:
        """(Re)enter a lane. The first admission seeds the RNG; a resume
        after preemption keeps ``generated``/``rng`` intact so replaying
        the folded prompt (original prompt + sampled tail) reproduces the
        identical stream — teacher-forced positions never consume the RNG.
        """
        self.t = 0
        self.cur = int(self.prompt[0])
        self.n_total = min(self.base_len + self.max_new, cache_len)
        if self.rng is None:
            self.rng = np.random.default_rng(self.seed)

    def feed_result(self, logits: np.ndarray) -> None:
        """Consume one step's logits; mirrors OffloadEngine.generate."""
        t = self.t
        self.t = t + 1
        if t + 1 < len(self.prompt):
            self.cur = int(self.prompt[t + 1])
        else:
            self.cur = sample_token(logits, self.temperature, self.rng)
            self.generated.append(self.cur)
            if self.first_token_s < 0:
                self.first_token_s = time.perf_counter()

    @property
    def done(self) -> bool:
        return self.t >= self.n_total

    @property
    def prefilling(self) -> bool:
        return self.t < self.prefill_end


PolicySpec = Union[None, Policy, Callable[[], Policy]]


class BatchedOffloadEngine:
    """Multi-request offloaded decode with async prefetch overlap.

    policy: None, a *stateless* Policy shared across requests, or a
    zero-arg factory building one Policy per admitted request.

    paged=True (default) pages the KV cache into ``block_size``-position
    blocks and absorbs prompts via chunked prefill (``prefill_chunk`` tokens
    per chunk, clamped so a chunk can never pin more than ``capacity``
    experts). ``kv_blocks`` bounds the pool (None -> worst case for
    ``max_batch`` full-length requests, plus the scratch block); a smaller
    pool admits by block availability instead. paged=False keeps the
    contiguous fixed-row engine.

    ``serve`` (a :class:`ServeConfig`) bundles the batching/paging/kernel
    knobs in one place and overrides the individual keyword arguments;
    ``use_kernel``/``kernel_backend`` select the paged flash-decode read
    path (``use_kernel=False`` is the gather parity reference); ``tiers``
    (a :class:`~repro.serving.expertstore.TierConfig`) swaps the
    single-host expert store for the tiered device/host/peer/disk
    hierarchy with horizon-aware prefetch — streams stay token-identical,
    only the storage substrate and the modeled fetch timeline change.
    ``TierConfig.dispatch`` additionally lets the engine *ship token
    groups to peer-resident experts* instead of fetching their weights
    (``"ship"``/``"auto"``; see ``dispatch_summary`` for the traffic
    split) — still token-identical, the peer computes with the same
    bytes a fetch would have delivered.
    """

    def __init__(self, model, params, policy: PolicySpec, capacity: int,
                 eviction: str = "lru", host_bw: float = 100e9,
                 expert_backend: str = "jnp", max_batch: int = 4,
                 layer_compute_s=0.0, paged: bool = True,
                 block_size: int = 8, kv_blocks: Optional[int] = None,
                 prefill_chunk: int = 8, use_kernel: bool = True,
                 kernel_backend: Optional[str] = None,
                 prefix_cache: bool = False,
                 prefix_cache_blocks: Optional[int] = None,
                 tiers=None,
                 serve: Optional[ServeConfig] = None):
        if serve is None:
            serve = ServeConfig(max_batch=max_batch, paged=paged,
                                block_size=block_size, kv_blocks=kv_blocks,
                                prefill_chunk=prefill_chunk,
                                use_kernel=use_kernel,
                                kernel_backend=kernel_backend,
                                prefix_cache=prefix_cache,
                                prefix_cache_blocks=prefix_cache_blocks,
                                replacement=eviction,
                                tiers=tiers,
                                layer_compute_s=layer_compute_s)
        self.serve = serve
        max_batch = serve.max_batch
        need = max_batch * model.cfg.moe.top_k
        if capacity < need:
            raise ValueError(
                f"capacity {capacity} < max_batch*top_k = {need}: a single "
                "step could pin more experts than the cache holds")
        # a prefill chunk pins up to chunk*top_k experts — clamp it to the
        # same bound the decode batch obeys
        self.prefill_chunk = max(1, min(serve.prefill_chunk,
                                        capacity // model.cfg.moe.top_k))
        self.core = DecodeCore(model, params, capacity, serve.replacement,
                               host_bw, expert_backend, max_batch=max_batch,
                               layer_compute_s=serve.layer_compute_s,
                               max_prefill_chunk=self.prefill_chunk,
                               kernel=serve.resolve_kernel(),
                               tiers=serve.tiers,
                               telemetry=serve.telemetry)
        # the core resolved None -> NULL_TELEMETRY; share its choice
        self.tel = self.core.tel
        self.cfg = self.core.cfg
        self.max_batch = max_batch
        self.paged = serve.paged and self.core.paged_ok
        self.block_size = serve.block_size
        self.kv_blocks = serve.kv_blocks
        self.pool: Optional[KVBlockPool] = None
        # prefix sharing rides on chunked prefill: every layer's state must
        # be reachable through block tables for a matched prefix to stand in
        # for prefill (ring/recurrent rows are per-lane, not shareable)
        self.prefix_enabled = (serve.prefix_cache and self.paged
                               and self.core.chunk_prefill_ok)
        self.prefix_cache_blocks = serve.prefix_cache_blocks
        self.prefix: Optional[PrefixCache] = None   # built per run
        self.kv_block_bytes = 0          # device bytes per block, set by run
        # preemption needs block tables to evict and the prefix index flow
        # to make resume cheap; the contiguous row path stays FIFO-only
        self.preemption = serve.preemption and self.paged
        self._policy = None if policy is None else PerRequestPolicy(policy)
        # min-heap of (priority, seq, Request): priority order, FIFO within
        # a class; a preempted victim re-enters with its original seq so it
        # goes back to the front of its class
        self._queue: List[Tuple[int, int, Request]] = []
        self._seq = 0
        self._ttft: Dict[int, float] = {}
        self._records: Dict[int, RequestLatency] = {}
        self._next_rid = 0

    @property
    def stats(self) -> EngineStats:
        return self.core.stats

    def dispatch_summary(self) -> Dict[str, float]:
        """Fetch-vs-ship traffic split of the run so far (the
        compute-dispatch report ``engine_bench --tiers --dispatch``
        tabulates): ships and fetches executed, wire bytes each path put
        on the interconnect, and the un-overlapped stall attributed to the
        peer fetch channel (tier 2) vs the ship channel (4). All zeros on
        fetch-only/single-host engines."""
        s = self.core.stats
        from repro.serving.offload import CHANNEL_SHIP, TIER_PEER
        return {
            "ships": s.ships,
            "ship_tokens": s.ship_tokens,
            "fetches": sum(s.fetches_by_tier.values()),
            "ship_wire_bytes": s.ship_bytes,
            "fetch_wire_bytes": s.fetch_bytes_by_tier.get(TIER_PEER, 0),
            "peer_stall_s": s.stall_by_tier.get(TIER_PEER, 0.0),
            "ship_stall_s": s.stall_by_tier.get(CHANNEL_SHIP, 0.0),
        }

    def ttft(self) -> Dict[int, float]:
        """Admission-to-first-token seconds per request retired by the
        latest ``run`` (requests truncated before their first sampled
        token are absent)."""
        return dict(self._ttft)

    def _record_ttft(self, req: Request) -> None:
        if req.first_token_s >= 0:
            self._ttft[req.rid] = req.first_token_s - req.admit_s

    def _finish_record(self, req: Request, rejected: bool = False) -> None:
        """Write the request's RequestLatency row (retire or reject)."""
        self._records[req.rid] = RequestLatency(
            rid=req.rid, priority=req.priority, arrival_s=req.arrival_s,
            first_token_s=req.first_token_s,
            finish_s=time.perf_counter(),
            tokens_out=len(req.generated),
            preemptions=req.preemptions,
            rejected=rejected,
            slo_ttft_s=req.slo.ttft_s if req.slo is not None else None,
            slo_per_token_s=(req.slo.per_token_s
                             if req.slo is not None else None))

    def records(self) -> Dict[int, RequestLatency]:
        """Per-request latency records of the latest run (rid -> record);
        feed subsets to :func:`repro.core.metrics.latency_stats` for e.g.
        per-priority-class breakdowns."""
        return dict(self._records)

    @property
    def kv_high_water_bytes(self) -> int:
        """Peak *logical* KV working set (blocks in use × bytes/block).

        The pool tensors themselves are allocated at ``kv_blocks`` size up
        front; this metric tells you how small ``kv_blocks`` could have
        been for this workload — the device saving is realised by setting
        ``kv_blocks`` below the ``max_batch × cache_len`` worst case."""
        if self.pool is None:
            return 0
        return self.pool.stats.high_water * self.kv_block_bytes

    # ------------------------------------------------------------------
    def _make_request(self, prompt: Sequence[int], max_new: int,
                      temperature: float, seed: int,
                      priority: Optional[int],
                      slo: Optional[SLO]) -> Request:
        prompt = [int(p) for p in prompt]
        if not prompt:
            raise ValueError(
                "empty prompt: a request needs at least one token to seed "
                "decoding")
        if max_new < 0:
            raise ValueError(f"max_new must be >= 0, got {max_new}")
        rid = self._next_rid
        self._next_rid += 1
        now = time.perf_counter()
        return Request(rid, prompt, max_new, temperature, seed,
                       priority=(self.serve.default_priority
                                 if priority is None else int(priority)),
                       slo=self.serve.default_slo if slo is None else slo,
                       arrival_s=now, queued_s=now)

    def _push(self, req: Request) -> None:
        if req.seq < 0:
            req.seq = self._seq
            self._seq += 1
        heapq.heappush(self._queue, (req.priority, req.seq, req))

    def _pop_next(self) -> Request:
        return heapq.heappop(self._queue)[2]

    def submit(self, prompt: Sequence[int], max_new: int,
               temperature: float = 0.0, seed: int = 0,
               priority: Optional[int] = None,
               slo: Optional[SLO] = None) -> int:
        """Enqueue a request; returns its rid. ``priority`` (lower = more
        urgent) and ``slo`` default to the ServeConfig's
        ``default_priority``/``default_slo``."""
        req = self._make_request(prompt, max_new, temperature, seed,
                                 priority, slo)
        self._push(req)
        return req.rid

    def run(self, cache_len: int) -> Dict[int, List[int]]:
        self._ttft.clear()             # ttft() reports the latest run only
        self._records = {}
        t0 = time.perf_counter()
        if self.paged:
            results = self._run_paged(cache_len)
        else:
            results = self._run_rows(cache_len)
        self.core.stats.latency = latency_stats(
            self._records.values(), time.perf_counter() - t0)
        return results

    def run_workload(self, workload: Sequence[WorkloadRequest],
                     cache_len: int) -> Dict[int, List[int]]:
        """Open-loop replay: each :class:`WorkloadRequest` becomes visible
        to the scheduler at its ``arrival_s`` offset from the start of the
        call (the engine never waits for the generator, so queueing delay
        is measured rather than hidden). Returns ``{rid: generated}`` with
        rids assigned in arrival order; ``stats.latency`` summarises the
        run. Needs the paged engine."""
        if not self.paged:
            raise ValueError("run_workload needs the paged engine "
                             "(ServeConfig.paged=True)")
        if self._queue:
            raise RuntimeError("run_workload with requests already queued")
        self._ttft.clear()
        self._records = {}
        arrivals = deque(sorted(workload, key=lambda r: r.arrival_s))
        t0 = time.perf_counter()
        results = self._run_paged(cache_len, arrivals=arrivals, t0=t0)
        self.core.stats.latency = latency_stats(
            self._records.values(), time.perf_counter() - t0)
        return results

    # ------------------------------------------------------------------
    def _run_rows(self, cache_len: int) -> Dict[int, List[int]]:
        """Contiguous fallback: fixed-length KV rows, prompts streamed
        token-by-token through the decode path (the PR-1 engine)."""
        caches = self.core.alloc_caches(cache_len)
        rows: List[Optional[Request]] = [None] * self.max_batch
        results: Dict[int, List[int]] = {}
        while self._queue or any(r is not None for r in rows):
            for s in range(self.max_batch):          # admission
                while rows[s] is None and self._queue:
                    req = self._pop_next()
                    req.start(cache_len)
                    req.admit_s = time.perf_counter()
                    if req.done:
                        # degenerate (cache_len admits zero steps): retire
                        # before ever stepping — pinned to match the paged
                        # engine's immediate-retire behavior
                        results[req.rid] = req.generated
                        self._record_ttft(req)
                        self._finish_record(req)
                        continue
                    rows[s] = req
                    if self._policy is not None:
                        self._policy.begin_request(req.rid)
            active = [(s, r) for s, r in enumerate(rows) if r is not None]
            if not active:
                continue
            self._count_fallback(r for _, r in active)
            logits, caches, _ = self.core.step(
                caches,
                rows=[s for s, _ in active],
                pos=[r.t for _, r in active],
                tokens=[r.cur for _, r in active],
                policy=self._policy,
                rids=[r.rid for _, r in active])
            for (s, r), lg in zip(active, logits):   # retire
                r.feed_result(lg)
                if r.done:
                    results[r.rid] = r.generated
                    self._record_ttft(r)
                    self._finish_record(r)
                    rows[s] = None
                    if self._policy is not None:
                        self._policy.end_request(r.rid)
        return results

    # ------------------------------------------------------------------
    def _admit_paged(self, lanes: List[Optional[Request]], cache_len: int,
                     results: Dict[int, List[int]]) -> None:
        """Admit the most urgent waiter while a lane is free AND the pool
        can reserve its worst-case block count — block-granular admission,
        priority order (FIFO within a class).

        With the prefix cache on, admission first walks the radix index:
        matched blocks are adopted (retained, copy-on-write) instead of
        reserved, chunked prefill starts at the first unmatched position,
        and the prefix's recorded expert activations are replayed. A
        request whose worst case exceeds the *whole* pool is rejected
        gracefully (empty result + ``EngineStats.rejected_requests``)
        rather than aborting the run with lanes held and blocks leaked.

        With ``ServeConfig.preemption``, a waiter that can't get a lane or
        a reservation may evict a strictly lower-priority running request
        (``_preempt``): the victim's blocks return to the pool (published
        to the prefix index first) and admission retries with a fresh
        prefix match."""
        bs = self.block_size
        while self._queue:
            req = self._queue[0][2]            # most urgent waiter
            lane = next((i for i, r in enumerate(lanes) if r is None), None)
            if lane is None:
                if not self._try_preempt(lanes, req):
                    return                     # every lane is busy
                continue                       # a lane is free now
            n_total = min(req.base_len + req.max_new, cache_len)
            # the request must process at least the position whose
            # logits seed sampling, so a match may cover at most
            # min(len(prompt), n_total) - 1 positions
            match = (self.prefix.match(req.prompt,
                                       min(len(req.prompt), n_total) - 1)
                     if self.prefix is not None else PrefixMatch())
            # a match ending mid-block COWs that block on first write:
            # one extra allocation beyond the unmatched remainder
            need = (blocks_for(n_total, bs) - len(match.bids)
                    + (1 if match.tokens % bs else 0))
            if blocks_for(n_total, bs) > self.pool.num_blocks - 1:
                # the FULL footprint is what must fit live (matched
                # blocks stay allocated too): reject on it, not on the
                # match-reduced reservation, or an impossible request
                # would first wipe the index via the fallback below
                self._pop_next()               # reject, keep running
                results[req.rid] = []
                self.core.stats.rejected_requests += 1
                self._finish_record(req, rejected=True)
                if self.tel.enabled:
                    self.tel.counter("sched.rejected")
                    self.tel.instant(PID_ENGINE, 1, "reject",
                                     {"rid": req.rid,
                                      "need_blocks":
                                          blocks_for(n_total, bs)})
                continue
            if not self.pool.try_reserve(need):
                # pool pressure may be cached prefixes nobody holds —
                # evict zero-extra-ref LRU prefixes (NOT the blocks we
                # just matched: until adopted, the index's reference is
                # their only one, so eviction would free them out from
                # under the pending adopt) and retry
                reserved = False
                if self.prefix is not None:
                    self.prefix.evict(need - self.pool.available,
                                      exclude=match.bids)
                    if self.pool.try_reserve(need):
                        reserved = True
                    elif match:
                        # the protected match itself may BE the pressure:
                        # give it up and admit as a plain full-prefill
                        # request (guaranteed to fit once lanes drain —
                        # the whole-pool reject above already ran)
                        match = PrefixMatch()
                        need = blocks_for(n_total, bs)
                        self.prefix.evict(need - self.pool.available)
                        reserved = self.pool.try_reserve(need)
                if not reserved:
                    if not self._try_preempt(lanes, req):
                        return                 # FIFO within class: wait
                    continue                   # blocks freed: re-match
            self._pop_next()
            req.start(cache_len)
            if req.admit_s == 0.0:         # resumes keep the first admission
                req.admit_s = time.perf_counter()
            req.table = BlockTable(self.pool, need)
            req.lane = lane
            if self.tel.enabled:
                tid = req.rid + 1
                self.tel.ensure_track(PID_REQUESTS, tid, f"req {req.rid}")
                now_s = self.tel.now()
                q0 = self.tel.rel(req.queued_s)
                self.tel.complete(PID_REQUESTS, tid, "queued", q0,
                                  max(0.0, now_s - q0),
                                  {"priority": req.priority,
                                   "resumed": req.preemptions > 0})
                self.tel.begin(PID_REQUESTS, tid, "request",
                               {"rid": req.rid,
                                "prompt_len": len(req.prompt),
                                "max_new": req.max_new,
                                "priority": req.priority,
                                "resumed": req.preemptions > 0},
                               ts=now_s)
                self.tel.counter("sched.admitted")
            if self._policy is not None:
                self._policy.begin_request(req.rid)
            if match:
                req.table.adopt(match.bids)
                req.t = match.tokens             # prefill starts here
                self.prefix.stats.hits += 1
                self.prefix.stats.hit_tokens += match.tokens
                if self.tel.enabled:
                    self.tel.counter("prefix.adopted_blocks",
                                     len(match.bids))
                    self.tel.instant(PID_REQUESTS, req.rid + 1,
                                     "prefix-adopt",
                                     {"blocks": len(match.bids),
                                      "tokens": match.tokens})
                self._replay(req, match.experts)
            elif self.prefix is not None:
                self.prefix.stats.misses += 1
            # positions a prefill program may absorb: everything up to
            # (not including) the position whose logits the first
            # sample needs
            req.prefill_end = (min(len(req.prompt) - 1, req.n_total)
                               if self.core.chunk_prefill_ok else 0)
            lanes[lane] = req
            if req.done:
                # degenerate: cache_len admits zero steps
                self._retire(lanes, req, results)
            elif not req.prefilling and req.t > 0:
                # full-prefix hit: go straight to decoding the tail
                req.cur = int(req.prompt[req.t])

    # -- preemption ----------------------------------------------------
    def _try_preempt(self, lanes: List[Optional[Request]],
                     waiter: Request) -> bool:
        """Evict the least-urgent running request strictly below the
        waiter's priority (ties broken toward the most recently admitted —
        least progress lost). Returns True when a victim was preempted;
        strict inequality prevents same-priority ping-pong."""
        if not self.preemption:
            return False
        victims = [r for r in lanes
                   if r is not None and r.priority > waiter.priority]
        if not victims:
            return False
        victim = max(victims, key=lambda r: (r.priority, r.admit_s))
        self._preempt(lanes, victim)
        return True

    def _preempt(self, lanes: List[Optional[Request]],
                 victim: Request) -> None:
        """Evict ``victim`` from its lane and re-queue it for later.

        The sampled tail is folded into the teacher-forced prompt —
        position p of the resumed request replays ``prompt[p]`` for
        p < base_len and ``generated[p - base_len]`` after, and teacher-
        forced positions never consume the RNG, so the resumed stream is
        token-identical to a never-preempted run. The victim's completed
        prompt blocks are published to the prefix index *before* its table
        is released (the index's retains keep them alive), so with the
        prefix cache on the re-prefill replays as cache hits."""
        victim.prompt = (list(victim.prompt[:victim.base_len])
                         + victim.generated)
        self._insert_prefix(victim)    # publish before release: resume hits
        self.pool.stats.preempt_ref_drops += len(victim.table.ids)
        victim.table.release()
        victim.table = None
        if self.prefix is not None:
            self.prefix.enforce_cap()
        lanes[victim.lane] = None
        victim.lane = -1
        victim.preemptions += 1
        self.core.stats.preemptions += 1
        victim.queued_s = time.perf_counter()
        if self.tel.enabled:
            tid = victim.rid + 1
            self.tel.instant(PID_REQUESTS, tid, "preempt",
                             {"priority": victim.priority,
                              "tokens_done": victim.t,
                              "preemptions": victim.preemptions})
            self.tel.end(PID_REQUESTS, tid, "request")
            self.tel.counter("sched.preemptions")
        if self._policy is not None:
            # the per-request predictor restarts on resume; the prefix
            # index's recorded activations are replayed into the fresh
            # instance at re-admission
            self._policy.end_request(victim.rid)
        self._push(victim)             # original seq: front of its class

    def _count_fallback(self, active) -> None:
        """Prompt tokens fed through a decode step that chunked prefill
        could have absorbed (position < len(prompt)-1): zero on the
        chunk-prefill path, the whole prompt body when ring/recurrent
        stacks (or paged=False) stream prompts token-by-token."""
        self.core.stats.fallback_prefill_tokens += sum(
            1 for r in active if r.t < len(r.prompt) - 1)

    def _retire(self, lanes, req: Request, results) -> None:
        results[req.rid] = req.generated
        self._record_ttft(req)
        self._finish_record(req)
        if self.tel.enabled:
            tid = req.rid + 1
            self.tel.instant(PID_REQUESTS, tid, "retire",
                             {"tokens_out": len(req.generated),
                              "preemptions": req.preemptions})
            self.tel.end(PID_REQUESTS, tid, "request")
            self.tel.counter("sched.retired")
        self._insert_prefix(req)         # index prompt blocks before release
        req.table.release()
        if self.prefix is not None:
            self.prefix.enforce_cap()    # our refs gone: cap is enforceable
        lanes[req.lane] = None
        if self._policy is not None:
            self._policy.end_request(req.rid)

    # -- prefix sharing ------------------------------------------------
    def _replay(self, req: Request, experts_by_layer) -> None:
        """Warm the ExpertCache with a matched prefix's recorded expert
        activations and feed them to the request's policy as observations —
        the hit skipped the prefill that would have produced both."""
        if not experts_by_layer:
            return
        for mi in sorted(experts_by_layer):
            self.core.cache.prefetch(
                (mi, int(e)) for e in experts_by_layer[mi])
        if self._policy is not None:
            self._policy.replay_prefix(req.rid, experts_by_layer)

    def _record_experts(self, req: Request, t0: int, experts) -> None:
        """Accumulate per-block activation sets for prompt positions
        ``t0 + j`` — ``experts`` is per-MoE-layer, per-token id arrays."""
        bs = self.block_size
        plen = len(req.prompt)
        for mi, per_tok in enumerate(experts):
            for j, ids in enumerate(per_tok):
                p = t0 + j
                if p >= plen:
                    break
                blk = req.block_experts.setdefault(p // bs, {})
                blk.setdefault(mi, set()).update(int(e) for e in ids)

    def _insert_prefix(self, req: Request) -> None:
        """Publish the request's completed whole-prompt blocks into the
        radix index (idempotent; already-indexed blocks are kept). Once
        every prompt position is processed, the partial tail block (prompt
        length % block_size positions) is indexed too — sub-block
        matching: a future request sharing only part of a block still
        adopts its KV copy-on-write."""
        if self.prefix is None or req.table is None:
            return
        plen = len(req.prompt)
        done = min(plen, req.t)
        n_blocks = done // self.block_size
        tail_len = plen % self.block_size if done == plen else 0
        if tail_len and (n_blocks >= len(req.table.ids)
                         or req.table.is_shared(n_blocks)):
            # safety: no owned tail block to index (still an adopted
            # read-only copy — then it is already indexed by its owner)
            tail_len = 0
        if n_blocks > 0 or tail_len > 0:
            self.prefix.insert(req.prompt, n_blocks, req.table.ids,
                               req.block_experts, tail_len=tail_len)

    def _extend_prefix(self, req: Request) -> None:
        """At a chunk boundary, adopt blocks a sibling has published since
        this request was admitted — the same-wave sharing path: a burst of
        identical prompts admitted together still prefills each shared
        block exactly once."""
        bs = self.block_size
        while (req.prefilling and req.t % bs == 0
               and len(req.table) == req.t // bs):
            node = self.prefix.extend(req.prompt, req.t // bs)
            if node is None:
                break
            req.table.adopt([node.bid])
            if self.tel.enabled:
                self.tel.counter("prefix.adopted_blocks")
                self.tel.instant(PID_REQUESTS, req.rid + 1,
                                 "prefix-extend", {"block": req.t // bs})
            end = min(req.t + bs, req.prefill_end)
            if end == req.t + bs:
                # a whole adopted block is one allocation this request will
                # never make — hand the reservation back to the pool now
                req.table.return_reservation(1)
            self.prefix.stats.hit_tokens += end - req.t
            req.t = end
            self._replay(req, node.experts)

    def _cow(self, caches, req: Request, t0: int, n: int):
        """Copy-on-write every shared block the write window
        ``[t0, t0 + n)`` touches: swap in a private block id and duplicate
        the device page so the scatter can't corrupt a sibling's KV."""
        bs = self.block_size
        for idx in range(t0 // bs, (t0 + n - 1) // bs + 1):
            if idx < len(req.table.ids) and req.table.is_shared(idx):
                swap = req.table.make_private(idx)
                if swap is not None:
                    caches = self.core.copy_block(caches, swap[0], swap[1])
        return caches

    def _admit_arrivals(self, arrivals: deque, t0: float) -> None:
        """Move workload requests whose arrival offset has passed into the
        scheduler queue; their TTFT clock starts at the *scheduled*
        arrival, so any backlog shows up as queueing delay."""
        now = time.perf_counter() - t0
        while arrivals and arrivals[0].arrival_s <= now:
            wr = arrivals.popleft()
            req = self._make_request(wr.prompt, wr.max_new, wr.temperature,
                                     wr.seed, wr.priority, wr.slo)
            req.arrival_s = t0 + wr.arrival_s
            req.queued_s = req.arrival_s
            self._push(req)

    def _run_paged(self, cache_len: int,
                   arrivals: Optional[deque] = None,
                   t0: float = 0.0) -> Dict[int, List[int]]:
        bs = self.block_size
        table_width = blocks_for(cache_len, bs)
        num_blocks = (self.kv_blocks if self.kv_blocks is not None
                      else self.max_batch * table_width + 1)
        # cache_len=0 (every request degenerate-retires) still needs the
        # scratch block plus one allocatable block for the pool to exist
        num_blocks = max(num_blocks, 2)
        self.pool = KVBlockPool(num_blocks, bs, telemetry=self.tel)
        # the index is per pool: block ids are meaningless across runs
        self.prefix = (PrefixCache(self.pool, self.prefix_cache_blocks,
                                   telemetry=self.tel)
                       if self.prefix_enabled else None)
        caches = self.core.alloc_paged_caches(num_blocks, bs)
        self.kv_block_bytes = self.core.paged_block_bytes(caches)
        lanes: List[Optional[Request]] = [None] * self.max_batch
        results: Dict[int, List[int]] = {}

        while self._queue or arrivals or any(r is not None for r in lanes):
            if arrivals:
                self._admit_arrivals(arrivals, t0)
                if not self._queue and not any(r is not None for r in lanes):
                    # idle until the next arrival: sleep briefly instead of
                    # spinning (open loop — the clock keeps running)
                    if arrivals:
                        gap = arrivals[0].arrival_s - (
                            time.perf_counter() - t0)
                        if gap > 0:
                            time.sleep(min(gap, 0.002))
                    continue
            self._admit_paged(lanes, cache_len, results)

            # one prefill chunk per prefilling request, interleaved with the
            # decode step below — policy predictions submitted during these
            # chunks warm the ExpertCache before the first decode token
            for req in [r for r in lanes if r is not None and r.prefilling]:
                if self.prefix is not None:
                    self._extend_prefix(req)         # adopt siblings' blocks
                if req.prefilling:
                    n = min(self.prefill_chunk, req.prefill_end - req.t)
                    caches = self._cow(caches, req, req.t, n)
                    req.table.ensure(req.t + n - 1)
                    chunk = req.prompt[req.t: req.t + n]
                    _, caches, experts = self.core.prefill_chunk(
                        caches, req.table.padded(table_width), req.t, chunk,
                        self._policy, req.rid)
                    if self.prefix is not None:
                        self._record_experts(req, req.t, experts)
                    req.t += n
                    # publish completed blocks NOW: same-wave siblings pick
                    # them up at their next chunk boundary
                    self._insert_prefix(req)
                if not req.prefilling:
                    if req.t >= req.n_total:         # truncated by cache_len
                        self._retire(lanes, req, results)
                    else:
                        req.cur = int(req.prompt[req.t])

            active = [r for r in lanes
                      if r is not None and not r.prefilling]
            if not active:
                continue
            self._count_fallback(active)
            for r in active:
                r.table.ensure(r.t)
                caches = self._cow(caches, r, r.t, 1)
            tables = np.stack([r.table.padded(table_width) for r in active])
            logits, caches, experts_step = self.core.step(
                caches,
                rows=[r.lane for r in active],
                pos=[r.t for r in active],
                tokens=[r.cur for r in active],
                policy=self._policy,
                rids=[r.rid for r in active],
                tables=tables)
            for r, lg, exp in zip(active, logits, experts_step):
                if self.prefix is not None and r.t < len(r.prompt):
                    # prompt tokens decoded (e.g. the final one) complete
                    # blocks the index can still use
                    self._record_experts(r, r.t, [[ids] for ids in exp])
                r.feed_result(lg)
                if r.done:                           # retire frees blocks
                    self._retire(lanes, r, results)
        self.pool.check_leaks(expected_in_use=(
            self.prefix.cached_blocks if self.prefix is not None else 0))
        return results

    # ------------------------------------------------------------------
    def generate(self, prompts: Sequence[Sequence[int]], max_new: int,
                 cache_len: int, temperature: float = 0.0,
                 seeds: Optional[Sequence[int]] = None) -> List[List[int]]:
        """Decode a batch of prompts; returns per-prompt generated tokens
        in submission order."""
        rids = [self.submit(p, max_new, temperature,
                            seeds[i] if seeds is not None else 0)
                for i, p in enumerate(prompts)]
        results = self.run(cache_len)
        return [results[r] for r in rids]
