"""recurrentgemma-9b [hybrid] — Griffin: RG-LRU + local attention, 1:2 ratio.

[arXiv:2402.19427]: 38 layers, d_model 4096, 16 heads (MQA kv=1,
head_dim 256), d_ff 12288 (GeGLU), vocab 256000, pattern = 2 recurrent
(RG-LRU) blocks : 1 local-attention (window 2048) block.
Recurrent state is O(1) in sequence length -> long_500k-eligible.
"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    ffn_kind="geglu",
    rglru=RGLRUConfig(lru_width=4096, d_conv=4),
    rope_theta=10_000.0,
    tie_embeddings=True,
    long_context_ok=True,
    source="arXiv:2402.19427",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=1, head_dim=64,
        d_ff=512, vocab_size=512, window=32,
        block_pattern=("rglru", "local"),
        rglru=RGLRUConfig(lru_width=256, d_conv=4),
    )
