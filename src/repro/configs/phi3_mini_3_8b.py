"""phi3-mini-3.8b [dense] — RoPE + SwiGLU + GQA (kv == heads, i.e. MHA).

[arXiv:2404.14219]: 32 layers, d_model 3072, 32 heads (kv=32, head_dim 96),
d_ff 8192, vocab 32064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    arch_type="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    block_pattern=("global",),
    rope_theta=10_000.0,
    long_context_ok=False,
    source="arXiv:2404.14219",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=512,
    )
