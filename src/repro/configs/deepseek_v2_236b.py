"""deepseek-v2-236b [moe] — MLA + fine-grained MoE (the paper's big sibling).

[arXiv:2405.04434]: 60 layers, d_model 5120, 128 heads, MLA with
kv_lora_rank 512 / q_lora_rank 1536 / rope_head_dim 64 / nope 128 / v 128;
MoE: 2 shared + 160 routed experts, top-6, expert d_ff 1536; first layer
dense (d_ff 12288); vocab 102400.

This is the most paper-representative assigned architecture: the paper's
backbone (DeepSeek-V2-Lite) is this family at reduced scale, and the expert
cache / learned prefetch technique applies first-class.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,   # MLA: kv heads == heads post up-projection
    head_dim=128,
    d_ff=1536,          # assigned table value == routed-expert d_ff
    vocab_size=102400,
    block_pattern=("mla",),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(
        num_experts=160,
        top_k=6,
        num_shared=2,
        d_ff_expert=1536,
        first_dense_layers=1,
        d_ff_dense=12288,
    ),
    rope_theta=10_000.0,
    long_context_ok=False,  # full (latent) attention -> skip long_500k
    source="arXiv:2405.04434",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, head_dim=32,
        d_ff=128, vocab_size=512,
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=64, rope_head_dim=16,
                      nope_head_dim=32, v_head_dim=32),
        moe=MoEConfig(num_experts=4, top_k=2, num_shared=1, d_ff_expert=128,
                      first_dense_layers=1, d_ff_dense=256),
    )
