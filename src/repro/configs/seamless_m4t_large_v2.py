"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal.

[arXiv:2308.11596]: 24-layer decoder, d_model 1024, 16 heads (kv=16,
head_dim 64), d_ff 8192, vocab 256206; 24-layer encoder over audio frame
embeddings. The mel-spectrogram + conformer feature extractor is a STUB per
the assignment carve-out: input_specs() supplies frame embeddings.
"""
from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    block_pattern=("global",),
    encdec=EncDecConfig(enc_layers=24, enc_heads=16, enc_d_ff=8192),
    frontend="audio",
    frontend_dim=1024,
    frontend_len=512,        # audio frames per example
    rope_theta=10_000.0,
    long_context_ok=False,   # full attention decoder -> skip long_500k
    source="arXiv:2308.11596",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=512,
        encdec=EncDecConfig(enc_layers=2, enc_heads=4, enc_d_ff=512),
        frontend_dim=128, frontend_len=32,
    )
