"""yi-6b [dense] — llama-architecture GQA decoder.

[arXiv:2403.04652]: 32 layers, d_model 4096, 32 heads (GQA kv=4,
head_dim 128), d_ff 11008, vocab 64000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    block_pattern=("global",),
    rope_theta=5_000_000.0,
    long_context_ok=False,
    source="arXiv:2403.04652",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512,
    )
