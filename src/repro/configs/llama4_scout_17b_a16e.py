"""llama4-scout-17b-a16e [moe] — MoE with early fusion, chunked attention.

[hf:meta-llama/Llama-4-Scout-17B-16E]: 48 layers, d_model 5120, 40 heads
(GQA kv=8, head_dim 128), d_ff 8192, vocab 202048, 16 routed experts top-1
plus one shared expert; 3:1 chunked-local (iRoPE, 8192 chunk) : global
attention, which makes it long_500k-eligible.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=("chunked", "chunked", "chunked", "global"),
    chunk=8192,
    moe=MoEConfig(
        num_experts=16,
        top_k=1,
        num_shared=1,
        d_ff_expert=8192,
        capacity_factor=2.0,  # top-1 routing needs slack
    ),
    frontend="vision",
    frontend_dim=1408,
    frontend_len=256,
    rope_theta=500_000.0,
    long_context_ok=True,   # chunked local attention (iRoPE)
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, chunk=64,
        block_pattern=("chunked", "global"),
        moe=MoEConfig(num_experts=4, top_k=1, num_shared=1, d_ff_expert=256,
                      capacity_factor=2.0),
        frontend_dim=128, frontend_len=16,
    )
