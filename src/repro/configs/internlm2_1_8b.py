"""internlm2-1.8b [dense] — GQA decoder.

[arXiv:2403.17297]: 24 layers, d_model 2048, 16 heads (GQA kv=8,
head_dim 128), d_ff 8192, vocab 92544.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    arch_type="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92544,
    block_pattern=("global",),
    rope_theta=1_000_000.0,
    long_context_ok=False,
    source="arXiv:2403.17297",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512,
    )
