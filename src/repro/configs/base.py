"""Model configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; the model
builder (``repro.models.model``) consumes only this dataclass, so a config
file fully determines an architecture.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# Block kinds usable in ``ModelConfig.block_pattern``:
#   "global"   full causal attention (GQA)
#   "local"    sliding-window causal attention (GQA), window = cfg.window
#   "chunked"  chunked local attention (llama4 iRoPE style), chunk = cfg.chunk
#   "mla"      multi-head latent attention (DeepSeek-V2), needs cfg.mla
#   "rglru"    Griffin recurrent block (RG-LRU), needs cfg.rglru
#   "ssd"      Mamba-2 SSD block, needs cfg.ssm
ATTN_KINDS = ("global", "local", "chunked", "mla")
RECURRENT_KINDS = ("rglru", "ssd")


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int                 # routed experts
    top_k: int
    d_ff_expert: int
    num_shared: int = 0              # shared (always-on) experts
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    first_dense_layers: int = 0      # leading layers that use a dense FFN
    d_ff_dense: int = 0              # d_ff for those dense layers (0 -> cfg.d_ff)
    # tokens per dispatch group: the one-hot dispatch einsum costs
    # O(S_g * cf / (3 * d_ff_expert)) relative to useful expert compute, so
    # smaller groups cut dispatch FLOPs/bytes linearly (EXPERIMENTS.md §Perf)
    dispatch_group: int = 4096
    # decode-time gather path (fetch only the routed experts' weights):
    # wins on an unsharded edge store, loses under expert-parallel sharding
    # (EXPERIMENTS.md §Perf B1) — hence opt-in
    decode_gather: bool = False


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    headdim: int = 64
    chunk: int = 128
    d_conv: int = 4


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0               # 0 -> d_model
    d_conv: int = 4
    block_width: int = 0             # unused placeholder for future


@dataclass(frozen=True)
class EncDecConfig:
    enc_layers: int = 24
    enc_heads: int = 16
    enc_d_ff: int = 8192
    # encoder consumes frontend embeddings (audio frames), is bidirectional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    block_pattern: Tuple[str, ...] = ("global",)
    window: int = 1024               # sliding-window size for "local"
    chunk: int = 8192                # chunk size for "chunked"
    ffn_kind: str = "swiglu"         # swiglu | geglu
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: Optional[str] = None   # None | "vision" | "audio" (stubbed)
    frontend_dim: int = 1024         # dim of precomputed patch/frame embeddings
    frontend_len: int = 256          # patches/frames per example
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    long_context_ok: bool = False    # eligible for long_500k (sub-quadratic)
    source: str = ""                 # citation for the config

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def is_recurrent_kind(self, kind: str) -> bool:
        return kind in RECURRENT_KINDS

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expand block_pattern to num_layers entries (pattern repeats)."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.hd
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        kinds = self.layer_kinds()
        for i, k in enumerate(kinds):
            if k in ("global", "local", "chunked"):
                n += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
                    + self.num_heads * hd * d
            elif k == "mla":
                m = self.mla
                qk = m.nope_head_dim + m.rope_head_dim
                n += d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk
                n += d * (m.kv_lora_rank + m.rope_head_dim)
                n += m.kv_lora_rank * self.num_heads * (m.nope_head_dim + m.v_head_dim)
                n += self.num_heads * m.v_head_dim * d
            elif k == "ssd":
                s = self.ssm
                di = s.expand * d
                n += d * (2 * di + 2 * s.d_state + di // s.headdim) + di * d
            elif k == "rglru":
                w = (self.rglru.lru_width or d)
                n += 2 * d * w + 3 * w + w * d  # in-projs + gates + out
            # FFN
            n += self._ffn_params(i)
        return n

    def _ffn_params(self, layer_idx: int) -> int:
        d = self.d_model
        if self.layer_kinds()[layer_idx] == "ssd":
            return 0  # mamba block has no separate FFN
        if self.moe is not None and layer_idx >= self.moe.first_dense_layers:
            m = self.moe
            per = 3 * d * m.d_ff_expert
            return (m.num_experts + m.num_shared) * per + d * m.num_experts
        dff = self.d_ff
        if self.moe is not None and self.moe.d_ff_dense:
            dff = self.moe.d_ff_dense
        return 3 * d * dff

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        d = self.d_model
        per = 3 * d * m.d_ff_expert
        n_moe_layers = self.num_layers - m.first_dense_layers
        inactive = n_moe_layers * (m.num_experts - m.top_k) * per
        return full - inactive


@dataclass(frozen=True)
class PredictorConfig:
    """The paper's expert-activation predictor (§3.2)."""
    token_emb_dim: int = 2048        # backbone token-embedding dim
    num_model_layers: int = 27       # backbone MoE layers (layer-id vocab)
    num_experts: int = 64            # routed experts to predict
    layer_emb_dim: int = 512
    d_model: int = 512
    num_layers: int = 4
    num_heads: int = 8
    d_ff: int = 2048
    dropout: float = 0.1
    max_seq: int = 512
    top_k: int = 6                   # experts selected at eval
    threshold: float = 0.5
    horizon: int = 1                 # layers of look-ahead (paper: 1; >1 is ours)

    def replace(self, **kw) -> "PredictorConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper.
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                        # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
