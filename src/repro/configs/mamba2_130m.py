"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060]: 24 layers, d_model 768, ssm_state 128, expand 2
(d_inner 1536, headdim 64 -> 24 ssd heads), vocab 50280. No attention, no
separate FFN (the Mamba block is the whole layer). O(1) decode state ->
long_500k-eligible.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    num_layers=24,
    d_model=768,
    num_heads=24,            # d_inner // headdim (informational for ssd)
    num_kv_heads=24,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    block_pattern=("ssd",),
    ssm=SSMConfig(d_state=128, expand=2, headdim=64, chunk=128, d_conv=4),
    tie_embeddings=True,
    long_context_ok=True,
    source="arXiv:2405.21060",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=8, head_dim=32,
        vocab_size=512,
        ssm=SSMConfig(d_state=32, expand=2, headdim=64, chunk=32, d_conv=4),
    )
