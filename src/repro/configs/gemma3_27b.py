"""gemma3-27b [dense] — 5:1 local:global sliding-window attention, 128k ctx.

[hf:google/gemma-3-1b-pt family card, 27B variant]: 62 layers, d_model 5376,
32 heads (GQA kv=16, head_dim 128), d_ff 21504 (GeGLU), vocab 262144,
pattern = 5 sliding-window (1024) layers : 1 global layer.
Sliding-window makes it long_500k-eligible.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    arch_type="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    block_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    ffn_kind="geglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    long_context_ok=True,
    source="hf:google/gemma-3-1b-pt (27B config)",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, window=32,
        block_pattern=("local", "global"),
    )
