"""deepseek-v2-lite — the paper's backbone (not in the assigned pool, but the
reproduction target: 27 layers, 64 routed experts top-6 + 2 shared, MLA).

[arXiv:2405.04434 (Lite variant), paper §4.1.1]: 27 layers (first dense),
d_model 2048, 16 heads, MLA kv_lora 512 / rope 64 / nope 128 / v 128 with a
direct (uncompressed) q projection, 64 routed experts top-6 + 2 shared,
expert d_ff 1408, dense d_ff 10944, vocab 102400. 15.7B total / 2.4B active.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite",
    arch_type="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    block_pattern=("mla",),
    mla=MLAConfig(q_lora_rank=0, kv_lora_rank=512, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        num_shared=2,
        d_ff_expert=1408,
        first_dense_layers=1,
        d_ff_dense=10944,
    ),
    rope_theta=10_000.0,
    long_context_ok=False,
    source="arXiv:2405.04434 (Lite); paper §4.1.1",
)


def reduced() -> ModelConfig:
    """The backbone actually trained end-to-end in examples/ and tests."""
    return CONFIG.replace(
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512,
        mla=MLAConfig(q_lora_rank=0, kv_lora_rank=32, rope_head_dim=16,
                      nope_head_dim=32, v_head_dim=32),
        moe=MoEConfig(num_experts=16, top_k=2, num_shared=1, d_ff_expert=128,
                      first_dense_layers=1, d_ff_dense=256,
                      router_aux_coef=0.002),
    )
