"""pixtral-12b [vlm] — Pixtral-ViT frontend (stubbed) + Mistral-Nemo decoder.

[hf:mistralai/Pixtral-12B-2409]: 40 layers, d_model 5120, 32 heads (GQA kv=8,
head_dim 128), d_ff 14336, vocab 131072. Vision tower supplies patch
embeddings (stub per assignment carve-out).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    arch_type="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    block_pattern=("global",),
    frontend="vision",
    frontend_dim=1024,
    frontend_len=256,
    rope_theta=1_000_000.0,
    long_context_ok=False,  # pure full attention -> skip long_500k
    source="hf:mistralai/Pixtral-12B-2409",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, frontend_dim=128, frontend_len=16,
    )
