"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (  # noqa: F401 (re-export)
    INPUT_SHAPES,
    EncDecConfig,
    InputShape,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    PredictorConfig,
    RGLRUConfig,
    SSMConfig,
)

# arch id -> module name
_ARCH_MODULES = {
    "pixtral-12b": "pixtral_12b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "gemma3-27b": "gemma3_27b",
    "yi-6b": "yi_6b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "internlm2-1.8b": "internlm2_1_8b",
    "mamba2-130m": "mamba2_130m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    # the paper's own backbone (reproduction target, not in assigned pool)
    "deepseek-v2-lite": "deepseek_v2_lite",
}

ASSIGNED_ARCHS = tuple(a for a in _ARCH_MODULES if a != "deepseek-v2-lite")


def _mod(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    # reduced variants exist for CPU smoke tests -> f32 for tight numerics
    return _mod(arch).reduced().replace(dtype="float32")


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in _ARCH_MODULES}
