"""Synthetic topic-mixture corpus.

Stands in for Puffin/WebGLM-QA (offline container): K topics, each with its
own Zipfian unigram distribution over a topic-specific vocabulary slice plus
a shared slice, and a sticky bigram kick. Prompts drawn from one topic make
a trained MoE router specialise — reproducing the property the paper
exploits (within-request expert locality, across-request uniformity,
paper Figs 1-3).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class TopicCorpus:
    vocab_size: int
    n_topics: int
    topic_probs: np.ndarray      # (K, V) unigram distribution per topic
    seed: int

    def sample_tokens(self, topic: int, length: int,
                      rng: np.random.Generator) -> np.ndarray:
        p = self.topic_probs[topic]
        toks = rng.choice(self.vocab_size, size=length, p=p)
        # sticky bigrams: with prob .3 repeat-shift the previous token,
        # giving the LM something learnable beyond unigrams
        for i in range(1, length):
            if rng.random() < 0.3:
                toks[i] = (toks[i - 1] + 1) % self.vocab_size
        return toks.astype(np.int32)


def make_topic_corpus(vocab_size: int, n_topics: int = 8,
                      shared_frac: float = 0.25, zipf_a: float = 1.2,
                      seed: int = 0) -> TopicCorpus:
    rng = np.random.default_rng(seed)
    n_shared = int(vocab_size * shared_frac)
    per_topic = (vocab_size - n_shared) // n_topics
    probs = np.zeros((n_topics, vocab_size))
    ranks = np.arange(1, per_topic + 1, dtype=np.float64)
    zipf = ranks ** -zipf_a
    for k in range(n_topics):
        lo = n_shared + k * per_topic
        own = rng.permutation(per_topic)
        probs[k, lo: lo + per_topic] = zipf[own]
        probs[k, :n_shared] = zipf.mean() * 0.5      # common tokens
        probs[k] /= probs[k].sum()
    return TopicCorpus(vocab_size, n_topics, probs, seed)


def lm_batches(corpus: TopicCorpus, batch_size: int, seq_len: int,
               n_batches: int, seed: int = 0):
    """Yield (B, S+1) token arrays; each row is a single-topic document."""
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        rows = []
        for _ in range(batch_size):
            topic = rng.integers(corpus.n_topics)
            rows.append(corpus.sample_tokens(topic, seq_len + 1, rng))
        yield np.stack(rows)


def sample_prompts(corpus: TopicCorpus, n_prompts: int, prompt_len: int,
                   seed: int = 0):
    """Batch-1 prompts (one topic each) for trace collection."""
    rng = np.random.default_rng(seed)
    prompts = []
    for _ in range(n_prompts):
        topic = int(rng.integers(corpus.n_topics))
        prompts.append(corpus.sample_tokens(topic, prompt_len, rng))
    return prompts
