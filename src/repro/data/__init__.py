from repro.data.synthetic import (  # noqa: F401
    TopicCorpus, lm_batches, make_topic_corpus, sample_prompts)
from repro.data.traces import PredictorDataset, SequenceCache  # noqa: F401
