"""Predictor dataset: (trace, MoE-layer) -> padded multi-label sequences.

Mirrors the paper's §3.2.1/§3.2.4 pipeline: max_seq 512 via truncation and
padding, batch size 4, and an LRU cache of processed sequences
(capacity 1000) to accelerate epoch iteration.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import List, Tuple

import numpy as np

from repro.configs.base import PredictorConfig


class SequenceCache:
    """LRU cache of processed (padded) sequences, capacity per the paper."""

    def __init__(self, capacity: int = 1000):
        self.capacity = capacity
        self._d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key):
        if key in self._d:
            self.hits += 1
            self._d.move_to_end(key)
            return self._d[key]
        self.misses += 1
        return None

    def put(self, key, value):
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)


class PredictorDataset:
    """One example per (trace, moe_layer): inputs are the trace's token
    embeddings with that layer's id, targets the multi-hot expert set
    (optionally for ``horizon`` consecutive layers — beyond-paper)."""

    def __init__(self, traces, pcfg: PredictorConfig,
                 cache_capacity: int = 1000):
        self.traces = traces
        self.pcfg = pcfg
        self.cache = SequenceCache(cache_capacity)
        self.index: List[Tuple[int, int]] = []
        for ti, tr in enumerate(traces):
            for layer in range(tr.experts.shape[1]):
                self.index.append((ti, layer))

    def __len__(self):
        return len(self.index)

    def example(self, i: int):
        key = self.index[i]
        cached = self.cache.get(key)
        if cached is not None:
            return cached
        ti, layer = key
        tr = self.traces[ti]
        pc = self.pcfg
        t = min(tr.num_tokens, pc.max_seq)

        emb = np.zeros((pc.max_seq, pc.token_emb_dim), np.float32)
        emb[:t] = tr.embeddings[:t, : pc.token_emb_dim]
        layer_ids = np.full((pc.max_seq,), layer, np.int32)
        mask = np.zeros((pc.max_seq,), bool)
        mask[:t] = True

        n_layers = tr.experts.shape[1]
        target = np.zeros((pc.max_seq, pc.num_experts * pc.horizon),
                          np.float32)
        for h in range(pc.horizon):
            ll = layer + h
            if ll >= n_layers:
                break
            idx = tr.experts[:t, ll]                       # (t, k)
            rows = np.repeat(np.arange(t), idx.shape[1])
            target[rows, idx.reshape(-1) + h * pc.num_experts] = 1.0
        ex = (emb, layer_ids, mask, target)
        self.cache.put(key, ex)
        return ex

    def batches(self, batch_size: int, seed: int = 0, shuffle: bool = True):
        order = np.arange(len(self))
        if shuffle:
            np.random.default_rng(seed).shuffle(order)
        for s in range(0, len(order), batch_size):
            items = [self.example(int(i)) for i in order[s: s + batch_size]]
            yield tuple(np.stack(z) for z in zip(*items))
