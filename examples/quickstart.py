"""Quickstart: the whole MoE-Beyond pipeline in ~2 minutes on CPU.

1. train a tiny DeepSeek-V2-Lite-family MoE backbone on a topic corpus
2. collect batch-1 expert-activation traces (the paper's dataset schema)
3. train the learned expert-activation predictor (paper §3.2)
4. replay held-out traces through the cache simulator and compare policies

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.configs.base import PredictorConfig
from repro.core.policies import (MoEBeyondPolicy, MoEInfinityPolicy,
                                 NoPrefetchPolicy, OraclePolicy, RandomPolicy)
from repro.core.predictor_train import train_predictor
from repro.core.simulator import SimConfig, simulate
from repro.core.tracing import collect_traces, moe_layer_ids
from repro.data import lm_batches, make_topic_corpus, sample_prompts
from repro.models import build_model
from repro.training.optimizer import make_adamw

t0 = time.time()

# 1. backbone -------------------------------------------------------------
cfg = get_reduced("deepseek-v2-lite")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
corpus = make_topic_corpus(cfg.vocab_size, n_topics=4, seed=0)
opt_init, opt_update = make_adamw(lr=3e-3, clip=1.0)
opt_state = opt_init(params)


@jax.jit
def train_step(params, opt_state, tokens):
    def lf(p):
        return model.loss_fn(p, {"tokens": tokens})
    (loss, _), grads = jax.value_and_grad(lf, has_aux=True)(params)
    params, opt_state, _ = opt_update(grads, opt_state, params)
    return params, opt_state, loss


for i, tokens in enumerate(lm_batches(corpus, 16, 64, 80, seed=1)):
    params, opt_state, loss = train_step(params, opt_state,
                                         jnp.asarray(tokens[:, :64]))
print(f"[1] backbone trained: loss {float(loss):.3f} "
      f"({time.time() - t0:.0f}s)")

# 2. traces ---------------------------------------------------------------
prompts = sample_prompts(corpus, 14, 16, seed=2)
traces = collect_traces(model, params, prompts, max_new=48, cache_len=72)
train_tr, test_tr = traces[:10], traces[10:]
n_moe = len(moe_layer_ids(cfg))
print(f"[2] {len(traces)} traces collected, schema (T, L_moe={n_moe}, "
      f"k={cfg.moe.top_k}) ({time.time() - t0:.0f}s)")

# 3. predictor ------------------------------------------------------------
pcfg = PredictorConfig(token_emb_dim=cfg.d_model, num_model_layers=n_moe,
                       num_experts=cfg.moe.num_experts, layer_emb_dim=16,
                       d_model=64, num_layers=2, num_heads=4, d_ff=128,
                       max_seq=72, top_k=cfg.moe.top_k)
pp, hist = train_predictor(train_tr, test_tr, pcfg, epochs=6, batch_size=4,
                           base_lr=5e-3, patience=6)
print(f"[3] predictor: val acc {hist.val_acc[-1]:.3f}, "
      f"F1 {hist.val_f1[-1]:.3f} ({time.time() - t0:.0f}s)")

# 4. simulator ------------------------------------------------------------
sim = SimConfig(num_layers=n_moe, num_experts=cfg.moe.num_experts,
                capacity_fraction=0.2, warm_tokens=6)
print(f"[4] cache simulator @ {sim.capacity_fraction:.0%} expert capacity:")
for policy in [NoPrefetchPolicy(), RandomPolicy(cfg.moe.num_experts, 6),
               MoEInfinityPolicy(train_tr, n_moe, cfg.moe.num_experts, 6),
               MoEBeyondPolicy(pp, pcfg), OraclePolicy()]:
    r = simulate(test_tr, policy, sim)
    print(f"    {r.policy:16s} cache-hit {r.cache_hit_rate:.3f}  "
          f"pred-hit {r.prediction_hit_rate:.3f}  "
          f"stall {r.est_stall_s_per_token * 1e3:.2f} ms/token")
print(f"done in {time.time() - t0:.0f}s")
