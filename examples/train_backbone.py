"""End-to-end driver: train a ~100M-param MoE LM for a few hundred steps.

The ~100M config is the DeepSeek-V2-Lite family scaled to this container
(d_model 256, 8 layers, 16 experts); pass --steps/--batch to scale.

Run:  PYTHONPATH=src python examples/train_backbone.py --steps 200
"""
import argparse

from repro.configs import get_reduced
from repro.launch.train import train


def hundred_m_config():
    cfg = get_reduced("deepseek-v2-lite")
    from repro.configs.base import MLAConfig, MoEConfig
    return cfg.replace(
        num_layers=8, d_model=256, num_heads=8, num_kv_heads=8, head_dim=32,
        vocab_size=8192, d_ff=512,
        mla=MLAConfig(q_lora_rank=0, kv_lora_rank=64, rope_head_dim=16,
                      nope_head_dim=32, v_head_dim=32),
        moe=MoEConfig(num_experts=16, top_k=2, num_shared=1,
                      d_ff_expert=512, first_dense_layers=1,
                      d_ff_dense=1024),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--save", default="artifacts/backbone_100m.npz")
    args = ap.parse_args()

    import jax

    from repro.models import build_model
    cfg = hundred_m_config()
    model = build_model(cfg)
    n = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(model.init, jax.random.PRNGKey(0))))
    print(f"training {n / 1e6:.0f}M-param MoE "
          f"({cfg.num_layers}L x {cfg.moe.num_experts}e top-{cfg.moe.top_k})")

    # reuse the launcher's loop with this custom config via monkey config:
    import repro.launch.train as LT

    def patched_get_reduced(arch):
        return cfg
    LT.get_reduced = patched_get_reduced
    LT.train("deepseek-v2-lite", reduced=True, steps=args.steps,
             batch_size=args.batch, seq_len=args.seq, lr=3e-3,
             save=args.save)


if __name__ == "__main__":
    main()
