"""Continuous-batching serving demo: many requests share ONE expert cache
and decode together through the BatchedOffloadEngine — a finished request
frees its KV-cache row and the next queued one takes it, while the policy's
expert predictions for the next MoE layer are fetched host->device behind
the current layer's attention.

Run:  PYTHONPATH=src python examples/serve_batched.py \
          --policy moe-infinity --capacity-frac 0.3 --max-batch 4 \
          --requests 8 --tokens 24
"""
from __future__ import annotations

import argparse
import time

from repro.configs import get_reduced
from repro.core.policies import (MoEInfinityPolicy, NextLayerAllPolicy,
                                 NoPrefetchPolicy, RandomPolicy)
from repro.core.tracing import collect_traces, moe_layer_ids
from repro.data import make_topic_corpus, sample_prompts
from repro.launch.train import train
from repro.models import build_model
from repro.serving.scheduler import BatchedOffloadEngine


def build_policy_spec(name: str, cfg, train_traces, width: int = 6):
    """Stateless policies are shared; stateful ones get a per-request
    factory (the scheduler instantiates one per admitted request)."""
    n_layers = len(moe_layer_ids(cfg))
    e = cfg.moe.num_experts
    if name == "none":
        return NoPrefetchPolicy()
    if name == "random":
        return lambda: RandomPolicy(e, width)
    if name == "next-layer-all":
        return NextLayerAllPolicy(e)
    if name == "moe-infinity":
        return lambda: MoEInfinityPolicy(train_traces, n_layers, e, width)
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite")
    ap.add_argument("--policy", default="moe-infinity",
                    choices=["none", "random", "next-layer-all",
                             "moe-infinity"])
    ap.add_argument("--capacity-frac", type=float, default=0.3)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--layer-compute-us", type=float, default=50.0)
    args = ap.parse_args()

    params, _ = train(args.arch, reduced=True, steps=args.train_steps,
                      batch_size=16, seq_len=64)
    cfg = get_reduced(args.arch)
    model = build_model(cfg)
    corpus = make_topic_corpus(cfg.vocab_size, n_topics=8, seed=0)

    train_traces = collect_traces(
        model, params, sample_prompts(corpus, 8, 16), max_new=48,
        cache_len=80)

    n_layers = len(moe_layer_ids(cfg))
    capacity = max(args.max_batch * cfg.moe.top_k,
                   int(args.capacity_frac * n_layers * cfg.moe.num_experts))
    engine = BatchedOffloadEngine(
        model, params, build_policy_spec(args.policy, cfg, train_traces),
        capacity, max_batch=args.max_batch,
        layer_compute_s=args.layer_compute_us * 1e-6)

    prompts = sample_prompts(corpus, args.requests, 12, seed=123)
    cache_len = 12 + args.tokens + 1
    t0 = time.time()
    outs = engine.generate(prompts, max_new=args.tokens,
                           cache_len=cache_len)
    dt = time.time() - t0
    s = engine.stats
    print(f"policy={args.policy} capacity={capacity} "
          f"max_batch={args.max_batch} requests={args.requests}")
    print(f"decoded {s.tokens} tokens in {dt:.1f}s "
          f"({s.tokens / dt:.1f} tok/s) over {s.steps} batched steps "
          f"(mean occupancy {s.mean_batch:.2f})")
    print(f"cache hit rate: {s.hit_rate:.3f} ({s.hits}/{s.hits + s.misses}),"
          f" fetched {s.fetch_bytes / 2**20:.1f} MiB")
    print(f"modeled stall: {s.sim_stall_s * 1e3:.1f} ms overlapped vs "
          f"{s.blocking_stall_s * 1e3:.1f} ms blocking "
          f"({s.overlapped_s * 1e3:.1f} ms hidden behind compute)")
    if engine.pool is not None:
        ps = engine.pool.stats
        tt = sorted(engine.ttft().values())
        p50 = f"{tt[len(tt) // 2] * 1e3:.0f} ms" if tt else "n/a"
        print(f"paged KV: {s.prefill_tokens} prompt tokens in "
              f"{s.prefill_chunks} prefill chunks; {ps.high_water} blocks "
              f"high-water ({engine.kv_high_water_bytes / 2**10:.0f} KiB) of "
              f"{engine.pool.num_blocks - 1}; TTFT p50 {p50}")
    for rid, out in enumerate(outs[: 4]):
        print(f"  req {rid}: {out[:12]}{'...' if len(out) > 12 else ''}")


if __name__ == "__main__":
    main()
