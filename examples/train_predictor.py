"""Train the MoE-Beyond predictor on saved traces with the paper's protocol
(AdamW b2=.98, layerwise LRs, clip 1.0, batch 4, early stopping).

Run:  PYTHONPATH=src python examples/train_predictor.py \
          --traces artifacts/my_traces.npz
"""
import argparse

from repro.configs import get_reduced
from repro.configs.base import PredictorConfig
from repro.core.predictor_train import train_predictor
from repro.core.tracing import load_traces, moe_layer_ids
from repro.training import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--traces", default="artifacts/my_traces.npz")
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--out", default="artifacts/my_predictor.npz")
    args = ap.parse_args()

    traces = load_traces(args.traces)
    n_val = max(1, len(traces) // 5)
    train_tr, val_tr = traces[:-n_val], traces[-n_val:]
    cfg = get_reduced("deepseek-v2-lite")
    n_moe = len(moe_layer_ids(cfg))
    pcfg = PredictorConfig(
        token_emb_dim=traces[0].embeddings.shape[1],
        num_model_layers=traces[0].experts.shape[1],
        num_experts=cfg.moe.num_experts, layer_emb_dim=32, d_model=96,
        num_layers=4, num_heads=8, d_ff=192, max_seq=96,
        top_k=cfg.moe.top_k)
    params, hist = train_predictor(train_tr, val_tr, pcfg,
                                   epochs=args.epochs,
                                   batch_size=args.batch, base_lr=args.lr)
    ckpt.save(args.out, params)
    print(f"best val: loss {min(hist.val_loss):.4f}, "
          f"acc {max(hist.val_acc):.4f}, F1 {max(hist.val_f1):.4f}")
    print(f"saved predictor to {args.out}")


if __name__ == "__main__":
    main()
