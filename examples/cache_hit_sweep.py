"""Reproduce paper Fig 7 (cache hit rate vs GPU expert capacity) using the
shared benchmark pipeline — prints the sweep for every policy.

Run:  PYTHONPATH=src python examples/cache_hit_sweep.py
"""
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.fig7_cache_hit import run  # noqa: E402

if __name__ == "__main__":
    run()
