"""Serving demo: batch-1 autoregressive decode through the REAL offload
engine — expert weights live in a host store, a fixed-capacity device slot
buffer acts as the HBM expert cache, and the chosen policy prefetches.

Run:  PYTHONPATH=src python examples/serve_with_cache.py \
          --policy moe-infinity --capacity-frac 0.2
(see also: python -m repro.launch.serve)
"""
import argparse
import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main()
