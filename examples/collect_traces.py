"""Trace collection (paper Contribution 2): run batch-1 decoding over many
prompts and persist the (token, layer, expert-ids, embedding) trace dataset.

Run:  PYTHONPATH=src python examples/collect_traces.py --n 24 \
          --out artifacts/my_traces.npz
"""
import argparse

from repro.core.tracing import collect_traces, save_traces
from repro.data import make_topic_corpus, sample_prompts
from repro.launch.train import train
from repro.configs import get_reduced
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=56)
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--out", default="artifacts/my_traces.npz")
    args = ap.parse_args()

    params, _ = train("deepseek-v2-lite", reduced=True,
                      steps=args.train_steps, batch_size=16, seq_len=64)
    cfg = get_reduced("deepseek-v2-lite")
    model = build_model(cfg)
    corpus = make_topic_corpus(cfg.vocab_size, n_topics=8, seed=0)
    prompts = sample_prompts(corpus, args.n, args.prompt_len, seed=42)
    traces = collect_traces(model, params, prompts, max_new=args.max_new,
                            cache_len=args.prompt_len + args.max_new)
    save_traces(args.out, traces)
    total = sum(t.num_tokens * t.experts.shape[1] * t.experts.shape[2]
                for t in traces)
    print(f"saved {len(traces)} traces ({total} activation records) "
          f"to {args.out}")


if __name__ == "__main__":
    main()
