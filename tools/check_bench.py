"""Perf-regression smoke gate for the tiered engine bench (CI).

Extracts a small set of stable metrics from the ``--tiers --dispatch all``
artifact (``benchmarks/engine_bench.py``) and fails when any regresses
more than ``--tol`` (default 25%) against the committed
``BENCH_BASELINE.json``:

  * modeled un-overlapped stall (ms) for the fetch-only and auto dispatch
    modes, and the horizon-aware prefetch row — deterministic given the
    seeds (the OverlapTracker clock is modeled, not wall time), so a move
    means the cost model or the engine's overlap behaviour changed;
  * the stall *reductions* (auto vs fetch-only, horizon-aware vs fixed) —
    the headline wins the benches assert directionally, gated here on
    magnitude;
  * the tier-0+1 hit rate of the full-capacity 4-shard sweep row —
    deterministic routing + placement;
  * the auto/fetch tok/s ratio — wall-clock, but machine speed cancels in
    the ratio, so 25% is a wide-enough band for CI hosts.

Absolute tok/s and wall seconds are deliberately NOT gated: they measure
the CI host, not the code.

Usage (from the repo root):
  PYTHONPATH=src python -m benchmarks.engine_bench --tiny --tiers \
      --dispatch all --out artifacts/engine_bench_tiers.json
  python tools/check_bench.py --current artifacts/engine_bench_tiers.json

``--update`` rewrites the baseline from the current artifact (run it when
a perf change is intentional and commit the diff). Exit 0 = within
tolerance; 1 = regression (each printed on its own line).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(REPO, "BENCH_BASELINE.json")

# metric name -> direction: "lower" = smaller is better, "higher" = bigger
DIRECTIONS = {
    "dispatch_fetch_stall_ms": "lower",
    "dispatch_auto_stall_ms": "lower",
    "dispatch_stall_reduction": "higher",
    "dispatch_tok_s_auto_over_fetch": "higher",
    "horizon_aware_stall_ms": "lower",
    "horizon_stall_reduction": "higher",
    "tier01_hit_rate_4shard_full": "higher",
}

# below this, a "lower" metric is noise-floor and compared by absolute
# slack instead of ratio (0.25 of ABS_FLOOR), so a 0.001 -> 0.002 ms move
# cannot fail the gate
ABS_FLOOR = 0.05


def extract(doc: dict) -> tuple[dict, list]:
    """(gated metrics, missing dotted key paths) from one engine_bench
    --tiers artifact. A renamed/removed key never raises: it lands in the
    missing list so the gate can print a readable schema diff instead of
    a KeyError traceback."""
    out: dict = {}
    missing: list = []

    def dig(path: str):
        cur = doc
        parts = path.split(".")
        for i, part in enumerate(parts):
            if not isinstance(cur, dict) or part not in cur:
                missing.append(".".join(parts[: i + 1]))
                return None
            cur = cur[part]
        return cur

    if "dispatch_comparison" in doc:
        fetch_stall = dig("dispatch_comparison.fetch.sim_stall_ms")
        auto_stall = dig("dispatch_comparison.auto.sim_stall_ms")
        fetch_tok = dig("dispatch_comparison.fetch.tok_s")
        auto_tok = dig("dispatch_comparison.auto.tok_s")
        if fetch_stall is not None:
            out["dispatch_fetch_stall_ms"] = fetch_stall
        if auto_stall is not None:
            out["dispatch_auto_stall_ms"] = auto_stall
        if fetch_tok is not None and auto_tok is not None:
            out["dispatch_tok_s_auto_over_fetch"] = (
                auto_tok / max(fetch_tok, 1e-9))
    if "dispatch_stall_reduction" in doc:
        out["dispatch_stall_reduction"] = doc["dispatch_stall_reduction"]
    if "horizon_aware" in doc:
        v = dig("horizon_aware.sim_stall_ms")
        if v is not None:
            out["horizon_aware_stall_ms"] = v
    if "horizon_stall_reduction" in doc:
        out["horizon_stall_reduction"] = doc["horizon_stall_reduction"]
    rows = []
    for i, r in enumerate(doc.get("sweep", [])):
        if "num_shards" not in r or "replacement" not in r:
            missing.append(f"sweep[{i}].num_shards|replacement")
            continue
        if r["num_shards"] == 4 and r["replacement"] == "lru":
            rows.append((i, r))
    if rows:
        i, full = max(rows, key=lambda ir: ir[1].get("tier0_capacity", -1))
        if "tier01_hit_rate" in full:
            out["tier01_hit_rate_4shard_full"] = full["tier01_hit_rate"]
        else:
            missing.append(f"sweep[{i}].tier01_hit_rate")
    return out, missing


def key_diff(baseline: dict, current: dict) -> tuple[list, list]:
    """Metric names (missing from current, extra in current) vs baseline."""
    return (sorted(set(baseline) - set(current)),
            sorted(set(current) - set(baseline)))


def compare(baseline: dict, current: dict, tol: float) -> list:
    errors = []
    for name, base in baseline.items():
        direction = DIRECTIONS.get(name)
        if direction is None:
            continue
        if name not in current:
            errors.append(f"{name}: missing from current artifact "
                          f"(baseline {base:.4g})")
            continue
        cur = current[name]
        if direction == "lower":
            limit = max(base * (1 + tol), ABS_FLOOR * tol + base)
            if cur > limit:
                errors.append(
                    f"{name}: {cur:.4g} worse than baseline {base:.4g} "
                    f"by more than {tol:.0%} (limit {limit:.4g})")
        else:
            limit = base * (1 - tol)
            if cur < limit:
                errors.append(
                    f"{name}: {cur:.4g} worse than baseline {base:.4g} "
                    f"by more than {tol:.0%} (limit {limit:.4g})")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True,
                    help="engine_bench --tiers --dispatch all JSON artifact")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline (default BENCH_BASELINE.json)")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current artifact")
    args = ap.parse_args()

    with open(args.current) as f:
        current, missing_keys = extract(json.load(f))
    if missing_keys:
        print("check_bench: current artifact schema drift — missing "
              "key(s): " + ", ".join(sorted(set(missing_keys))))
    if not current:
        print("check_bench: current artifact has none of the gated "
              "metrics (was the bench run with --dispatch all?)")
        return 1

    if args.update:
        if missing_keys:
            print("check_bench: refusing --update from a drifted artifact "
                  "(the baseline would silently lose metrics)")
            return 1
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"check_bench: baseline updated -> {args.baseline}")
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)
    miss_names, extra_names = key_diff(baseline, current)
    if miss_names or extra_names:
        print("check_bench: metric diff vs baseline — missing from "
              f"current: {', '.join(miss_names) or 'none'}; extra in "
              f"current: {', '.join(extra_names) or 'none'}")
    errors = compare(baseline, current, args.tol)
    if missing_keys:
        errors.append("artifact schema drifted (see missing keys above)")
    for e in errors:
        print(f"check_bench: {e}")
    if errors:
        print(f"check_bench: {len(errors)} regression(s) beyond "
              f"{args.tol:.0%}")
        return 1
    print(f"check_bench: OK ({len(baseline)} metrics within "
          f"{args.tol:.0%} of baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
