"""Perf-regression smoke gate for the tiered engine bench (CI).

Extracts a small set of stable metrics from the ``--tiers --dispatch all``
artifact (``benchmarks/engine_bench.py``) and fails when any regresses
more than ``--tol`` (default 25%) against the committed
``BENCH_BASELINE.json``:

  * modeled un-overlapped stall (ms) for the fetch-only and auto dispatch
    modes, and the horizon-aware prefetch row — deterministic given the
    seeds (the OverlapTracker clock is modeled, not wall time), so a move
    means the cost model or the engine's overlap behaviour changed;
  * the stall *reductions* (auto vs fetch-only, horizon-aware vs fixed) —
    the headline wins the benches assert directionally, gated here on
    magnitude;
  * the tier-0+1 hit rate of the full-capacity 4-shard sweep row —
    deterministic routing + placement;
  * the auto/fetch tok/s ratio — wall-clock, but machine speed cancels in
    the ratio, so 25% is a wide-enough band for CI hosts.

Absolute tok/s and wall seconds are deliberately NOT gated: they measure
the CI host, not the code.

Usage (from the repo root):
  PYTHONPATH=src python -m benchmarks.engine_bench --tiny --tiers \
      --dispatch all --out artifacts/engine_bench_tiers.json
  python tools/check_bench.py --current artifacts/engine_bench_tiers.json

``--update`` rewrites the baseline from the current artifact (run it when
a perf change is intentional and commit the diff). Exit 0 = within
tolerance; 1 = regression (each printed on its own line).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(REPO, "BENCH_BASELINE.json")

# metric name -> direction: "lower" = smaller is better, "higher" = bigger
DIRECTIONS = {
    "dispatch_fetch_stall_ms": "lower",
    "dispatch_auto_stall_ms": "lower",
    "dispatch_stall_reduction": "higher",
    "dispatch_tok_s_auto_over_fetch": "higher",
    "horizon_aware_stall_ms": "lower",
    "horizon_stall_reduction": "higher",
    "tier01_hit_rate_4shard_full": "higher",
}

# below this, a "lower" metric is noise-floor and compared by absolute
# slack instead of ratio (0.25 of ABS_FLOOR), so a 0.001 -> 0.002 ms move
# cannot fail the gate
ABS_FLOOR = 0.05


def extract(doc: dict) -> dict:
    """The gated metrics from one engine_bench --tiers artifact."""
    out = {}
    disp = doc.get("dispatch_comparison")
    if disp and "fetch" in disp and "auto" in disp:
        out["dispatch_fetch_stall_ms"] = disp["fetch"]["sim_stall_ms"]
        out["dispatch_auto_stall_ms"] = disp["auto"]["sim_stall_ms"]
        out["dispatch_tok_s_auto_over_fetch"] = (
            disp["auto"]["tok_s"] / max(disp["fetch"]["tok_s"], 1e-9))
    if "dispatch_stall_reduction" in doc:
        out["dispatch_stall_reduction"] = doc["dispatch_stall_reduction"]
    if "horizon_aware" in doc:
        out["horizon_aware_stall_ms"] = doc["horizon_aware"]["sim_stall_ms"]
    if "horizon_stall_reduction" in doc:
        out["horizon_stall_reduction"] = doc["horizon_stall_reduction"]
    rows = [r for r in doc.get("sweep", [])
            if r["num_shards"] == 4 and r["replacement"] == "lru"]
    if rows:
        full = max(rows, key=lambda r: r["tier0_capacity"])
        out["tier01_hit_rate_4shard_full"] = full["tier01_hit_rate"]
    return out


def compare(baseline: dict, current: dict, tol: float) -> list:
    errors = []
    for name, base in baseline.items():
        direction = DIRECTIONS.get(name)
        if direction is None:
            continue
        if name not in current:
            errors.append(f"{name}: missing from current artifact "
                          f"(baseline {base:.4g})")
            continue
        cur = current[name]
        if direction == "lower":
            limit = max(base * (1 + tol), ABS_FLOOR * tol + base)
            if cur > limit:
                errors.append(
                    f"{name}: {cur:.4g} worse than baseline {base:.4g} "
                    f"by more than {tol:.0%} (limit {limit:.4g})")
        else:
            limit = base * (1 - tol)
            if cur < limit:
                errors.append(
                    f"{name}: {cur:.4g} worse than baseline {base:.4g} "
                    f"by more than {tol:.0%} (limit {limit:.4g})")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True,
                    help="engine_bench --tiers --dispatch all JSON artifact")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline (default BENCH_BASELINE.json)")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current artifact")
    args = ap.parse_args()

    with open(args.current) as f:
        current = extract(json.load(f))
    if not current:
        print("check_bench: current artifact has none of the gated "
              "metrics (was the bench run with --dispatch all?)")
        return 1

    if args.update:
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"check_bench: baseline updated -> {args.baseline}")
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)
    errors = compare(baseline, current, args.tol)
    for e in errors:
        print(f"check_bench: {e}")
    if errors:
        print(f"check_bench: {len(errors)} regression(s) beyond "
              f"{args.tol:.0%}")
        return 1
    print(f"check_bench: OK ({len(baseline)} metrics within "
          f"{args.tol:.0%} of baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
