"""Documentation lint for the serving stack (CI gate, no dependencies).

Two checks:

  1. **Config/stats docstring coverage** — every public field of the
     dataclasses listed in ``DOCUMENTED_CLASSES`` must be *named* in its
     class docstring, so units and semantics live next to the field and a
     new knob cannot land undocumented. (A pydocstyle-lite: we check
     coverage, not prose style.)

  2. **Markdown link integrity** — every relative link target in
     ``README.md`` and ``docs/*.md`` must exist in the repo, and every
     backticked repo path (``src/...``, ``tests/...``, ...) must point at
     a real file or directory, so the architecture tour cannot rot
     silently as files move.

Run from the repo root: ``PYTHONPATH=src python tools/check_docs.py``.
Exit status 0 = clean; 1 = violations (each printed on its own line).
"""
from __future__ import annotations

import dataclasses
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# (module, class): every dataclass field must appear by name in __doc__
DOCUMENTED_CLASSES = [
    ("repro.serving.config", "ServeConfig"),
    ("repro.serving.engine", "EngineStats"),
    ("repro.serving.kvpool", "PoolStats"),
    ("repro.serving.expertstore", "TierConfig"),
    ("repro.serving.expertstore", "StoreStats"),
    ("repro.serving.expertstore", "DispatchPlanner"),
    ("repro.core.cache", "CacheStats"),
    ("repro.serving.workload", "SLO"),
    ("repro.serving.workload", "PriorityClass"),
    ("repro.serving.workload", "WorkloadRequest"),
    ("repro.core.metrics", "RequestLatency"),
    ("repro.core.metrics", "LatencyStats"),
    ("repro.analysis.linter", "Diagnostic"),
    ("repro.serving.telemetry", "Telemetry"),
    ("repro.serving.telemetry", "Span"),
    ("repro.serving.telemetry", "SeriesPoint"),
]

MARKDOWN = ["README.md"] + sorted(
    os.path.join("docs", f)
    for f in (os.listdir(os.path.join(REPO, "docs"))
              if os.path.isdir(os.path.join(REPO, "docs")) else [])
    if f.endswith(".md"))

# backticked repo paths must start with one of these to be checked (other
# backticks are code, flags, or config values, not paths)
PATH_PREFIXES = ("src/", "tests/", "docs/", "benchmarks/", "examples/",
                 "tools/", ".github/")

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(#[^)\s]*)?\)")
TICK_RE = re.compile(r"`([^`\n]+)`")


def check_docstrings() -> list:
    errors = []
    for mod_name, cls_name in DOCUMENTED_CLASSES:
        mod = __import__(mod_name, fromlist=[cls_name])
        cls = getattr(mod, cls_name)
        doc = cls.__doc__ or ""
        if not dataclasses.is_dataclass(cls):
            errors.append(f"{mod_name}.{cls_name}: not a dataclass")
            continue
        for f in dataclasses.fields(cls):
            if f.name.startswith("_"):
                continue
            if not re.search(rf"``{re.escape(f.name)}``", doc):
                errors.append(
                    f"{mod_name}.{cls_name}: field ``{f.name}`` is not "
                    "documented in the class docstring")
    return errors


def check_markdown() -> list:
    errors = []
    for rel in MARKDOWN:
        path = os.path.join(REPO, rel)
        if not os.path.exists(path):
            errors.append(f"{rel}: file listed for checking does not exist")
            continue
        with open(path, encoding="utf-8") as f:
            text = f.read()
        base = os.path.dirname(path)
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            resolved = os.path.normpath(os.path.join(base, target))
            if not resolved.startswith(REPO):
                continue        # e.g. the CI badge's ../../actions link
            if not os.path.exists(resolved):
                errors.append(f"{rel}: broken link -> {target}")
        for m in TICK_RE.finditer(text):
            t = m.group(1).strip()
            if not t.startswith(PATH_PREFIXES):
                continue
            if any(c in t for c in " <>*?$(){}|"):
                continue        # a command line or glob, not a path
            t = t.split("::")[0].split(":")[0]   # strip :line / ::symbol
            if not os.path.exists(os.path.join(REPO, t)):
                errors.append(f"{rel}: backticked path does not exist "
                              f"-> {t}")
    return errors


def main() -> int:
    errors = check_docstrings() + check_markdown()
    for e in errors:
        print(f"check_docs: {e}")
    if errors:
        print(f"check_docs: {len(errors)} violation(s)")
        return 1
    n_fields = sum(
        len(dataclasses.fields(getattr(__import__(m, fromlist=[c]), c)))
        for m, c in DOCUMENTED_CLASSES)
    print(f"check_docs: OK ({len(DOCUMENTED_CLASSES)} classes / "
          f"{n_fields} fields documented, {len(MARKDOWN)} markdown files "
          "link-checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
