"""Chrome-trace artifact validator for the telemetry exporter (CI).

Validates the ``trace_event`` JSON emitted by
``Telemetry.to_chrome_trace`` (``src/repro/serving/telemetry.py``) and
written by ``engine_bench --trace``:

  * **schema** — every event carries the fields its phase requires
    (``M`` metadata needs ``name``/``args.name``; ``B``/``E`` span edges
    need ``ts``; ``X`` completes need ``ts`` + ``dur``; ``i`` instants
    need ``ts``), numeric fields are numeric, and phases outside the
    exporter's vocabulary are rejected;
  * **monotonic timestamps** — within each ``(pid, tid)`` track, ``ts``
    never decreases in file order (Perfetto tolerates disorder, but the
    exporter guarantees order, so disorder means an emitter bug);
  * **balanced spans** — ``B``/``E`` events nest like a stack per track
    and every ``B`` is closed (auto-closed spans are fine: the exporter
    marks them ``args.auto_closed``);
  * **named tracks** — every ``pid`` referenced by an event has a
    ``process_name`` metadata event and every ``(pid, tid)`` a
    ``thread_name`` one, so the Perfetto UI never shows bare numbers;
  * **scoreboard consistency** — when the artifact carries the
    predictor ``scoreboard`` section, per-window tp/fp/fn must sum to
    the run-level totals and each F1 must equal ``2tp / (2tp+fp+fn)``.

``--min-request-tracks`` / ``--min-channel-tracks`` additionally gate
the number of named threads under the ``requests`` / ``channels``
processes — the bench uses them to prove the trace actually contains
per-request timelines and async copy-channel tracks.

Usage (from the repo root):
  PYTHONPATH=src python -m benchmarks.engine_bench --tiny --trace \
      --out artifacts/engine_bench_trace.json
  python tools/check_trace.py artifacts/engine_bench_trace.json \
      --min-request-tracks 1 --min-channel-tracks 2

Exit 0 = valid; 1 = one problem per line on stderr. Stdlib only, like
the other ``tools/check_*.py`` gates.
"""
from __future__ import annotations

import argparse
import json
import sys

# Phases the exporter emits. Anything else in the artifact is a bug (the
# validator is a contract check on our exporter, not a general Chrome
# trace linter).
KNOWN_PHASES = {"M", "B", "E", "X", "i"}
METADATA_NAMES = {"process_name", "thread_name"}


def _is_num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def load_events(doc):
    """Return the event list from an artifact.

    Accepts the object form (``{"traceEvents": [...]}``, what the
    exporter writes) or a bare JSON array (also valid Chrome trace).
    """
    if isinstance(doc, list):
        return doc
    if isinstance(doc, dict) and isinstance(doc.get("traceEvents"), list):
        return doc["traceEvents"]
    raise ValueError("artifact is neither a traceEvents object nor an event array")


def check_events(events):
    """Validate schema, per-track monotonicity, span balance and naming.

    Returns a list of problem strings (empty = valid).
    """
    problems = []
    named_procs = set()
    named_threads = set()
    last_ts = {}
    stacks = {}

    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in KNOWN_PHASES:
            problems.append(f"{where}: unknown phase {ph!r}")
            continue
        pid, tid = ev.get("pid"), ev.get("tid")
        if not _is_num(pid) or not _is_num(tid):
            problems.append(f"{where}: pid/tid missing or non-numeric")
            continue

        if ph == "M":
            name = ev.get("name")
            label = (ev.get("args") or {}).get("name")
            if name not in METADATA_NAMES:
                problems.append(f"{where}: metadata name {name!r} not in "
                                f"{sorted(METADATA_NAMES)}")
            elif not isinstance(label, str) or not label:
                problems.append(f"{where}: {name} without args.name label")
            elif name == "process_name":
                named_procs.add(pid)
            else:
                named_threads.add((pid, tid))
            continue

        # Non-metadata events: need a timestamp, monotonic per track.
        ts = ev.get("ts")
        if not _is_num(ts):
            problems.append(f"{where}: ph={ph} without numeric ts")
            continue
        track = (pid, tid)
        if ts < last_ts.get(track, float("-inf")):
            problems.append(f"{where}: ts {ts} < previous {last_ts[track]} "
                            f"on track pid={pid} tid={tid}")
        last_ts[track] = ts

        if not isinstance(ev.get("name"), str) or not ev["name"]:
            problems.append(f"{where}: ph={ph} without a name")
            continue
        if ph == "X":
            if not _is_num(ev.get("dur")) or ev["dur"] < 0:
                problems.append(f"{where}: X event without non-negative dur")
        elif ph == "B":
            stacks.setdefault(track, []).append((i, ev["name"]))
        elif ph == "E":
            stack = stacks.setdefault(track, [])
            if not stack:
                problems.append(f"{where}: E {ev['name']!r} with no open B "
                                f"on track pid={pid} tid={tid}")
            else:
                _, open_name = stack.pop()
                if open_name != ev["name"]:
                    problems.append(f"{where}: E {ev['name']!r} closes "
                                    f"B {open_name!r} (bad nesting)")

    for (pid, tid), stack in sorted(stacks.items()):
        for i, name in stack:
            problems.append(f"event[{i}]: B {name!r} never closed on track "
                            f"pid={pid} tid={tid}")

    used_pids = {ev.get("pid") for ev in events
                 if isinstance(ev, dict) and ev.get("ph") in KNOWN_PHASES
                 and _is_num(ev.get("pid"))}
    used_tracks = {(ev.get("pid"), ev.get("tid")) for ev in events
                   if isinstance(ev, dict)
                   and ev.get("ph") in KNOWN_PHASES - {"M"}
                   and _is_num(ev.get("pid")) and _is_num(ev.get("tid"))}
    for pid in sorted(used_pids - named_procs):
        problems.append(f"pid {pid} has events but no process_name metadata")
    for pid, tid in sorted(used_tracks - named_threads):
        problems.append(f"track pid={pid} tid={tid} has events but no "
                        f"thread_name metadata")
    return problems


def track_names(events):
    """Map process label -> list of thread labels under it."""
    proc_label = {}
    threads = {}
    for ev in events:
        if not isinstance(ev, dict) or ev.get("ph") != "M":
            continue
        label = (ev.get("args") or {}).get("name")
        if ev.get("name") == "process_name":
            proc_label[ev.get("pid")] = label
        elif ev.get("name") == "thread_name":
            threads.setdefault(ev.get("pid"), []).append(label)
    return {label: threads.get(pid, []) for pid, label in proc_label.items()}


def check_scoreboard(doc):
    """Validate the scoreboard section, if present.

    Windows must sum to the run-level totals and every F1 (per-window
    and total) must match ``2tp / (2tp + fp + fn)``.
    """
    problems = []
    if not isinstance(doc, dict) or "scoreboard" not in doc:
        return problems
    sb = doc["scoreboard"]
    windows, total = sb.get("windows"), sb.get("total")
    if not isinstance(windows, list) or not isinstance(total, dict):
        return [f"scoreboard: expected windows list + total dict, got "
                f"{type(windows).__name__}/{type(total).__name__}"]

    def f1_of(row):
        tp, fp, fn = row["tp"], row["fp"], row["fn"]
        return 2 * tp / max(2 * tp + fp + fn, 1)

    for field in ("tp", "fp", "fn", "t01_hits", "t01_misses"):
        got = sum(w.get(field, 0) for w in windows)
        want = total.get(field, 0)
        if abs(got - want) > 1e-9:
            problems.append(f"scoreboard: windows sum {field}={got} != "
                            f"total {want}")
    for label, row in [("total", total)] + [
            (f"window[{i}]", w) for i, w in enumerate(windows)]:
        if abs(row.get("f1", 0.0) - f1_of(row)) > 1e-9:
            problems.append(f"scoreboard {label}: f1 {row.get('f1')} != "
                            f"2tp/(2tp+fp+fn) = {f1_of(row)}")
    return problems


def check_artifact(doc, min_request_tracks=0, min_channel_tracks=0):
    """Full validation; returns a list of problem strings."""
    try:
        events = load_events(doc)
    except ValueError as e:
        return [str(e)]
    problems = check_events(events)
    problems += check_scoreboard(doc)
    names = track_names(events)
    n_req = len(names.get("requests", []))
    n_chan = len(names.get("channels", []))
    if n_req < min_request_tracks:
        problems.append(f"only {n_req} request track(s), need "
                        f">= {min_request_tracks}")
    if n_chan < min_channel_tracks:
        problems.append(f"only {n_chan} channel track(s), need "
                        f">= {min_channel_tracks}")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifact", help="Chrome-trace JSON to validate")
    ap.add_argument("--min-request-tracks", type=int, default=0,
                    help="minimum named threads under the 'requests' process")
    ap.add_argument("--min-channel-tracks", type=int, default=0,
                    help="minimum named threads under the 'channels' process")
    args = ap.parse_args(argv)

    with open(args.artifact) as f:
        doc = json.load(f)
    problems = check_artifact(doc, args.min_request_tracks,
                              args.min_channel_tracks)
    for p in problems:
        print(f"check_trace: {p}", file=sys.stderr)
    if not problems:
        events = load_events(doc)
        n_spans = sum(1 for e in events if e.get("ph") in ("B", "X"))
        print(f"check_trace: OK ({len(events)} events, {n_spans} spans, "
              f"{len(track_names(events))} processes)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
