"""Repo-contract linter runner (CI gate, stdlib only — no jax needed).

Runs the AST rule engine (``src/repro/analysis/``) over ``src/``,
``benchmarks/`` and ``tools/`` and fails on any unsuppressed finding.
The five shipped rules guard the serving stack's conventions: refcount
acquire/release pairing, tracer purity inside jitted code, pow-2 shape
bucketing at jit call sites, stats-field docstring+serialization
registration, and config-knob test parity (see
``docs/ARCHITECTURE.md`` "Static analysis & sanitizers").

Findings print as ``file:line:rule-id message``. Silencing one takes an
*audited suppression* on the offending line (or standalone above it)::

    # lint: disable=<rule-id> -- <why this is safe>

The reason is mandatory; a reason-less suppression is itself a finding.

Usage (from the repo root):
  python tools/check_lint.py [--json artifacts/lint.json] [paths...]

Exit 0 = clean; 1 = findings (each printed on its own line).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.analysis.linter import run_lint            # noqa: E402
from repro.analysis.rules import default_rules        # noqa: E402

DEFAULT_PATHS = ["src", "benchmarks", "tools"]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to lint (default: "
                         f"{' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the machine-readable report here")
    args = ap.parse_args()

    paths = args.paths or DEFAULT_PATHS
    report = run_lint(REPO, paths, default_rules())

    if args.json_out:
        out_dir = os.path.dirname(args.json_out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(args.json_out, "w", encoding="utf-8") as f:
            f.write(report.to_json())

    for d in report.findings:
        print(f"check_lint: {d.format()}")
    if report.findings:
        counts = ", ".join(f"{r}={n}" for r, n in
                           sorted(report.by_rule().items()))
        print(f"check_lint: {len(report.findings)} finding(s) [{counts}] "
              f"across {len(report.files)} files")
        return 1
    print(f"check_lint: OK ({len(report.files)} files, "
          f"{len(report.rule_ids)} rules, "
          f"{len(report.suppressed)} audited suppression(s))")
    if report.suppressed:
        doc = json.loads(report.to_json())
        for s in doc["suppressed"]:
            print(f"check_lint:   suppressed {s['file']}:{s['line']}:"
                  f"{s['rule']} -- {s['reason']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
